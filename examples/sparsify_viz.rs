//! Regenerates the paper's Figures 5-8: per-dataset panels comparing the
//! optimal Sakoe-Chiba corridor, the raw sparse-paths occupancy grid and
//! the thresholded grid.  Writes PPM/PGM images + ASCII previews under
//! `out/figs/` and prints the ASCII art.
//!
//! ```bash
//! cargo run --release --example sparsify_viz -- [dataset ...]
//! ```

use spdtw::data::synthetic;
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::tuning;
use spdtw::viz::Heatmap;

fn main() -> spdtw::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let datasets: Vec<String> = if args.is_empty() {
        // the paper's Fig. 5-8 subjects
        ["Beef", "BeetleFly", "ElectricDevices", "MedicalImages"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        args
    };
    let out = std::path::PathBuf::from("out/figs");
    for name in &datasets {
        let ds = synthetic::generate_scaled(name, 42, 24, 0)?;
        let t = ds.series_len();
        let grid = learn_occupancy_grid(&ds.train, 8);
        let (band_pct, _) = tuning::tune_band_pct(&ds.train, &tuning::band_pct_grid(), 8);
        let (theta, _) = tuning::tune_theta(&grid, &ds.train, 1.0, &tuning::theta_grid(), 8);
        let band = ((band_pct / 100.0) * t as f64).round() as usize;
        println!("\n== {name} (T={t}) — optimal band={band}, θ={theta} ==");
        let panels = [
            ("sakoe_chiba", Heatmap::corridor(t, band)),
            ("sparse_paths", Heatmap::from_occupancy(&grid)),
            (
                "thresholded",
                Heatmap::from_loc_support(&grid.threshold(theta).to_loc_mask()),
            ),
        ];
        for (panel, hm) in &panels {
            let dir = out.join(name);
            hm.write_ppm(&dir.join(format!("{panel}.ppm")), 256)?;
            hm.write_pgm(&dir.join(format!("{panel}.pgm")), 256)?;
            println!("\n-- {panel} --\n{}", hm.ascii(40));
        }
    }
    println!("images written under out/figs/");
    Ok(())
}
