//! End-to-end driver (DESIGN.md: the mandated full-system workload):
//! run the complete paper pipeline — synthetic archive generation, grid
//! learning, LOO meta-parameter tuning, 1-NN + SVM evaluation of every
//! measure, visited-cell accounting — over a slice of the archive, and
//! print Table II / IV / VI-style rows.  Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example ucr_classification -- [dataset ...]
//! ```

use spdtw::config::ExperimentConfig;
use spdtw::experiments::runner::{evaluate_dataset, NN_METHODS, SVM_METHODS};
use spdtw::util::timer::Stopwatch;

fn main() -> spdtw::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let datasets: Vec<String> = if args.is_empty() {
        ["CBF", "SyntheticControl", "Gun-Point", "ECGFiveDays", "Wine"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        args
    };
    let cfg = ExperimentConfig {
        max_train: 30,
        max_test: 40,
        datasets: datasets.clone(),
        ..Default::default()
    };

    println!(
        "== SP-DTW end-to-end pipeline (seed={}, caps {}x{}) ==\n",
        cfg.seed, cfg.max_train, cfg.max_test
    );
    let mut header = format!("{:<18}", "dataset");
    for m in NN_METHODS {
        header.push_str(&format!("{m:>10}"));
    }
    println!("-- Table II shape: 1-NN error rates --\n{header}");

    let mut evals = Vec::new();
    let mut sw = Stopwatch::new();
    for name in &datasets {
        let ev = sw.measure(name, || evaluate_dataset(&cfg, name, true))?;
        let mut row = format!("{:<18}", ev.name);
        for m in NN_METHODS {
            row.push_str(&format!("{:>10.3}", ev.err_1nn[*m]));
        }
        println!("{row}");
        evals.push(ev);
    }

    println!("\n-- Table IV shape: SVM error rates --");
    let mut header = format!("{:<18}", "dataset");
    for m in SVM_METHODS {
        header.push_str(&format!("{m:>10}"));
    }
    println!("{header}");
    for ev in &evals {
        let mut row = format!("{:<18}", ev.name);
        for m in SVM_METHODS {
            row.push_str(&format!("{:>10.3}", ev.err_svm[*m]));
        }
        println!("{row}");
    }

    println!("\n-- Table VI shape: visited cells per comparison --");
    println!(
        "{:<18}{:>12}{:>12}{:>9}{:>12}{:>9}",
        "dataset", "DTW", "SP-DTW", "S(%)", "SP-Krdtw", "S(%)"
    );
    for ev in &evals {
        let full = ev.cells["DTW"] as f64;
        let sp = ev.cells["SP-DTW"] as f64;
        let spk = ev.cells["SP-Krdtw"] as f64;
        println!(
            "{:<18}{:>12}{:>12}{:>9.1}{:>12}{:>9.1}",
            ev.name,
            full as u64,
            sp as u64,
            100.0 * (1.0 - sp / full),
            spk as u64,
            100.0 * (1.0 - spk / full),
        );
    }

    println!("\n-- tuned meta-parameters --");
    for ev in &evals {
        println!(
            "{:<18} θ={:<4} γ={:<5} ν={:<6} band={}%",
            ev.name, ev.theta, ev.gamma, ev.nu, ev.band_pct
        );
    }

    println!("\n-- wall clock --\n{}", sw.report());
    Ok(())
}
