//! Serving end-to-end driver: boots the full three-layer stack — AOT
//! Pallas/XLA artifacts loaded by the PJRT runtime, fronted by the Rust
//! coordinator with its length-bucket batcher — then drives a batched
//! distance workload through BOTH backends and reports latency /
//! throughput plus numeric parity.  This is the proof that all layers
//! compose on a real workload (results recorded in EXPERIMENTS.md).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_pjrt
//! ```

use std::sync::Arc;
use std::time::Instant;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::Coordinator;
use spdtw::data::synthetic;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::Measure;
use spdtw::runtime::PjrtRuntime;
use spdtw::sparse::learn::learn_occupancy_grid;

fn main() -> spdtw::Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");
    let dataset = "SyntheticControl"; // T=60 — has dtw + krdtw buckets
    let n_queries = 512;

    // ---- model prep: learn + sparsify on train ---------------------------
    let ds = synthetic::generate_scaled(dataset, 42, 60, 64)?;
    let t = ds.series_len();
    let grid = learn_occupancy_grid(&ds.train, 8);
    let loc = grid.threshold(2.0).to_loc(1.0);
    println!(
        "{dataset}: T={t}, LOC {} cells ({:.1}% sparsity)",
        loc.nnz(),
        100.0 * loc.sparsity()
    );

    // ---- stack boot -------------------------------------------------------
    let runtime = PjrtRuntime::start(&artifacts)?;
    println!("pjrt: {}", runtime.handle().info()?.platform);

    let queries: Vec<_> = (0..n_queries)
        .map(|i| {
            let a = &ds.test.series[i % ds.test.len()];
            let b = &ds.train.series[(i * 7) % ds.train.len()];
            (a.clone(), b.clone())
        })
        .collect();

    let mut parity: Vec<(f64, f64)> = Vec::new();
    for (label, prefer_pjrt) in [("native", false), ("pjrt", true)] {
        let cfg = CoordinatorConfig {
            prefer_pjrt,
            flush_us: 2_000,
            ..Default::default()
        };
        let coord = Arc::new(Coordinator::start(cfg, Some(runtime.handle()))?);
        let key = coord.register_grid(loc.clone())?;

        // warmup (compile on first batch)
        let w = coord.submit_spdtw(key, &queries[0].0, &queries[0].1)?;
        coord.flush();
        w.wait()?;

        let t0 = Instant::now();
        let tickets: Vec<_> = queries
            .iter()
            .map(|(x, y)| coord.submit_spdtw(key, x, y))
            .collect::<spdtw::Result<_>>()?;
        coord.flush();
        let values: Vec<f64> = tickets
            .into_iter()
            .map(|t| t.wait().map(|r| r.value))
            .collect::<spdtw::Result<_>>()?;
        let dt = t0.elapsed();
        let snap = coord.metrics();
        println!(
            "\n[{label}] {n_queries} queries in {:.1} ms -> {:.0} pairs/s",
            dt.as_secs_f64() * 1e3,
            n_queries as f64 / dt.as_secs_f64()
        );
        println!("{}", snap.report());
        if parity.is_empty() {
            parity = values.iter().map(|&v| (v, 0.0)).collect();
        } else {
            for (p, &v) in parity.iter_mut().zip(&values) {
                p.1 = v;
            }
        }
    }

    // ---- parity check ------------------------------------------------------
    let sp = SpDtw::new(loc);
    let direct = sp.dist(&queries[3].0, &queries[3].1).value;
    let max_rel = parity
        .iter()
        .map(|&(a, b)| (a - b).abs() / a.abs().max(1e-9))
        .fold(0.0f64, f64::max);
    println!("\nnative vs pjrt max relative diff over {n_queries} queries: {max_rel:.2e}");
    println!("spot check vs direct eval: {direct:.6} (native path {:.6})", parity[3].0);
    assert!(max_rel < 1e-3, "backend parity violated");
    println!("\nOK: three-layer stack (Pallas → HLO → PJRT → coordinator) verified.");
    Ok(())
}
