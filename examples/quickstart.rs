//! Quickstart: the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Generate a synthetic UCR-style dataset (CBF).
//! 2. Learn the alignment-path occupancy grid on the train split.
//! 3. Threshold it into the sparse LOC search space.
//! 4. Compare DTW vs SP-DTW: same decisions, far fewer visited cells.

use spdtw::classify::nn::classify_1nn;
use spdtw::data::synthetic;
use spdtw::measures::dtw::Dtw;
use spdtw::measures::euclidean::Euclidean;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::Measure;
use spdtw::sparse::learn::learn_occupancy_grid;

fn main() -> spdtw::Result<()> {
    // 1. data -------------------------------------------------------------
    let ds = synthetic::generate_scaled("CBF", 42, 30, 120)?;
    println!(
        "dataset: {} (T={}, train={}, test={})",
        ds.name,
        ds.series_len(),
        ds.train.len(),
        ds.test.len()
    );

    // 2. learn the occupancy grid (Fig. 3 of the paper) --------------------
    let grid = learn_occupancy_grid(&ds.train, 8);
    println!(
        "occupancy grid: {} of {} cells ever visited by an optimal path",
        grid.support(),
        grid.t * grid.t
    );

    // 3. sparsify ----------------------------------------------------------
    let theta = 2.0; // percent of max occupancy (tuned by LOO in the full pipeline)
    let loc = grid.threshold(theta).to_loc(1.0);
    println!(
        "LOC sparse search space: {} cells ({:.1}% speed-up vs full DTW)",
        loc.nnz(),
        loc.speedup_pct()
    );

    // 4. one pair, then a whole classification -----------------------------
    let (a, b) = (&ds.test.series[0], &ds.test.series[1]);
    let sp = SpDtw::new(loc);
    let d_full = Dtw.dist(a, b);
    let d_sp = sp.dist(a, b);
    println!(
        "pair distance: DTW={:.4} ({} cells) | SP-DTW={:.4} ({} cells)",
        d_full.value, d_full.visited_cells, d_sp.value, d_sp.visited_cells
    );

    for (name, m) in [
        ("Ed", &Euclidean as &dyn Measure),
        ("DTW", &Dtw as &dyn Measure),
        ("SP-DTW", &sp as &dyn Measure),
    ] {
        let r = classify_1nn(m, &ds.train, &ds.test, 8);
        println!(
            "1-NN [{name:>6}]: error={:.3}  visited cells={}",
            r.error_rate, r.visited_cells
        );
    }
    Ok(())
}
