"""Pallas krdtw_wavefront kernel vs the numpy oracles.

Checks the log-domain wavefront against both the log-domain reference and
(for small T where it does not underflow) the plain-domain Algorithm 2
transcription.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import NEG, krdtw_wavefront, pack_diagonals
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)
NEG_THRESH = -1.0e29


def run_kernel(x, y, mask, nu, block_b=None):
    md = pack_diagonals(mask.astype(np.float64), np.float64(0.0))
    out = krdtw_wavefront(
        jnp.asarray(x, np.float64),
        jnp.asarray(y, np.float64),
        jnp.asarray(md),
        nu,
        block_b=block_b,
    )
    return np.asarray(out)


@st.composite
def pair_batch(draw, max_b=4, max_t=16):
    b = draw(st.integers(1, max_b))
    t = draw(st.integers(2, max_t))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, t))
    y = rng.normal(size=(b, t))
    nu = draw(st.sampled_from([0.1, 0.5, 1.0, 5.0]))
    return x, y, nu, rng


@given(pair_batch())
@settings(**SETTINGS)
def test_full_grid_matches_log_ref(batch):
    x, y, nu, _ = batch
    t = x.shape[1]
    mask = np.ones((t, t), bool)
    got = run_kernel(x, y, mask, nu)
    for i in range(x.shape[0]):
        exp = ref.krdtw_log_ref(x[i], y[i], mask, nu)
        np.testing.assert_allclose(got[i], exp, rtol=1e-10, atol=1e-10)


@given(pair_batch())
@settings(**SETTINGS)
def test_matches_plain_algorithm2_small_t(batch):
    """exp(kernel) == plain-domain Algorithm 2 while it still has headroom."""
    x, y, nu, _ = batch
    t = x.shape[1]
    mask = np.ones((t, t), bool)
    got = run_kernel(x, y, mask, nu)
    for i in range(x.shape[0]):
        plain = ref.krdtw_plain_ref(x[i], y[i], mask, nu)
        if plain > 1e-280:
            np.testing.assert_allclose(np.exp(got[i]), plain, rtol=1e-8)


@given(pair_batch(), st.integers(0, 8))
@settings(**SETTINGS)
def test_corridor_mask_matches_ref(batch, band):
    x, y, nu, _ = batch
    t = x.shape[1]
    mask = ref.sakoe_chiba_mask(t, band)
    got = run_kernel(x, y, mask, nu)
    for i in range(x.shape[0]):
        exp = ref.krdtw_log_ref(x[i], y[i], mask, nu)
        np.testing.assert_allclose(got[i], exp, rtol=1e-10, atol=1e-10)


@given(pair_batch())
@settings(**SETTINGS)
def test_sparse_mask_matches_ref(batch):
    x, y, nu, rng = batch
    t = x.shape[1]
    mask = rng.uniform(size=(t, t)) < 0.6
    np.fill_diagonal(mask, True)  # keep a path alive
    got = run_kernel(x, y, mask, nu)
    for i in range(x.shape[0]):
        exp = ref.krdtw_log_ref(x[i], y[i], mask, nu)
        np.testing.assert_allclose(got[i], exp, rtol=1e-10, atol=1e-10)


def test_empty_mask_returns_neg():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(2, 10))
    y = rng.normal(size=(2, 10))
    got = run_kernel(x, y, np.zeros((10, 10), bool), 1.0)
    assert (got <= NEG_THRESH).all()


def test_symmetry():
    """K_rdtw(x, y) == K_rdtw(y, x) on symmetric masks."""
    rng = np.random.default_rng(9)
    x = rng.normal(size=(3, 12))
    y = rng.normal(size=(3, 12))
    mask = ref.sakoe_chiba_mask(12, 4)
    a = run_kernel(x, y, mask, 0.5)
    b = run_kernel(y, x, mask, 0.5)
    np.testing.assert_allclose(a, b, rtol=1e-10)


def test_no_underflow_long_series():
    """T = 256 underflows plain f64 ((kappa/3)^512 ~ 1e-240-...) but the
    log-domain kernel must stay finite and match the log reference."""
    rng = np.random.default_rng(21)
    t = 256
    x = rng.normal(size=(1, t))
    y = rng.normal(size=(1, t))
    mask = ref.sakoe_chiba_mask(t, 20)
    got = run_kernel(x, y, mask, 1.0)
    assert np.isfinite(got).all() and got[0] > NEG_THRESH
    exp = ref.krdtw_log_ref(x[0], y[0], mask, 1.0)
    np.testing.assert_allclose(got[0], exp, rtol=1e-9)


def test_batch_tiling_invariance():
    rng = np.random.default_rng(31)
    x = rng.normal(size=(4, 14))
    y = rng.normal(size=(4, 14))
    mask = np.ones((14, 14), bool)
    full = run_kernel(x, y, mask, 0.7, block_b=4)
    for bb in (1, 2):
        np.testing.assert_allclose(run_kernel(x, y, mask, 0.7, block_b=bb), full)


def test_gram_positive_definite():
    """Small Gram matrix of normalized SP-Krdtw values is p.s.d. — the
    paper's core claim for the kernelized variant (Eq. 6)."""
    rng = np.random.default_rng(17)
    n, t = 8, 12
    series = rng.normal(size=(n, t))
    mask = ref.sakoe_chiba_mask(t, 5)
    lk = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            lk[i, j] = run_kernel(series[i : i + 1], series[j : j + 1], mask, 0.5)[0]
    diag = np.diag(lk)
    gram = np.exp(lk - 0.5 * (diag[:, None] + diag[None, :]))
    eig = np.linalg.eigvalsh(gram)
    assert eig.min() > -1e-10, eig
