"""Pallas dtw_wavefront kernel vs the pure-numpy oracle.

Hypothesis sweeps shapes, dtypes, batch tiling and mask families; every
case asserts allclose against ``ref.dtw_ref`` (the straight Algorithm 1
transcription).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import BIG, BIG_THRESH, dtw_wavefront, pack_diagonals
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def run_kernel(x, y, w, block_b=None, dtype=np.float32):
    b, t = x.shape
    wd = pack_diagonals(w.astype(dtype), dtype(BIG))
    out = dtw_wavefront(
        jnp.asarray(x, dtype), jnp.asarray(y, dtype), jnp.asarray(wd), block_b=block_b
    )
    return np.asarray(out)


def check(x, y, w, block_b=None, dtype=np.float32, rtol=1e-3):
    got = run_kernel(x, y, w, block_b=block_b, dtype=dtype)
    for i in range(x.shape[0]):
        exp = ref.dtw_ref(x[i], y[i], w.astype(np.float64))
        if exp >= BIG_THRESH:
            assert got[i] >= BIG_THRESH, (i, got[i], exp)
        else:
            np.testing.assert_allclose(got[i], exp, rtol=rtol, atol=1e-5)


@st.composite
def pair_batch(draw, max_b=6, max_t=24):
    b = draw(st.integers(1, max_b))
    t = draw(st.integers(2, max_t))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    scale = draw(st.sampled_from([0.1, 1.0, 10.0]))
    x = (rng.normal(size=(b, t)) * scale).astype(np.float32)
    y = (rng.normal(size=(b, t)) * scale).astype(np.float32)
    return x, y, rng


@given(pair_batch())
@settings(**SETTINGS)
def test_full_grid_matches_ref(batch):
    x, y, _ = batch
    t = x.shape[1]
    check(x, y, np.ones((t, t)))


@given(pair_batch(), st.integers(0, 10))
@settings(**SETTINGS)
def test_sakoe_chiba_band_matches_ref(batch, band):
    x, y, _ = batch
    t = x.shape[1]
    mask = ref.sakoe_chiba_mask(t, band)
    w = np.where(mask, 1.0, BIG)
    check(x, y, w)


@given(pair_batch(), st.floats(0.0, 3.0))
@settings(**SETTINGS)
def test_weighted_sparse_grid_matches_ref(batch, gamma):
    """Random sparse occupancy-style weights (SP-DTW shape)."""
    x, y, rng = batch
    t = x.shape[1]
    p = rng.uniform(0.05, 1.0, size=(t, t))
    keep = rng.uniform(size=(t, t)) < 0.7
    # always keep the main diagonal so a path exists
    np.fill_diagonal(keep, True)
    w = np.where(keep, p ** (-gamma), BIG)
    check(x, y, w, rtol=5e-3)


@given(pair_batch())
@settings(**SETTINGS)
def test_fully_masked_grid_is_unreachable(batch):
    x, y, _ = batch
    t = x.shape[1]
    w = np.full((t, t), BIG)
    got = run_kernel(x, y, w)
    assert (got >= BIG_THRESH).all()


def test_identity_pair_is_zero():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(3, 16)).astype(np.float32)
    t = x.shape[1]
    got = run_kernel(x, x.copy(), np.ones((t, t)))
    np.testing.assert_allclose(got, 0.0, atol=1e-6)


def test_paper_triangle_counterexample():
    """Footnote 2 of the paper: DTW([0],[1,2])=3 etc. with padding to equal
    length via explicit small series (computed pairwise at their own T)."""
    # [0] vs [1,2]: use T=2 by the paper's convention of repeating? The
    # footnote uses different-length series; emulate with the ref oracle
    # directly (the kernel buckets are same-length by design).
    d = np.full((1, 2), BIG)
    # Build the 1x2 DP by hand: D(0,0)=1, D(0,1)=1+4=5?? The paper uses
    # squared costs: phi(0,1)=1, phi(0,2)=4 -> DTW=5? It reports 3 with
    # |.| costs. We verify the |.|-cost variant numerically here.
    x = np.array([0.0])
    y = np.array([1.0, 2.0])
    # abs-cost DP on a 1x2 grid: D(0,0)=1, D(0,1)=D(0,0)+2=3
    dtw_xy = abs(0 - 1) + abs(0 - 2)
    assert dtw_xy == 3


@given(pair_batch())
@settings(**SETTINGS)
def test_dtw_leq_euclidean_alignment(batch):
    """The Euclidean (diagonal) path is admissible, so DTW <= sum (x-y)^2."""
    x, y, _ = batch
    t = x.shape[1]
    got = run_kernel(x, y, np.ones((t, t)))
    euc = ((x.astype(np.float64) - y) ** 2).sum(axis=1)
    assert (got <= euc + 1e-3 * np.abs(euc) + 1e-5).all()


def test_batch_tiling_invariance():
    """Result must not depend on the BlockSpec batch tile."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(8, 20)).astype(np.float32)
    y = rng.normal(size=(8, 20)).astype(np.float32)
    t = 20
    w = np.where(ref.sakoe_chiba_mask(t, 5), 1.0, BIG)
    full = run_kernel(x, y, w, block_b=8)
    for bb in (1, 2, 4):
        np.testing.assert_allclose(run_kernel(x, y, w, block_b=bb), full, rtol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dtypes(dtype):
    rng = np.random.default_rng(11)
    x = rng.normal(size=(2, 15)).astype(dtype)
    y = rng.normal(size=(2, 15)).astype(dtype)
    check(x, y, np.ones((15, 15)), dtype=dtype, rtol=1e-3 if dtype == np.float32 else 1e-9)


def test_gamma_zero_equals_plain_dtw():
    """SP-DTW with gamma=0 on a full grid IS the standard DTW (paper §III)."""
    rng = np.random.default_rng(13)
    x = rng.normal(size=(4, 18)).astype(np.float32)
    y = rng.normal(size=(4, 18)).astype(np.float32)
    t = 18
    p = rng.uniform(0.1, 1.0, size=(t, t))
    w_gamma0 = p**0.0  # all ones
    a = run_kernel(x, y, w_gamma0)
    b = run_kernel(x, y, np.ones((t, t)))
    np.testing.assert_allclose(a, b, rtol=1e-6)
