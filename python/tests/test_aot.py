"""AOT pipeline smoke tests: lowering, HLO text shape, manifest integrity."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import BIG, pack_diagonals


def test_dtw_lowering_produces_hlo_text():
    lowered = jax.jit(model.dtw_batch).lower(*model.dtw_batch_spec(4, 16))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,16]" in text  # batched inputs present
    # No Mosaic custom-call may survive: interpret=True lowers to plain HLO.
    assert "tpu_custom_call" not in text and "mosaic" not in text.lower()


def test_krdtw_lowering_is_f64():
    lowered = jax.jit(model.krdtw_batch).lower(*model.krdtw_batch_spec(4, 16))
    text = aot.to_hlo_text(lowered)
    assert "f64[4,16]" in text


def test_lowered_executable_matches_eager(tmp_path):
    """Round-trip: the lowered+compiled module computes the same numbers as
    the eager kernel call (this is what the Rust runtime will execute)."""
    b, t = 4, 16
    rng = np.random.default_rng(0)
    x = rng.normal(size=(b, t)).astype(np.float32)
    y = rng.normal(size=(b, t)).astype(np.float32)
    wd = pack_diagonals(np.ones((t, t), np.float32), np.float32(BIG))
    lowered = jax.jit(model.dtw_batch).lower(*model.dtw_batch_spec(b, t))
    compiled = lowered.compile()
    got = np.asarray(compiled(jnp.array(x), jnp.array(y), jnp.array(wd))[0])
    eager = np.asarray(model.dtw_batch(jnp.array(x), jnp.array(y), jnp.array(wd))[0])
    np.testing.assert_allclose(got, eager, rtol=1e-6)


def test_build_writes_manifest_and_artifacts(tmp_path):
    out = str(tmp_path)
    manifest = aot.build(out)
    assert len(manifest["entries"]) == len(aot.DTW_BUCKETS) + len(aot.KRDTW_BUCKETS)
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
        assert e["kernel"] in ("dtw", "krdtw")
        assert e["batch"] > 0 and e["length"] > 1


def test_checked_in_manifest_consistent():
    """If artifacts/ was built, its manifest must list existing files."""
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(adir, "manifest.json")
    if not os.path.exists(mpath):
        return  # `make artifacts` not run yet — nothing to verify
    with open(mpath) as f:
        manifest = json.load(f)
    for e in manifest["entries"]:
        assert os.path.exists(os.path.join(adir, e["file"])), e["file"]
