"""Layer-2 JAX compute graphs (build-time only).

Thin batched graphs around the Layer-1 Pallas kernels; these are what
``aot.py`` lowers to HLO text for the Rust runtime.  One weighted-DTW
graph covers DTW / DTW_sc / SP-DTW; one masked K_rdtw graph covers
K_rdtw / K_rdtw_sc / SP-K_rdtw — the variant lives entirely in the
weight/mask plane the Rust coordinator feeds at request time (DESIGN.md
§1), so a single compiled executable per (T, B) bucket serves every
measure.

Input z-normalization is deliberately NOT part of the graph: the Rust
data layer normalizes once per dataset, not once per pair.
"""

import jax
import jax.numpy as jnp

from .kernels import dtw_wavefront, krdtw_wavefront


def dtw_batch(x, y, wdiag):
    """Batched weighted masked DTW; see kernels.dtw_wavefront.

    Shapes: x, y (B, T) f32; wdiag (2T-1, T) f32.  Returns (B,) f32.
    Wrapped in a 1-tuple: the AOT bridge lowers with return_tuple=True.
    """
    return (dtw_wavefront(x, y, wdiag),)


def krdtw_batch(x, y, mdiag, nu):
    """Batched log-domain K_rdtw; see kernels.krdtw_wavefront.

    Shapes: x, y (B, T) f64; mdiag (2T-1, T) f64 binary; nu (1,) f64.
    Returns (B,) f64 values of log(K1 + K2).
    """
    return (krdtw_wavefront(x, y, mdiag, nu),)


def dtw_batch_spec(b, t):
    """ShapeDtypeStructs for lowering dtw_batch at a (B, T) bucket."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((b, t), f32),
        jax.ShapeDtypeStruct((b, t), f32),
        jax.ShapeDtypeStruct((2 * t - 1, t), f32),
    )


def krdtw_batch_spec(b, t):
    """ShapeDtypeStructs for lowering krdtw_batch at a (B, T) bucket."""
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((b, t), f64),
        jax.ShapeDtypeStruct((b, t), f64),
        jax.ShapeDtypeStruct((2 * t - 1, t), f64),
        jax.ShapeDtypeStruct((1,), f64),
    )
