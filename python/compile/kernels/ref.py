"""Pure-numpy O(T^2) oracles for the wavefront kernels.

These are the correctness anchors: straightforward transcriptions of the
paper's Eq. 4 / Algorithm 1 (weighted masked DTW) and Algorithm 2
(K_rdtw over an admissible cell set), with no wavefront reformulation.
The Rust native implementations mirror the same semantics and are
cross-checked against the same worked examples in `rust/tests/`.
"""

import math

import numpy as np

from .common import BIG, BIG_THRESH


def dtw_ref(x, y, w):
    """Weighted masked DTW over full (T, T) weight matrix ``w``.

    Mirrors the kernel's BIG arithmetic exactly: sparsified-out cells
    (``w >= BIG_THRESH``) contribute an additive BIG instead of their
    local cost, unreachable cells hold BIG, so finite results match the
    kernel bit-for-bit-ish (same operation order up to reassociation).
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    t = len(x)
    assert len(y) == t and w.shape == (t, t)
    d = np.full((t, t), BIG, np.float64)
    for i in range(t):
        for j in range(t):
            if w[i, j] >= BIG_THRESH:
                local = BIG
            else:
                local = w[i, j] * (x[i] - y[j]) ** 2
            if i == 0 and j == 0:
                d[0, 0] = local
                continue
            best = BIG
            if i > 0:
                best = min(best, d[i - 1, j])
            if j > 0:
                best = min(best, d[i, j - 1])
            if i > 0 and j > 0:
                best = min(best, d[i - 1, j - 1])
            d[i, j] = local + best
    return d[t - 1, t - 1]


def dtw_plain_ref(x, y):
    """Unweighted DTW (all-ones weights) — the textbook recurrence."""
    t = len(x)
    return dtw_ref(x, y, np.ones((t, t)))


def krdtw_plain_ref(x, y, mask, nu):
    """Plain-domain Algorithm 2 (only valid for small T: underflows fast).

    ``mask`` is a (T, T) boolean admissible-cell matrix.  Returns
    K1(T-1, T-1) + K2(T-1, T-1).
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    t = len(x)

    def kap(a, b):
        return math.exp(-nu * (a - b) ** 2)

    k1 = np.zeros((t, t))
    k2 = np.zeros((t, t))
    for i in range(t):
        for j in range(t):
            if not mask[i, j]:
                continue
            if i == 0 and j == 0:
                k1[0, 0] = kap(x[0], y[0])
                k2[0, 0] = kap(x[0], y[0])
                continue
            p11 = k1[i - 1, j - 1] if i > 0 and j > 0 else 0.0
            p10 = k1[i - 1, j] if i > 0 else 0.0
            p01 = k1[i, j - 1] if j > 0 else 0.0
            k1[i, j] = (1.0 / 3.0) * kap(x[i], y[j]) * (p11 + p10 + p01)
            q11 = k2[i - 1, j - 1] if i > 0 and j > 0 else 0.0
            q10 = k2[i - 1, j] if i > 0 else 0.0
            q01 = k2[i, j - 1] if j > 0 else 0.0
            k_ii = kap(x[i], y[i])
            k_jj = kap(x[j], y[j])
            k2[i, j] = (1.0 / 3.0) * (
                (k_ii + k_jj) * 0.5 * q11 + q10 * k_ii + q01 * k_jj
            )
    return k1[t - 1, t - 1] + k2[t - 1, t - 1]


def krdtw_log_ref(x, y, mask, nu):
    """Log-domain Algorithm 2 — valid for any T. Returns log(K1 + K2)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    t = len(x)
    neg = -1.0e30

    def lkap(a, b):
        return -nu * (a - b) ** 2

    def lse(vals):
        m = max(vals)
        if m <= -1.0e29:
            return neg
        return m + math.log(sum(math.exp(max(v - m, -700.0)) for v in vals))

    l1 = np.full((t, t), neg)
    l2 = np.full((t, t), neg)
    log3 = math.log(3.0)
    for i in range(t):
        for j in range(t):
            if not mask[i, j]:
                continue
            if i == 0 and j == 0:
                l1[0, 0] = lkap(x[0], y[0])
                l2[0, 0] = lkap(x[0], y[0])
                continue
            p11 = l1[i - 1, j - 1] if i > 0 and j > 0 else neg
            p10 = l1[i - 1, j] if i > 0 else neg
            p01 = l1[i, j - 1] if j > 0 else neg
            l1[i, j] = lkap(x[i], y[j]) - log3 + lse([p11, p10, p01])
            q11 = l2[i - 1, j - 1] if i > 0 and j > 0 else neg
            q10 = l2[i - 1, j] if i > 0 else neg
            q01 = l2[i, j - 1] if j > 0 else neg
            ls_i = lkap(x[i], y[i])
            ls_j = lkap(x[j], y[j])
            avg = math.log(max((math.exp(ls_i) + math.exp(ls_j)) * 0.5, 1e-300))
            l2[i, j] = -log3 + lse([avg + q11, ls_i + q10, ls_j + q01])
    return lse([l1[t - 1, t - 1], l2[t - 1, t - 1]])


def sakoe_chiba_mask(t, band):
    """Boolean (T, T) corridor mask |i - j| <= band."""
    i = np.arange(t)[:, None]
    j = np.arange(t)[None, :]
    return np.abs(i - j) <= band
