"""Weighted, masked DTW as an anti-diagonal wavefront Pallas kernel.

The DP recurrence (paper Eq. 4 generalized with SP-DTW cell weights,
Algorithm 1):

    D(i, j) = w(i, j) * (x_i - y_j)^2  +  min(D(i-1, j), D(i-1, j-1), D(i, j-1))

is evaluated along anti-diagonals ``k = i + j``.  Cells on diagonal ``k``
depend only on diagonals ``k-1`` and ``k-2``, so the kernel carries two
``(B_tile, T)`` buffers in VMEM and never materializes the ``T x T`` DP
matrix — this is the TPU-shaped formulation of the paper's CPU algorithm
(DESIGN.md §Hardware-Adaptation).

Sparsified-out cells arrive as weights ``>= BIG_THRESH`` in the packed
weight plane; they contribute an additive ``BIG`` so no admissible path
crosses them, mirroring the Max_Float initialization of Algorithm 1.

The weight plane is shared across the batch (one plane per
(dataset, measure-variant), computed once by the Rust coordinator), while
``x`` and ``y`` carry the batched pairs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import BIG, BIG_THRESH


def _shift_right(d, fill):
    """d[i] -> d[i-1] with ``fill`` entering at i = 0 (lane shift on TPU)."""
    return jnp.concatenate([jnp.full_like(d[:, :1], fill), d[:, :-1]], axis=1)


def _dtw_kernel(x_ref, y_ref, w_ref, o_ref):
    x = x_ref[...]  # (bb, T)
    y = y_ref[...]  # (bb, T)
    w = w_ref[...]  # (2T-1, T) packed per anti-diagonal
    bb, t = x.shape
    dtype = x.dtype
    big = jnp.asarray(BIG, dtype)
    big_thresh = jnp.asarray(BIG_THRESH, dtype)

    # y[k - i] for all i on diagonal k is a contiguous window of reversed y:
    # with yrp = pad(flip(y), T on both sides), window_k[i] = yrp[2T-1-k+i].
    yrp = jnp.concatenate(
        [jnp.zeros((bb, t), dtype), jnp.flip(y, axis=1), jnp.zeros((bb, t), dtype)],
        axis=1,
    )
    idx = jnp.arange(t)

    def cell_cost(k, dmin):
        """w(i, k-i) * (x_i - y_{k-i})^2 + dmin, BIG-masked, for all i."""
        win = jax.lax.dynamic_slice(yrp, (0, 2 * t - 1 - k), (bb, t))
        cost = (x - win) ** 2
        wk = jax.lax.dynamic_slice(w, (k, 0), (1, t))[0]  # (T,)
        masked = wk >= big_thresh
        local = jnp.where(masked[None, :], big, cost * wk[None, :])
        valid = (k - idx >= 0) & (k - idx <= t - 1)
        return jnp.where(valid[None, :], local + dmin, big)

    # Diagonal 0: single cell (0, 0) with no predecessor.
    d0 = cell_cost(0, jnp.where((idx == 0)[None, :], 0.0, big).astype(dtype))
    dm1 = jnp.full((bb, t), big, dtype)

    def body(k, carry):
        dprev2, dprev1 = carry
        # Predecessors of (i, k-i): (i, k-1-i) = dprev1[i],
        # (i-1, k-i) = dprev1[i-1], (i-1, k-1-i) = dprev2[i-1].
        dmin = jnp.minimum(dprev1, _shift_right(dprev1, big))
        dmin = jnp.minimum(dmin, _shift_right(dprev2, big))
        return (dprev1, cell_cost(k, dmin))

    _, dlast = jax.lax.fori_loop(1, 2 * t - 1, body, (dm1, d0))
    o_ref[...] = dlast[:, t - 1]


@functools.partial(jax.jit, static_argnames=("block_b",))
def dtw_wavefront(x, y, wdiag, *, block_b=None):
    """Batched weighted masked DTW.

    Args:
      x, y:   ``(B, T)`` batched series pairs (same dtype).
      wdiag:  ``(2T-1, T)`` weight plane packed per anti-diagonal
              (``pack_diagonals``); entries ``>= BIG_THRESH`` are
              sparsified-out cells.
      block_b: batch tile size (must divide B); defaults to B.

    Returns:
      ``(B,)`` DTW values.  A value ``>= BIG_THRESH`` means no admissible
      path exists under the mask.
    """
    b, t = x.shape
    assert y.shape == (b, t), (x.shape, y.shape)
    assert wdiag.shape == (2 * t - 1, t), wdiag.shape
    bb = block_b or b
    assert b % bb == 0, (b, bb)
    grid = (b // bb,)
    return pl.pallas_call(
        _dtw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, t), lambda i: (i, 0)),
            pl.BlockSpec((bb, t), lambda i: (i, 0)),
            pl.BlockSpec((2 * t - 1, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, wdiag)
