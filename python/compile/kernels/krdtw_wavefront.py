"""Log-domain K_rdtw recurrence as an anti-diagonal wavefront Pallas kernel.

Implements the recursion of the paper's Algorithm 2 (the Marteau-Gibet
recursive edit-distance kernel, Eq. 6-7) over an arbitrary admissible cell
set P (binary mask plane): K_rdtw, K_rdtw_sc and SP-K_rdtw are all this
kernel with different masks.

Plain-domain products of ``kappa/3 < 1`` underflow even f64 beyond
T ~ 150, so the whole DP runs in log domain:

    lK1(i,j) = log kappa(x_i, y_j) - log 3
               + logsumexp(lK1(i-1,j-1), lK1(i-1,j), lK1(i,j-1))
    lK2(i,j) = -log 3 + logsumexp(
                 log((kappa_ii + kappa_jj) / 2) + lK2(i-1,j-1),
                 lK2(i-1,j) + log kappa_ii,
                 lK2(i,j-1) + log kappa_jj)
    result   = logsumexp(lK1(T-1,T-1), lK2(T-1,T-1))

with ``kappa(a, b) = exp(-nu * (a - b)^2)``, ``kappa_ii = kappa(x_i, y_i)``
and ``kappa_jj = kappa(x_j, y_j)``.  Cells outside P (or outside the grid)
hold NEG, the log-domain zero, which reproduces Algorithm 2's semantics of
never visiting them: the boundary recursions of lines 10-19 are exactly the
general recursion with zero (NEG) out-of-grid neighbors.

The kernel returns ``log(K1 + K2)``; the Rust side classifies with the
normalized kernel ``exp(lK(x,y) - (lK(x,x) + lK(y,y)) / 2)``, which is
exactly the usual cosine-normalized Gram matrix computed stably.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import NEG

_NEG_THRESH = -1.0e29


def _shift_right(d, fill):
    return jnp.concatenate([jnp.full_like(d[:, :1], fill), d[:, :-1]], axis=1)


def _lse3(a, b, c):
    """Elementwise logsumexp over three stacked operands, NEG-safe."""
    m = jnp.maximum(jnp.maximum(a, b), c)
    msafe = jnp.where(m <= _NEG_THRESH, 0.0, m)
    s = (
        jnp.exp(jnp.maximum(a - msafe, _NEG_THRESH))
        + jnp.exp(jnp.maximum(b - msafe, _NEG_THRESH))
        + jnp.exp(jnp.maximum(c - msafe, _NEG_THRESH))
    )
    out = msafe + jnp.log(s)
    return jnp.where(m <= _NEG_THRESH, NEG, out)


def _krdtw_kernel(x_ref, y_ref, m_ref, nu_ref, o_ref):
    x = x_ref[...]  # (bb, T)
    y = y_ref[...]
    mask = m_ref[...]  # (2T-1, T), 1.0 = admissible cell
    nu = nu_ref[0]
    bb, t = x.shape
    dtype = x.dtype
    neg = jnp.asarray(NEG, dtype)
    log3 = jnp.log(jnp.asarray(3.0, dtype))
    idx = jnp.arange(t)

    # Window machinery for j = k - i terms (see dtw_wavefront).
    def pad_rev(v):
        return jnp.concatenate(
            [jnp.zeros((bb, t), dtype), jnp.flip(v, axis=1), jnp.zeros((bb, t), dtype)],
            axis=1,
        )

    yrp = pad_rev(y)
    # Same-index local log-kernel ls[i] = log kappa(x_i, y_i) = -nu (x_i-y_i)^2
    ls = -nu * (x - y) ** 2  # (bb, T)
    lsrp = pad_rev(ls)

    def diag_parts(k):
        """Per-diagonal gathers: lk(i, k-i), ls_i, ls_j, validity, mask."""
        win_y = jax.lax.dynamic_slice(yrp, (0, 2 * t - 1 - k), (bb, t))
        lk = -nu * (x - win_y) ** 2  # log kappa(x_i, y_{k-i})
        ls_j = jax.lax.dynamic_slice(lsrp, (0, 2 * t - 1 - k), (bb, t))
        mk = jax.lax.dynamic_slice(mask, (k, 0), (1, t))[0]  # (T,)
        valid = (k - idx >= 0) & (k - idx <= t - 1)
        keep = valid[None, :] & (mk > 0.5)[None, :]
        return lk, ls, ls_j, keep

    # Diagonal 0: K1(0,0) = K2(0,0) = kappa(x_0, y_0) on admissible grids.
    lk0, ls_i0, _, keep0 = diag_parts(0)
    first = (idx == 0)[None, :]
    l1_0 = jnp.where(first & keep0, lk0, neg)
    l2_0 = jnp.where(first & keep0, ls_i0, neg)
    carry0 = (
        jnp.full((bb, t), neg, dtype),  # lK1 diag k-2
        l1_0,  # lK1 diag k-1
        jnp.full((bb, t), neg, dtype),  # lK2 diag k-2
        l2_0,  # lK2 diag k-1
    )

    def body(k, carry):
        l1p2, l1p1, l2p2, l2p1 = carry
        lk, ls_i, ls_j, keep = diag_parts(k)
        # K1: local kernel times the 3-neighbor sum.
        n11 = _shift_right(l1p2, neg)  # (i-1, j-1)
        n10 = _shift_right(l1p1, neg)  # (i-1, j)
        n01 = l1p1  # (i, j-1)
        l1 = lk - log3 + _lse3(n11, n10, n01)
        # K2: diagonal term averages the two same-index kernels.
        k_ii = jnp.exp(ls_i)
        k_jj = jnp.exp(ls_j)
        avg = jnp.log(jnp.maximum((k_ii + k_jj) * 0.5, 1e-300))
        t11 = avg + _shift_right(l2p2, neg)
        t10 = ls_i + _shift_right(l2p1, neg)
        t01 = ls_j + l2p1
        l2 = -log3 + _lse3(t11, t10, t01)
        l1 = jnp.where(keep, l1, neg)
        l2 = jnp.where(keep, l2, neg)
        return (l1p1, l1, l2p1, l2)

    _, l1last, _, l2last = jax.lax.fori_loop(1, 2 * t - 1, body, carry0)
    a = l1last[:, t - 1]
    b = l2last[:, t - 1]
    m = jnp.maximum(a, b)
    msafe = jnp.where(m <= _NEG_THRESH, 0.0, m)
    s = jnp.exp(jnp.maximum(a - msafe, _NEG_THRESH)) + jnp.exp(
        jnp.maximum(b - msafe, _NEG_THRESH)
    )
    o_ref[...] = jnp.where(m <= _NEG_THRESH, neg, msafe + jnp.log(s))


@functools.partial(jax.jit, static_argnames=("block_b",))
def krdtw_wavefront(x, y, mdiag, nu, *, block_b=None):
    """Batched log-domain K_rdtw over an admissible cell mask.

    Args:
      x, y:   ``(B, T)`` batched series pairs (f64 recommended).
      mdiag:  ``(2T-1, T)`` binary mask plane packed per anti-diagonal
              (1.0 = cell in P, 0.0 = sparsified out / out of grid).
      nu:     ``(1,)`` local-kernel bandwidth (kappa = exp(-nu d^2)).
      block_b: batch tile size (must divide B); defaults to B.

    Returns:
      ``(B,)`` values of ``log(K1 + K2)``; NEG if the mask admits no path.
    """
    b, t = x.shape
    assert y.shape == (b, t), (x.shape, y.shape)
    assert mdiag.shape == (2 * t - 1, t), mdiag.shape
    nu = jnp.asarray(nu, x.dtype).reshape((1,))
    bb = block_b or b
    assert b % bb == 0, (b, bb)
    grid = (b // bb,)
    return pl.pallas_call(
        _krdtw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, t), lambda i: (i, 0)),
            pl.BlockSpec((bb, t), lambda i: (i, 0)),
            pl.BlockSpec((2 * t - 1, t), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, mdiag, nu)
