"""Layer-1 Pallas kernels for SP-DTW / SP-Krdtw.

Two wavefront (anti-diagonal) dynamic-programming kernels:

- ``dtw_wavefront``   : weighted, masked DTW (covers DTW / DTW_sc / SP-DTW
                        through the weight plane).
- ``krdtw_wavefront`` : log-domain K_rdtw recurrence (covers K_rdtw,
                        K_rdtw_sc and SP-K_rdtw through the binary mask
                        plane).

Both kernels consume the weight/mask matrix *packed per anti-diagonal*
(shape ``(2T-1, T)``) so the DP inner loop performs no gathers; see
``pack_diagonals``.  All kernels are lowered with ``interpret=True`` —
the CPU PJRT client cannot execute Mosaic custom-calls.
"""

from .common import BIG, BIG_THRESH, NEG, pack_diagonals
from .dtw_wavefront import dtw_wavefront
from .krdtw_wavefront import krdtw_wavefront

__all__ = [
    "BIG",
    "BIG_THRESH",
    "NEG",
    "pack_diagonals",
    "dtw_wavefront",
    "krdtw_wavefront",
]
