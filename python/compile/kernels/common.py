"""Shared constants and helpers for the wavefront kernels.

The sentinel conventions here MUST match ``rust/src/sparse/loc.rs``
(`pack_weight_plane`) — the Rust coordinator packs the same planes at
request time and feeds them to the AOT-compiled executables.
"""

import numpy as np

# Additive "unreachable" sentinel for the min-plus DTW recurrence.
# f32-safe: worst-case accumulation BIG * 2T stays < f32::MAX for T <= 4096.
BIG = 1.0e30
# Any weight >= BIG_THRESH marks a sparsified-out cell.
BIG_THRESH = 1.0e29
# Log-domain "zero" (log of 0) for the K_rdtw recurrence.
NEG = -1.0e30


def pack_diagonals(w, sentinel):
    """Pack a (T, T) cell matrix into per-anti-diagonal rows (2T-1, T).

    Row ``k`` holds the cells of anti-diagonal ``i + j == k`` indexed by
    ``i``: ``out[k, i] = w[i, k - i]`` when ``0 <= k - i < T``, else
    ``sentinel``.  Build-time / test helper; the Rust runtime implements
    the identical packing natively.
    """
    w = np.asarray(w)
    t = w.shape[0]
    assert w.shape == (t, t), "weight matrix must be square"
    out = np.full((2 * t - 1, t), sentinel, dtype=w.dtype)
    for k in range(2 * t - 1):
        lo = max(0, k - t + 1)
        hi = min(k, t - 1)
        i = np.arange(lo, hi + 1)
        out[k, i] = w[i, k - i]
    return out
