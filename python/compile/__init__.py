"""Build-time compile path: JAX/Pallas authoring + AOT lowering to HLO text.

Nothing in this package is imported at runtime — the Rust binary only
consumes the HLO text artifacts produced by ``python -m compile.aot``.
"""
