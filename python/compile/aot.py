"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits one artifact per (kernel, T, B) bucket plus ``manifest.json``
describing every artifact (kernel name, shapes, dtypes, argument order)
for ``rust/src/runtime/artifact.rs``.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # K_rdtw artifacts are f64

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Length buckets: chosen to cover the datasets the serving demo and the
# runtime integration tests exercise (SyntheticControl T=60, CBF T=128,
# Gun-Point T=150) plus a longer perf bucket.  Unknown lengths route to
# the native backend (coordinator/router.rs fallback).
DTW_BUCKETS = [(32, 60), (32, 128), (32, 150), (16, 512)]
KRDTW_BUCKETS = [(32, 60), (32, 128), (32, 150)]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, spec):
    return jax.jit(fn).lower(*spec)


def build(out_dir: str) -> dict:
    entries = []
    for b, t in DTW_BUCKETS:
        name = f"dtw_T{t}_B{b}"
        text = to_hlo_text(lower_entry(model.dtw_batch, model.dtw_batch_spec(b, t)))
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "kernel": "dtw",
                "name": name,
                "file": name + ".hlo.txt",
                "batch": b,
                "length": t,
                "dtype": "f32",
                "args": ["x[B,T]", "y[B,T]", "wdiag[2T-1,T]"],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    for b, t in KRDTW_BUCKETS:
        name = f"krdtw_T{t}_B{b}"
        text = to_hlo_text(
            lower_entry(model.krdtw_batch, model.krdtw_batch_spec(b, t))
        )
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "kernel": "krdtw",
                "name": name,
                "file": name + ".hlo.txt",
                "batch": b,
                "length": t,
                "dtype": "f64",
                "args": ["x[B,T]", "y[B,T]", "mdiag[2T-1,T]", "nu[1]"],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    return {"version": 1, "entries": entries}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = build(args.out_dir)
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['entries'])} artifacts)")


if __name__ == "__main__":
    main()
