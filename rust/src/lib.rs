//! # spdtw — Sparsified-Paths search space DTW
//!
//! Production-quality reproduction of *"Sparsification of the Alignment
//! Path Search Space in Dynamic Time Warping"* (Soheily-Khah & Marteau,
//! 2017): the SP-DTW and SP-K_rdtw (dis)similarity measures, every
//! baseline the paper evaluates (CORR, DACO, Euclidean/L_p, DTW,
//! Sakoe-Chiba DTW, K_rdtw, K_ga), the occupancy-grid sparsification
//! pipeline, 1-NN and SVM classification, Wilcoxon significance testing,
//! and a batched distance-computation coordinator that can execute the
//! DP hot loop either natively or through AOT-compiled XLA executables
//! (JAX/Pallas → HLO text → PJRT; see `runtime`).
//!
//! ## Layout
//!
//! | module        | role |
//! |---------------|------|
//! | [`data`]      | time-series types, z-normalization, UCR IO, the 30-dataset synthetic archive |
//! | [`measures`]  | all (dis)similarity measures + the zero-allocation [`measures::workspace`] arena |
//! | [`sparse`]    | occupancy-grid learning, thresholding, LOC sparse format |
//! | [`classify`]  | 1-NN and SMO SVM (one-vs-one) |
//! | [`stats`]     | Wilcoxon signed-rank test, rank aggregation |
//! | [`tuning`]    | LOO / k-fold grid search for θ, ν, γ, band width |
//! | [`search`]    | cascaded lower-bound + early-abandoning k-NN engine |
//! | [`stream`]    | online subsequence k-NN: sliding envelopes, RWS pre-filter, stream monitor |
//! | [`pool`]      | thread-pool substrate (no rayon in the vendored set) |
//! | [`runtime`]   | PJRT client, artifact manifest, executable cache |
//! | [`coordinator`]| router + length-bucket batcher + workers + metrics + TCP server |
//! | [`shard`]     | multi-node serving: exact shard fan-out, merge, shard manifest |
//! | [`experiments`]| regenerates every table and figure of the paper |
//! | [`util`]      | RNG, JSON, math/stat helpers, bench + property harnesses |
//! | [`viz`]       | PGM/PPM + ASCII heatmaps (Figs. 5–8) |
//!
//! ## Quickstart
//!
//! ```no_run
//! use spdtw::data::synthetic;
//! use spdtw::measures::{Measure, dtw::Dtw};
//! use spdtw::sparse::learn::learn_occupancy_grid;
//! use spdtw::measures::spdtw::SpDtw;
//!
//! let ds = synthetic::generate("CBF", 42).unwrap();
//! let grid = learn_occupancy_grid(&ds.train, 1);
//! let loc = grid.threshold(0.5).to_loc(1.0);
//! let sp = SpDtw::new(loc);
//! let d = sp.dist(&ds.train.series[0], &ds.train.series[1]);
//! assert!(d.value >= 0.0);
//! ```

// The DP kernels are deliberately written index-style: the recurrences
// read and write several parallel arrays at related offsets, and the
// iterator chains clippy prefers hide exactly the cell dependencies the
// §Perf notes reason about.  `inherent_to_string` covers the in-tree
// JSON value's serializer (no serde/Display split in the vendored set).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::inherent_to_string)]
// Every `unsafe` operation must sit in its own `unsafe` block with a
// `// SAFETY:` comment (the latter enforced by `cargo xtask lint`), even
// inside `unsafe fn` — so each block's proof obligation stays local.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod classify;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod measures;
pub mod pool;
pub mod runtime;
pub mod search;
pub mod shard;
pub mod sparse;
pub mod stats;
pub mod stream;
pub mod tuning;
pub mod util;
pub mod viz;

pub use error::{Error, Result};
