//! TCP front-end for sharded serving: speaks the same one-JSON-object
//! per line protocol as [`crate::coordinator::Server`] (v1 bare ops and
//! the v2 envelope), but serves `register_index` / `search` /
//! `batch_search` by fanning out to the shard fleet through a
//! [`ShardCoordinator`] and merging exactly.
//!
//! Reply shapes match the single-server protocol where the ops overlap
//! (`neighbors` entries carry `dist`/`label`/`idx`, with `idx` in
//! *global* index space), plus fan-out fields:
//! `shards_ok`/`shards_total` on every search reply, and on the typed
//! `unavailable` error reply when a shard stays down.
//!
//! Fault-tolerance surface (all typed, never silent):
//!
//! - `deadline_ms` on any request bounds it end to end; the remaining
//!   budget is forwarded to every shard leg and exhaustion returns the
//!   typed `deadline_exceeded` error code.
//! - `allow_partial: true` on `search`/`batch_search` opts in to the
//!   exact merge over responsive shards when some are down; such
//!   replies carry a `partial: {shards_ok, shards_total, missing}`
//!   block naming the absent shards.  The default stays
//!   all-or-typed-error.
//! - `info` reports each link's circuit-breaker state alongside
//!   liveness; `metrics` carries the full breaker/probe/partial
//!   counters.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use super::coordinator::{QueryOpts, ShardCoordinator, ShardRegistration, ShardedSearch};
use super::fault::FaultHook;
use crate::coordinator::server::{
    attach_id, check_finite, error_reply, parse_cascade, parse_deadline,
};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// A running shard front; dropping stops accepting (existing
/// connections finish their in-flight line), mirroring
/// [`crate::coordinator::Server`].
pub struct FrontServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl FrontServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    /// Generic over the coordinator's fault hook so chaos fronts serve
    /// through the exact same code path as production ones.
    pub fn start<F: FaultHook>(
        shards: Arc<ShardCoordinator<F>>,
        addr: &str,
    ) -> Result<FrontServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = thread::Builder::new()
            .name("spdtw-front".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let sc = Arc::clone(&shards);
                            let stop3 = Arc::clone(&stop2);
                            thread::spawn(move || {
                                let _ = handle_conn(stream, &sc, &stop3);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(FrontServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Whether the stop flag has fired (the TCP `shutdown` op or
    /// [`Self::stop`]) — lets a CLI serve loop exit cleanly.
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }
}

impl Drop for FrontServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn<F: FaultHook>(
    stream: TcpStream,
    sc: &ShardCoordinator<F>,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = dispatch_front(&line, sc, stop);
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

/// Parse one request line and serve it — same envelope rules as the
/// single-server dispatch (`proto` 1/2, `id` echo, typed error codes).
pub(crate) fn dispatch_front<F: FaultHook>(
    line: &str,
    sc: &ShardCoordinator<F>,
    stop: &AtomicBool,
) -> Json {
    let req = match Json::parse(line) {
        Ok(r) => r,
        Err(e) => return error_reply(&e, None),
    };
    let id = req.get("id").cloned();
    match req.get("proto").map(|p| (p.as_usize(), p)) {
        None | Some((Some(1), _)) | Some((Some(2), _)) => {}
        Some((_, p)) => {
            let shown = p.to_string();
            let mut reply = Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::str(format!(
                        "unsupported protocol version {shown} (this server speaks 1 and 2)"
                    )),
                ),
                ("code", Json::str("unsupported_proto")),
            ]);
            attach_id(&mut reply, id.as_ref());
            return reply;
        }
    }
    let mut reply = match handle_front_op(&req, sc, stop) {
        Ok(json) => json,
        Err(e) => return error_reply(&e, id.as_ref()),
    };
    attach_id(&mut reply, id.as_ref());
    reply
}

/// Parse `field` as an array of equal-typed numeric rows.
fn parse_rows(req: &Json, field: &str) -> Result<Vec<Vec<f64>>> {
    let arr = req.req_arr(field)?;
    let mut rows = Vec::with_capacity(arr.len());
    for row in arr {
        let vals: Option<Vec<f64>> = row
            .as_arr()
            .map(|r| r.iter().map(Json::as_f64).collect())
            .unwrap_or(None);
        let vals = vals
            .ok_or_else(|| Error::config(format!("'{field}' must be arrays of numbers")))?;
        check_finite(&vals, field)?;
        rows.push(vals);
    }
    Ok(rows)
}

fn parse_values(req: &Json, field: &str) -> Result<Vec<f64>> {
    let arr = req.req_arr(field)?;
    let values: Option<Vec<f64>> = arr.iter().map(Json::as_f64).collect();
    let values =
        values.ok_or_else(|| Error::config(format!("'{field}' must be numbers")))?;
    check_finite(&values, field)?;
    Ok(values)
}

/// The `index` parameter: a front key (number) or a registered name.
fn front_index_key<F: FaultHook>(sc: &ShardCoordinator<F>, req: &Json) -> Result<u64> {
    match req.get("index") {
        Some(Json::Num(_)) => Ok(req.req_usize("index")? as u64),
        Some(Json::Str(name)) => sc.key_by_name(name).ok_or(Error::NotFound {
            kind: "index",
            name: name.clone(),
        }),
        _ => Err(Error::config("missing 'index' (a key or a registered name)")),
    }
}

/// Validated cascade selector, forwarded verbatim to the shards.
fn cascade_str(req: &Json) -> Result<Option<&str>> {
    parse_cascade(req)?; // fail fast on the front, same error as a shard
    Ok(req.get("cascade").and_then(Json::as_str))
}

/// Strict opt-in flag: anything but a boolean is a `bad_request` (a
/// truthy-string accident must never silently enable degradation).
fn parse_allow_partial(req: &Json) -> Result<bool> {
    match req.get("allow_partial") {
        None => Ok(false),
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => Err(Error::config("'allow_partial' must be a boolean")),
    }
}

/// The typed degradation flag on an opt-in partial reply.
fn partial_block(out: &ShardedSearch) -> Json {
    Json::obj(vec![
        ("shards_ok", Json::num(out.shards_ok as f64)),
        ("shards_total", Json::num(out.shards_total as f64)),
        (
            "missing",
            Json::arr(out.missing.iter().map(|&s| Json::num(s as f64))),
        ),
    ])
}

fn search_reply_fields(out: &ShardedSearch) -> Vec<(&'static str, Json)> {
    let neighbors = Json::arr(out.neighbors.iter().map(|n| {
        Json::obj(vec![
            ("dist", Json::num(n.dist)),
            ("label", Json::num(n.label as f64)),
            ("idx", Json::num(n.global_idx as f64)),
        ])
    }));
    vec![
        ("neighbors", neighbors),
        ("shards_ok", Json::num(out.shards_ok as f64)),
        ("shards_total", Json::num(out.shards_total as f64)),
        ("merge_candidates", Json::num(out.merge_candidates as f64)),
    ]
}

fn handle_front_op<F: FaultHook>(
    req: &Json,
    sc: &ShardCoordinator<F>,
    stop: &AtomicBool,
) -> Result<Json> {
    let op = req.req_str("op")?;
    // Pre-dispatch deadline check: a request that arrives with its
    // budget already drained is rejected before any fan-out work.
    let deadline = parse_deadline(req)?;
    if let Some(d) = deadline {
        if d.expired() {
            return Err(d.error());
        }
    }
    match op {
        "ping" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("pong", Json::Bool(true)),
            ("role", Json::str("front")),
        ])),
        "info" => {
            let up = sc.links_up();
            let breakers = sc.breaker_states();
            let shards = Json::arr(sc.addrs().iter().zip(up.iter().zip(&breakers)).map(
                |(addr, (up, breaker))| {
                    Json::obj(vec![
                        ("addr", Json::str(addr.clone())),
                        ("up", Json::Bool(*up)),
                        ("breaker", Json::str(*breaker)),
                    ])
                },
            ));
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("role", Json::str("front")),
                ("shards_total", Json::num(sc.shards_total() as f64)),
                ("shards", shards),
            ]))
        }
        "register_index" => {
            let name = req.get("name").and_then(Json::as_str).map(str::to_string);
            let series = parse_rows(req, "series")?;
            let labels: Vec<usize> = match req.get("labels").and_then(Json::as_arr) {
                Some(ls) => {
                    let parsed: Option<Vec<usize>> = ls.iter().map(Json::as_usize).collect();
                    parsed.ok_or_else(|| {
                        Error::config("'labels' must be non-negative integers")
                    })?
                }
                None => vec![0; series.len()],
            };
            let band = req.get("band").and_then(Json::as_usize);
            let measure = req.get("measure").cloned();
            let si = sc.register(&ShardRegistration {
                name,
                series,
                labels,
                band,
                measure,
            })?;
            let hashes = Json::arr(si.content_hashes.iter().map(|h| match h {
                Some(h) => Json::str(h.clone()),
                None => Json::Null,
            }));
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("index", Json::num(si.key as f64)),
                ("t", Json::num(si.t as f64)),
                ("count", Json::num(si.total as f64)),
                ("shards_total", Json::num(sc.shards_total() as f64)),
                (
                    "per_shard",
                    Json::arr(si.per_shard_count.iter().map(|&c| Json::num(c as f64))),
                ),
                ("content_hashes", hashes),
            ]))
        }
        "search" => {
            let key = front_index_key(sc, req)?;
            let k = req.get("k").and_then(Json::as_usize).unwrap_or(1);
            let x = parse_values(req, "x")?;
            let cascade = cascade_str(req)?;
            let opts = QueryOpts {
                allow_partial: parse_allow_partial(req)?,
                deadline,
            };
            let out = sc.search_opts(key, &x, k, cascade, opts)?;
            let mut fields = vec![("ok", Json::Bool(true))];
            fields.extend(search_reply_fields(&out));
            if !out.missing.is_empty() {
                fields.push(("partial", partial_block(&out)));
            }
            Ok(Json::obj(fields))
        }
        "batch_search" => {
            let key = front_index_key(sc, req)?;
            let k = req.get("k").and_then(Json::as_usize).unwrap_or(1);
            let xs = parse_rows(req, "xs")?;
            let cascade = cascade_str(req)?;
            let opts = QueryOpts {
                allow_partial: parse_allow_partial(req)?,
                deadline,
            };
            let outs = sc.batch_search_opts(key, &xs, k, cascade, opts)?;
            let shards_ok = outs.iter().map(|o| o.shards_ok).min().unwrap_or(0);
            let results = Json::arr(
                outs.iter()
                    .map(|out| Json::obj(search_reply_fields(out))),
            );
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("queries", Json::num(outs.len() as f64)),
                ("results", results),
                ("shards_ok", Json::num(shards_ok as f64)),
                ("shards_total", Json::num(sc.shards_total() as f64)),
            ];
            // the whole batch shares one leg per shard, so one missing
            // set flags every query's degradation at the top level too
            if let Some(out) = outs.iter().find(|o| !o.missing.is_empty()) {
                fields.push(("partial", partial_block(out)));
            }
            Ok(Json::obj(fields))
        }
        "metrics" => {
            let mut reply = sc.metrics().to_json();
            if let Json::Obj(m) = &mut reply {
                m.insert("ok".to_string(), Json::Bool(true));
            }
            Ok(reply)
        }
        "shutdown" => {
            // raise the coordinator's stop flag FIRST so in-flight
            // reconnect backoffs unblock before the accept loop stops
            sc.begin_shutdown();
            stop.store(true, Ordering::Relaxed);
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(Error::Unknown {
            kind: "op",
            name: other.to_string(),
        }),
    }
}
