//! The front `ShardCoordinator`: persistent multiplexed TCP links to N
//! shard servers, exact fan-out/merge, health metrics, and the
//! fault-tolerance layer (circuit breakers, health probes, deadline
//! propagation, opt-in partial results).
//!
//! Each link is one `TcpStream` split into a write half (behind a
//! mutex, shared by every in-flight request) and a dedicated reader
//! thread owning the `BufReader` half.  Requests carry monotonically
//! increasing v2 `id`s; the reader routes each reply line to the
//! waiting caller's channel by its echoed `id`, so any number of
//! requests can be in flight per connection (multiplexing — the front's
//! connection handler threads share the same N links).
//!
//! Failure model: a dead link fails all of its in-flight requests
//! immediately (the reader drops their reply senders on EOF).  The next
//! fan-out retries the shard once after a capped-backoff reconnect;
//! after `breaker_threshold` consecutive failures the link's circuit
//! breaker **opens** and requests fail fast (typed `unavailable`)
//! without paying inline connect backoff — a background probe thread
//! redials open links (half-open state) and closes the breaker once the
//! shard answers `info` with the right topology again.  If a query
//! cannot get exact results from every shard it returns
//! [`Error::ShardUnavailable`](crate::error::Error::ShardUnavailable) —
//! unless the caller opted in with [`QueryOpts::allow_partial`], in
//! which case the exact bounded-heap merge over the *responsive* shards
//! is returned with the missing shards named
//! ([`ShardedSearch::missing`]), never a silently truncated neighbor
//! list.  Requests carrying a [`Deadline`] get remaining-budget-aware
//! per-leg timeouts and the typed `deadline_exceeded` error once the
//! budget drains.
//!
//! Everything here is generic over [`FaultHook`] so the deterministic
//! chaos harness ([`ActiveFaults`](super::fault::ActiveFaults)) can
//! inject connect-class faults at the dial boundary; production code is
//! monomorphized with [`NoFaults`], whose inlined no-op hooks erase the
//! seam entirely.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Mutex, Weak};
use std::thread;
use std::time::{Duration, Instant};

use super::fault::{ConnectFault, FaultHook, NoFaults};
use super::layout::{ShardEntry, ShardLayout, ShardManifest};
use super::{merge_topk, ShardNeighbor};
use crate::coordinator::request::Deadline;
use crate::coordinator::validate_index_name;
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Connection/retry policy for the front's shard links.
#[derive(Clone, Debug)]
pub struct ShardClientConfig {
    /// Shard server addresses, one per shard, in shard-id order.
    pub addrs: Vec<String>,
    /// Dial attempts per (re)connect, with doubling backoff.
    pub connect_attempts: usize,
    /// First backoff delay.
    pub backoff_base_ms: u64,
    /// Backoff ceiling (capped exponential).
    pub backoff_cap_ms: u64,
    /// Per-request reply timeout ceiling; a request [`Deadline`] lowers
    /// the effective per-leg timeout to its remaining budget.
    pub call_timeout_ms: u64,
    /// Consecutive per-link failures before the circuit breaker opens
    /// and requests fail fast instead of paying inline reconnects.
    pub breaker_threshold: u32,
    /// Background health-probe cadence for open breakers (0 disables
    /// the probe thread; then only an explicit reconnect, or a request
    /// arriving while the breaker is half-open, can close a breaker).
    pub probe_interval_ms: u64,
    /// Directory for the shard manifest (per-shard content hashes);
    /// `None` disables manifest persistence.
    pub store: Option<PathBuf>,
}

impl Default for ShardClientConfig {
    fn default() -> Self {
        ShardClientConfig {
            addrs: Vec::new(),
            connect_attempts: 4,
            backoff_base_ms: 50,
            backoff_cap_ms: 800,
            call_timeout_ms: 30_000,
            breaker_threshold: 3,
            probe_interval_ms: 500,
            store: None,
        }
    }
}

impl ShardClientConfig {
    pub fn for_addrs(addrs: Vec<String>) -> Self {
        ShardClientConfig {
            addrs,
            ..Default::default()
        }
    }
}

/// Per-query options for the sharded search paths.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueryOpts {
    /// Accept the exact merge over *responsive* shards when some shards
    /// are down, instead of the all-or-typed-error default.  The reply
    /// names the missing shards; it is never a silent subset.
    pub allow_partial: bool,
    /// End-to-end budget; forwarded to every shard leg as the remaining
    /// budget at send time.
    pub deadline: Option<Deadline>,
}

impl QueryOpts {
    pub fn with_deadline(deadline: Option<Deadline>) -> Self {
        QueryOpts {
            allow_partial: false,
            deadline,
        }
    }
}

/// A request in flight on a link: the reply arrives on `rx` when the
/// reader thread routes the line with the matching id.
struct PendingCall {
    id: u64,
    rx: mpsc::Receiver<Json>,
    sent_at: Instant,
}

/// Mutable half of a link.  `pending` and `alive` are re-created per
/// connection so a dying reader only fails its own generation's
/// waiters, and `begin` can detect a dead reader before writing into
/// the socket.
struct LinkState {
    writer: Option<BufWriter<TcpStream>>,
    pending: Arc<Mutex<HashMap<u64, mpsc::Sender<Json>>>>,
    alive: Arc<AtomicBool>,
}

// Circuit-breaker states (per link).
const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// One persistent, multiplexed connection to a shard server.
struct ShardLink<F: FaultHook> {
    shard_id: usize,
    addr: String,
    next_id: AtomicU64,
    state: Mutex<LinkState>,
    connect_attempts: usize,
    backoff_base_ms: u64,
    backoff_cap_ms: u64,
    call_timeout: Duration,
    /// Circuit breaker: consecutive failures, state, and open count.
    consecutive_failures: AtomicU64,
    breaker: AtomicU8,
    breaker_opens: AtomicU64,
    breaker_threshold: u64,
    probes: AtomicU64,
    /// Shared shutdown flag: interrupts connect backoff sleeps so a
    /// front `shutdown` (or process stop) is never delayed by reconnect
    /// backoff against a dead shard.
    stop: Arc<AtomicBool>,
    faults: Arc<F>,
}

impl<F: FaultHook> ShardLink<F> {
    fn new(
        shard_id: usize,
        addr: &str,
        cfg: &ShardClientConfig,
        faults: Arc<F>,
        stop: Arc<AtomicBool>,
    ) -> ShardLink<F> {
        ShardLink {
            shard_id,
            addr: addr.to_string(),
            next_id: AtomicU64::new(1),
            state: Mutex::new(LinkState {
                writer: None,
                pending: Arc::new(Mutex::new(HashMap::new())),
                alive: Arc::new(AtomicBool::new(false)),
            }),
            connect_attempts: cfg.connect_attempts.max(1),
            backoff_base_ms: cfg.backoff_base_ms,
            backoff_cap_ms: cfg.backoff_cap_ms.max(cfg.backoff_base_ms),
            call_timeout: Duration::from_millis(cfg.call_timeout_ms),
            consecutive_failures: AtomicU64::new(0),
            breaker: AtomicU8::new(BREAKER_CLOSED),
            breaker_opens: AtomicU64::new(0),
            breaker_threshold: cfg.breaker_threshold.max(1) as u64,
            probes: AtomicU64::new(0),
            stop,
            faults,
        }
    }

    fn down_err(&self) -> Error {
        Error::coordinator(format!("shard {} ({}): link down", self.shard_id, self.addr))
    }

    fn fast_fail_err(&self) -> Error {
        Error::coordinator(format!(
            "shard {} ({}): breaker open (failing fast)",
            self.shard_id, self.addr
        ))
    }

    // --- circuit breaker ------------------------------------------------

    fn breaker_is_open(&self) -> bool {
        self.breaker.load(Ordering::Relaxed) == BREAKER_OPEN
    }

    fn breaker_state(&self) -> &'static str {
        match self.breaker.load(Ordering::Relaxed) {
            BREAKER_OPEN => "open",
            BREAKER_HALF_OPEN => "half_open",
            _ => "closed",
        }
    }

    /// A completed call: reset the failure streak, close the breaker.
    fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.breaker.store(BREAKER_CLOSED, Ordering::Relaxed);
    }

    /// A failed call (deadline-bounded timeouts are NOT failures — a
    /// tight budget says nothing about shard health).
    fn record_failure(&self) {
        let streak = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if streak >= self.breaker_threshold
            && self.breaker.swap(BREAKER_OPEN, Ordering::Relaxed) != BREAKER_OPEN
        {
            self.breaker_opens.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Probe entry: an open breaker moves to half-open for one trial.
    fn set_half_open(&self) {
        let _ = self.breaker.compare_exchange(
            BREAKER_OPEN,
            BREAKER_HALF_OPEN,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    /// Failed probe: half-open falls back to open.
    fn reopen(&self) {
        let _ = self.breaker.compare_exchange(
            BREAKER_HALF_OPEN,
            BREAKER_OPEN,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    // --- connection lifecycle -------------------------------------------

    /// Sleep in ≤20 ms slices, bailing out the moment the shared stop
    /// flag is set (the satellite fix: backoff never delays shutdown).
    fn sleep_interruptible(&self, total: Duration) -> Result<()> {
        let mut slept = Duration::ZERO;
        while slept < total {
            if self.stop.load(Ordering::Relaxed) {
                return Err(Error::coordinator(format!(
                    "shard {} ({}): shutting down",
                    self.shard_id, self.addr
                )));
            }
            let step = (total - slept).min(Duration::from_millis(20));
            thread::sleep(step);
            slept += step;
        }
        Ok(())
    }

    /// One dial, through the fault hook: an injected `Refuse` fails the
    /// attempt exactly as a closed port would.
    fn dial(&self) -> std::io::Result<TcpStream> {
        if self.faults.connect_fault(self.shard_id) == ConnectFault::Refuse {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionRefused,
                "injected refuse_connect",
            ));
        }
        TcpStream::connect(&self.addr)
    }

    /// Dial with capped exponential backoff (stop-interruptible), then
    /// install the stream and spawn a fresh reader thread for it.
    fn connect(&self) -> Result<()> {
        let mut delay = Duration::from_millis(self.backoff_base_ms);
        let cap = Duration::from_millis(self.backoff_cap_ms);
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..self.connect_attempts {
            if attempt > 0 {
                self.sleep_interruptible(delay)?;
                delay = (delay * 2).min(cap);
            }
            match self.dial() {
                Ok(stream) => return self.attach(stream),
                Err(e) => last = Some(e),
            }
        }
        Err(Error::coordinator(format!(
            "shard {} ({}): connect failed after {} attempts: {}",
            self.shard_id,
            self.addr,
            self.connect_attempts,
            last.map(|e| e.to_string()).unwrap_or_default()
        )))
    }

    /// Single dial with no backoff — the probe path (a probe must never
    /// block the probe thread for a full backoff ladder).
    fn connect_once(&self) -> Result<()> {
        match self.dial() {
            Ok(stream) => self.attach(stream),
            Err(e) => Err(Error::coordinator(format!(
                "shard {} ({}): probe dial failed: {e}",
                self.shard_id, self.addr
            ))),
        }
    }

    fn attach(&self, stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        let read_half = stream
            .try_clone()
            .map_err(|e| Error::coordinator(format!("shard {}: {e}", self.addr)))?;
        let pending: Arc<Mutex<HashMap<u64, mpsc::Sender<Json>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let alive = Arc::new(AtomicBool::new(true));
        {
            let mut st = self.state.lock().unwrap();
            st.writer = Some(BufWriter::new(stream));
            st.pending = Arc::clone(&pending);
            st.alive = Arc::clone(&alive);
        }
        let name = format!("spdtw-shard-link-{}", self.shard_id);
        thread::Builder::new()
            .name(name)
            .spawn(move || {
                let mut reader = BufReader::new(read_half);
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {
                            // A line that isn't JSON, or a parseable one
                            // with no id, means the stream is corrupt
                            // (garbled): kill the connection rather than
                            // leave its waiters hanging to their full
                            // timeouts on a broken framing.
                            let Ok(reply) = Json::parse(line.trim()) else {
                                break;
                            };
                            let Some(id) = reply.get("id").and_then(Json::as_f64) else {
                                break;
                            };
                            // An UNKNOWN id is normal: a deadline-bounded
                            // waiter that gave up already removed its
                            // sender, and the late reply just drains.
                            if let Some(tx) = pending.lock().unwrap().remove(&(id as u64)) {
                                let _ = tx.send(reply);
                            }
                        }
                    }
                }
                // EOF, read error or garble: mark the connection dead so
                // `begin` stops writing into it, then drop the senders to
                // fail every waiter of THIS generation immediately.
                alive.store(false, Ordering::Release);
                pending.lock().unwrap().clear();
            })
            .map_err(|e| Error::coordinator(format!("shard link thread: {e}")))?;
        Ok(())
    }

    fn is_up(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.writer.is_some() && st.alive.load(Ordering::Acquire)
    }

    /// Send `req` (id injected) without waiting for the reply.
    fn begin(&self, req: &Json) -> Result<PendingCall> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = req.clone();
        if let Json::Obj(m) = &mut req {
            m.insert("id".to_string(), Json::num(id as f64));
        }
        let line = req.to_string();
        let (tx, rx) = mpsc::channel();
        let mut st = self.state.lock().unwrap();
        if !st.alive.load(Ordering::Acquire) {
            // The reader died (EOF/garble) but nobody reconnected yet:
            // fail fast instead of writing into a dead socket and
            // waiting out the full reply timeout.
            st.writer = None;
        }
        if st.writer.is_none() {
            return Err(self.down_err());
        }
        st.pending.lock().unwrap().insert(id, tx);
        let wrote = {
            let writer = st.writer.as_mut().expect("writer checked above");
            writer
                .write_all(line.as_bytes())
                .and_then(|_| writer.write_all(b"\n"))
                .and_then(|_| writer.flush())
        };
        if let Err(e) = wrote {
            st.pending.lock().unwrap().remove(&id);
            st.writer = None; // mark the link dead for later callers
            return Err(Error::coordinator(format!(
                "shard {} ({}): write failed: {e}",
                self.shard_id, self.addr
            )));
        }
        Ok(PendingCall {
            id,
            rx,
            sent_at: Instant::now(),
        })
    }

    /// Wait for the reply to a [`begin`](Self::begin), bounded by the
    /// flat call timeout or the request deadline's remaining budget,
    /// whichever is smaller.
    fn finish(&self, call: PendingCall, deadline: Option<Deadline>) -> Result<(Json, Duration)> {
        let mut wait = self.call_timeout;
        let mut deadline_bound = false;
        if let Some(d) = deadline {
            let remaining = d.remaining();
            if remaining < wait {
                wait = remaining;
                deadline_bound = true;
            }
        }
        match call.rx.recv_timeout(wait) {
            Ok(reply) => Ok((reply, call.sent_at.elapsed())),
            Err(_) => {
                // Timeout, or the reader died and dropped our sender.
                let st = self.state.lock().unwrap();
                st.pending.lock().unwrap().remove(&call.id);
                if deadline_bound {
                    if let Some(d) = deadline {
                        if d.expired() {
                            return Err(d.error());
                        }
                    }
                }
                Err(Error::coordinator(format!(
                    "shard {} ({}): no reply (link lost or timed out)",
                    self.shard_id, self.addr
                )))
            }
        }
    }

    fn call(&self, req: &Json, deadline: Option<Deadline>) -> Result<(Json, Duration)> {
        self.finish(self.begin(req)?, deadline)
    }
}

/// Per-link health counters.
#[derive(Default)]
struct PerShardMetrics {
    calls: AtomicU64,
    errors: AtomicU64,
    reconnects: AtomicU64,
    latency_us_sum: AtomicU64,
    latency_us_max: AtomicU64,
}

/// Fan-out/merge health counters for the whole front.
#[derive(Default)]
struct ShardMetrics {
    per_shard: Vec<PerShardMetrics>,
    fanouts: AtomicU64,
    fanout_depth_sum: AtomicU64,
    inflight: AtomicU64,
    peak_inflight: AtomicU64,
    merges: AtomicU64,
    merge_candidates: AtomicU64,
    partial_failures: AtomicU64,
    partial_replies: AtomicU64,
    deadlines_exceeded: AtomicU64,
}

/// Point-in-time stats for one shard link.
#[derive(Clone, Debug)]
pub struct ShardLinkStats {
    pub addr: String,
    pub up: bool,
    /// Circuit-breaker state: `"closed"`, `"open"` or `"half_open"`.
    pub breaker: &'static str,
    pub breaker_opens: u64,
    pub probes: u64,
    pub calls: u64,
    pub errors: u64,
    pub reconnects: u64,
    pub mean_latency_us: f64,
    pub max_latency_us: u64,
}

/// Point-in-time view of the front's health metrics.
#[derive(Clone, Debug)]
pub struct ShardMetricsSnapshot {
    pub shards: Vec<ShardLinkStats>,
    pub fanouts: u64,
    pub mean_fanout_depth: f64,
    pub inflight: u64,
    pub peak_inflight: u64,
    pub merges: u64,
    pub merge_candidates: u64,
    /// Fan-outs that could not get every shard's answer (whether they
    /// then errored or degraded to a flagged partial reply).
    pub partial_failures: u64,
    /// Opt-in partial replies actually returned (`allow_partial` set
    /// and at least one shard missing).
    pub partial_replies: u64,
    /// Requests that died on the typed `deadline_exceeded` path.
    pub deadlines_exceeded: u64,
}

impl ShardMetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let shards = self.shards.iter().map(|s| {
            Json::obj(vec![
                ("addr", Json::str(s.addr.clone())),
                ("up", Json::Bool(s.up)),
                ("breaker", Json::str(s.breaker)),
                ("breaker_opens", Json::num(s.breaker_opens as f64)),
                ("probes", Json::num(s.probes as f64)),
                ("calls", Json::num(s.calls as f64)),
                ("errors", Json::num(s.errors as f64)),
                ("reconnects", Json::num(s.reconnects as f64)),
                ("mean_latency_us", Json::num(s.mean_latency_us)),
                ("max_latency_us", Json::num(s.max_latency_us as f64)),
            ])
        });
        Json::obj(vec![
            ("shards", Json::arr(shards)),
            ("fanouts", Json::num(self.fanouts as f64)),
            ("mean_fanout_depth", Json::num(self.mean_fanout_depth)),
            ("inflight", Json::num(self.inflight as f64)),
            ("peak_inflight", Json::num(self.peak_inflight as f64)),
            ("merges", Json::num(self.merges as f64)),
            ("merge_candidates", Json::num(self.merge_candidates as f64)),
            ("partial_failures", Json::num(self.partial_failures as f64)),
            ("partial_replies", Json::num(self.partial_replies as f64)),
            (
                "deadlines_exceeded",
                Json::num(self.deadlines_exceeded as f64),
            ),
        ])
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "shard front: fanouts={} mean_depth={:.2} peak_inflight={} merges={} \
             merge_candidates={} partial_failures={} partial_replies={} deadlines_exceeded={}\n",
            self.fanouts,
            self.mean_fanout_depth,
            self.peak_inflight,
            self.merges,
            self.merge_candidates,
            self.partial_failures,
            self.partial_replies,
            self.deadlines_exceeded
        );
        for (i, sh) in self.shards.iter().enumerate() {
            s.push_str(&format!(
                "  shard {i} {}: up={} breaker={} (opens={} probes={}) calls={} errors={} \
                 reconnects={} mean_latency={:.1}us max_latency={}us\n",
                sh.addr,
                sh.up,
                sh.breaker,
                sh.breaker_opens,
                sh.probes,
                sh.calls,
                sh.errors,
                sh.reconnects,
                sh.mean_latency_us,
                sh.max_latency_us
            ));
        }
        s
    }
}

/// A corpus registered through the front: per-shard index keys (on the
/// remote shard servers) plus the content hashes used for drift
/// detection.
#[derive(Clone, Debug)]
pub struct ShardedIndex {
    pub key: u64,
    pub name: Option<String>,
    pub t: usize,
    pub total: usize,
    /// Remote `register_index` key per shard; `None` for shards the
    /// layout left empty (corpus smaller than the fleet).
    pub per_shard_key: Vec<Option<u64>>,
    pub per_shard_count: Vec<usize>,
    pub content_hashes: Vec<Option<String>>,
}

/// A corpus to register through the front.
#[derive(Clone, Debug, Default)]
pub struct ShardRegistration {
    pub name: Option<String>,
    pub series: Vec<Vec<f64>>,
    pub labels: Vec<usize>,
    /// Sakoe-Chiba band for the default banded-DTW index (server-side
    /// default: unconstrained).
    pub band: Option<usize>,
    /// Measure spec forwarded verbatim to every shard (see
    /// `MeasureSpec::from_json`).
    pub measure: Option<Json>,
}

/// An exactly merged fan-out result.  `missing` is empty for a full
/// answer; non-empty only on the opt-in `allow_partial` path, where it
/// names the shards whose exact lists could not enter the merge (the
/// typed flag that keeps a degraded reply from ever looking complete).
#[derive(Clone, Debug)]
pub struct ShardedSearch {
    pub neighbors: Vec<ShardNeighbor>,
    pub shards_ok: usize,
    pub shards_total: usize,
    /// Candidates that entered the merge (Σ per-shard top-k sizes).
    pub merge_candidates: usize,
    /// Shard ids absent from the merge (ascending; empty = exact full).
    pub missing: Vec<usize>,
}

/// Replies plus the shards that never produced one (transport level).
struct FanOut {
    replies: Vec<(usize, Json)>,
    missing: Vec<usize>,
}

struct FrontTables {
    next_key: u64,
    by_key: HashMap<u64, Arc<ShardedIndex>>,
    by_name: HashMap<String, u64>,
}

/// The front coordinator: owns the links, the sharded-index registry,
/// the breaker probe thread, and the merge.
pub struct ShardCoordinator<F: FaultHook = NoFaults> {
    cfg: ShardClientConfig,
    layout: ShardLayout,
    links: Vec<ShardLink<F>>,
    metrics: ShardMetrics,
    tables: Mutex<FrontTables>,
    stop: Arc<AtomicBool>,
    probe: Mutex<Option<thread::JoinHandle<()>>>,
}

impl ShardCoordinator<NoFaults> {
    /// Connect to every shard server (capped backoff per link) and
    /// verify the fleet topology: each server must carry the matching
    /// [`ShardRole`](crate::config::ShardRole).
    pub fn connect(cfg: ShardClientConfig) -> Result<Arc<ShardCoordinator>> {
        Self::connect_with_faults(cfg, Arc::new(NoFaults))
    }
}

impl<F: FaultHook> ShardCoordinator<F> {
    /// [`connect`](ShardCoordinator::connect) with a fault hook wired
    /// into every link's dial path — the chaos-harness entry point.
    pub fn connect_with_faults(
        cfg: ShardClientConfig,
        faults: Arc<F>,
    ) -> Result<Arc<ShardCoordinator<F>>> {
        let layout = ShardLayout::new(cfg.addrs.len())
            .map_err(|_| Error::config("shard front needs at least one shard address"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let links: Vec<ShardLink<F>> = cfg
            .addrs
            .iter()
            .enumerate()
            .map(|(i, a)| ShardLink::new(i, a, &cfg, Arc::clone(&faults), Arc::clone(&stop)))
            .collect();
        let metrics = ShardMetrics {
            per_shard: links.iter().map(|_| PerShardMetrics::default()).collect(),
            ..Default::default()
        };
        let sc = Arc::new(ShardCoordinator {
            cfg,
            layout,
            links,
            metrics,
            tables: Mutex::new(FrontTables {
                next_key: 0,
                by_key: HashMap::new(),
                by_name: HashMap::new(),
            }),
            stop,
            probe: Mutex::new(None),
        });
        for shard in 0..sc.links.len() {
            sc.links[shard].connect()?;
            sc.verify_link(shard)?;
        }
        if sc.cfg.probe_interval_ms > 0 {
            Self::spawn_probe(&sc);
        }
        Ok(sc)
    }

    /// `info` round trip asserting the server at the other end really
    /// is shard `shard` of this fleet — run at first connect AND on
    /// every reconnect/probe, so a *different* server reappearing on
    /// the same port (the mixed-generation hazard) is rejected before
    /// any of its answers can enter a merge.
    fn verify_link(&self, shard: usize) -> Result<()> {
        let link = &self.links[shard];
        let verify_ms = (link.call_timeout.as_millis() as u64).clamp(1, 2_000);
        let (info, _) = link.call(
            &Json::obj(vec![("proto", Json::num(2.0)), ("op", Json::str("info"))]),
            Some(Deadline::in_ms(verify_ms)),
        )?;
        let total = self.links.len();
        let sid = info.get("shard_id").and_then(Json::as_usize);
        let stot = info.get("shards_total").and_then(Json::as_usize);
        match (sid, stot) {
            (Some(s), Some(n)) if s == link.shard_id && n == total => Ok(()),
            (None, _) => Err(Error::config(format!(
                "{} is not a shard server (start it with `spdtw shard-serve`)",
                link.addr
            ))),
            (s, n) => Err(Error::config(format!(
                "shard topology mismatch at {}: server reports shard {:?}/{:?}, \
                 front expects shard {}/{}",
                link.addr, s, n, link.shard_id, total
            ))),
        }
    }

    /// Background breaker probe: every `probe_interval_ms`, each OPEN
    /// link moves to half-open and gets one no-backoff dial plus a
    /// topology `info` check; success closes the breaker, failure
    /// reopens it.  The thread holds only a `Weak` so it can never keep
    /// a dropped front alive, and exits on the shared stop flag.
    fn spawn_probe(sc: &Arc<ShardCoordinator<F>>) {
        let interval = Duration::from_millis(sc.cfg.probe_interval_ms.max(1));
        let weak: Weak<ShardCoordinator<F>> = Arc::downgrade(sc);
        let stop = Arc::clone(&sc.stop);
        let handle = thread::Builder::new()
            .name("spdtw-shard-probe".to_string())
            .spawn(move || loop {
                let mut slept = Duration::ZERO;
                while slept < interval {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    let step = (interval - slept).min(Duration::from_millis(20));
                    thread::sleep(step);
                    slept += step;
                }
                let Some(sc) = weak.upgrade() else { return };
                sc.probe_once();
                // drop the Arc before sleeping again: the probe must
                // never be what keeps the coordinator alive
                drop(sc);
            })
            .ok();
        *sc.probe.lock().unwrap() = handle;
    }

    /// One probe sweep over all open breakers (also directly callable
    /// from tests for a deterministic, clock-free probe).
    pub fn probe_once(&self) {
        for shard in 0..self.links.len() {
            let link = &self.links[shard];
            if !link.breaker_is_open() {
                continue;
            }
            link.set_half_open();
            link.probes.fetch_add(1, Ordering::Relaxed);
            match link.connect_once().and_then(|_| self.verify_link(shard)) {
                Ok(()) => {
                    self.metrics.per_shard[shard]
                        .reconnects
                        .fetch_add(1, Ordering::Relaxed);
                    link.record_success();
                }
                Err(_) => link.reopen(),
            }
        }
    }

    /// Raise the shared stop flag: interrupts connect-backoff sleeps on
    /// every link and stops the probe thread at its next slice.  Called
    /// by the front's `shutdown` op and on drop.
    pub fn begin_shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    pub fn shards_total(&self) -> usize {
        self.links.len()
    }

    pub fn layout(&self) -> ShardLayout {
        self.layout
    }

    /// Per-link liveness, in shard order.
    pub fn links_up(&self) -> Vec<bool> {
        self.links.iter().map(|l| l.is_up()).collect()
    }

    /// Per-link breaker state (`"closed"` / `"open"` / `"half_open"`),
    /// in shard order.
    pub fn breaker_states(&self) -> Vec<&'static str> {
        self.links.iter().map(|l| l.breaker_state()).collect()
    }

    pub fn addrs(&self) -> &[String] {
        &self.cfg.addrs
    }

    pub fn metrics(&self) -> ShardMetricsSnapshot {
        let m = &self.metrics;
        let shards = self
            .links
            .iter()
            .zip(&m.per_shard)
            .map(|(l, p)| {
                let calls = p.calls.load(Ordering::Relaxed);
                let sum = p.latency_us_sum.load(Ordering::Relaxed);
                ShardLinkStats {
                    addr: l.addr.clone(),
                    up: l.is_up(),
                    breaker: l.breaker_state(),
                    breaker_opens: l.breaker_opens.load(Ordering::Relaxed),
                    probes: l.probes.load(Ordering::Relaxed),
                    calls,
                    errors: p.errors.load(Ordering::Relaxed),
                    reconnects: p.reconnects.load(Ordering::Relaxed),
                    mean_latency_us: if calls > 0 { sum as f64 / calls as f64 } else { 0.0 },
                    max_latency_us: p.latency_us_max.load(Ordering::Relaxed),
                }
            })
            .collect();
        let fanouts = m.fanouts.load(Ordering::Relaxed);
        let depth_sum = m.fanout_depth_sum.load(Ordering::Relaxed);
        ShardMetricsSnapshot {
            shards,
            fanouts,
            mean_fanout_depth: if fanouts > 0 {
                depth_sum as f64 / fanouts as f64
            } else {
                0.0
            },
            inflight: m.inflight.load(Ordering::Relaxed),
            peak_inflight: m.peak_inflight.load(Ordering::Relaxed),
            merges: m.merges.load(Ordering::Relaxed),
            merge_candidates: m.merge_candidates.load(Ordering::Relaxed),
            partial_failures: m.partial_failures.load(Ordering::Relaxed),
            partial_replies: m.partial_replies.load(Ordering::Relaxed),
            deadlines_exceeded: m.deadlines_exceeded.load(Ordering::Relaxed),
        }
    }

    /// Look up a registered sharded index by front key.
    pub fn index(&self, key: u64) -> Result<Arc<ShardedIndex>> {
        self.tables
            .lock()
            .unwrap()
            .by_key
            .get(&key)
            .cloned()
            .ok_or(Error::NotFound {
                kind: "index",
                name: key.to_string(),
            })
    }

    pub fn key_by_name(&self, name: &str) -> Option<u64> {
        self.tables.lock().unwrap().by_name.get(name).copied()
    }

    /// Split the corpus across the layout and register each slice on
    /// its shard (with `global_ids` so shards reply in global index
    /// space).  All fan-out legs must succeed — registration is never
    /// partial; per-shard content hashes land in the shard manifest
    /// when a store directory is configured.
    pub fn register(&self, reg: &ShardRegistration) -> Result<Arc<ShardedIndex>> {
        let n = reg.series.len();
        if n == 0 {
            return Err(Error::config("register: series must be non-empty"));
        }
        let t = reg.series[0].len();
        if t == 0 {
            return Err(Error::config("register: series must have length >= 1"));
        }
        for (i, s) in reg.series.iter().enumerate() {
            if s.len() != t {
                return Err(Error::config(format!(
                    "register: series {i} has length {} != {t}",
                    s.len()
                )));
            }
        }
        if reg.labels.len() != n {
            return Err(Error::config(format!(
                "register: {} labels for {n} series",
                reg.labels.len()
            )));
        }
        if let Some(name) = &reg.name {
            validate_index_name(name)?;
        }

        let parts = self.layout.split(n);
        let mut reqs: Vec<(usize, Json)> = Vec::new();
        for (shard, part) in parts.iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let series = Json::arr(
                part.iter()
                    .map(|&g| Json::arr(reg.series[g].iter().copied().map(Json::num))),
            );
            let labels = Json::arr(part.iter().map(|&g| Json::num(reg.labels[g] as f64)));
            let global_ids = Json::arr(part.iter().map(|&g| Json::num(g as f64)));
            let mut fields = vec![
                ("proto", Json::num(2.0)),
                ("op", Json::str("register_index")),
                ("shard", Json::num(shard as f64)),
                ("global_ids", global_ids),
                ("series", series),
                ("labels", labels),
            ];
            if let Some(b) = reg.band {
                fields.push(("band", Json::num(b as f64)));
            }
            if let Some(m) = &reg.measure {
                fields.push(("measure", m.clone()));
            }
            reqs.push((shard, Json::obj(fields)));
        }

        let replies = self.fan_out(&reqs, QueryOpts::default())?.replies;
        let total = self.links.len();
        let mut per_shard_key = vec![None; total];
        let mut per_shard_count = vec![0usize; total];
        let mut content_hashes = vec![None; total];
        for (shard, reply) in &replies {
            self.check_ok(reply, *shard)?;
            per_shard_key[*shard] = Some(reply.req_usize("index")? as u64);
            per_shard_count[*shard] = parts[*shard].len();
            content_hashes[*shard] = reply
                .get("content_hash")
                .and_then(Json::as_str)
                .map(str::to_string);
        }

        let si = {
            let mut tb = self.tables.lock().unwrap();
            let key = tb.next_key;
            tb.next_key += 1;
            let si = Arc::new(ShardedIndex {
                key,
                name: reg.name.clone(),
                t,
                total: n,
                per_shard_key,
                per_shard_count,
                content_hashes,
            });
            tb.by_key.insert(key, Arc::clone(&si));
            if let Some(name) = &reg.name {
                tb.by_name.insert(name.clone(), key);
            }
            si
        };

        if let (Some(dir), Some(name)) = (&self.cfg.store, &reg.name) {
            let manifest = ShardManifest {
                name: name.clone(),
                shards_total: total,
                total: n,
                t,
                entries: (0..total)
                    .map(|s| ShardEntry {
                        shard_id: s,
                        count: si.per_shard_count[s],
                        content_hash: si.content_hashes[s].clone(),
                    })
                    .collect(),
            };
            if let Err(e) = manifest.save(dir) {
                eprintln!("spdtw: shard manifest save failed (continuing): {e}");
            }
        }
        Ok(si)
    }

    /// Exact k-NN over all shards: fan out `shard_search`, merge the
    /// per-shard exact top-k lists under `(dist, global_idx)`.
    pub fn search(
        &self,
        index: u64,
        x: &[f64],
        k: usize,
        cascade: Option<&str>,
    ) -> Result<ShardedSearch> {
        self.search_opts(index, x, k, cascade, QueryOpts::default())
    }

    /// [`search`](Self::search) with per-query options (deadline,
    /// opt-in partial results).
    pub fn search_opts(
        &self,
        index: u64,
        x: &[f64],
        k: usize,
        cascade: Option<&str>,
        opts: QueryOpts,
    ) -> Result<ShardedSearch> {
        self.check_deadline(opts.deadline)?;
        let si = self.index(index)?;
        self.check_query(&si, x, k)?;
        let reqs = self.shard_search_reqs(&si, k, cascade, opts.deadline, |fields| {
            fields.push(("x", Json::arr(x.iter().copied().map(Json::num))));
        });
        let fan = self.fan_out(&reqs, opts)?;
        let n_legs = reqs.len();
        let mut missing = fan.missing;
        let mut lists = Vec::with_capacity(fan.replies.len());
        for (shard, reply) in &fan.replies {
            match self.check_ok(reply, *shard) {
                Ok(()) => lists.push(parse_neighbors(reply.req_arr("neighbors")?)?),
                Err(e) => self.degrade_or_fail(e, *shard, &mut missing, opts, n_legs)?,
            }
        }
        missing.sort_unstable();
        if !missing.is_empty() {
            self.metrics.partial_replies.fetch_add(1, Ordering::Relaxed);
        }
        Ok(self.merge(lists, k, missing))
    }

    /// Batched exact k-NN: one `shard_search` leg per shard carrying
    /// every query, merged per query.
    pub fn batch_search(
        &self,
        index: u64,
        xs: &[Vec<f64>],
        k: usize,
        cascade: Option<&str>,
    ) -> Result<Vec<ShardedSearch>> {
        self.batch_search_opts(index, xs, k, cascade, QueryOpts::default())
    }

    /// [`batch_search`](Self::batch_search) with per-query options.  On
    /// the partial path the whole batch shares one missing set (a leg
    /// carries every query, so a dead shard is missing from all of
    /// them).
    pub fn batch_search_opts(
        &self,
        index: u64,
        xs: &[Vec<f64>],
        k: usize,
        cascade: Option<&str>,
        opts: QueryOpts,
    ) -> Result<Vec<ShardedSearch>> {
        self.check_deadline(opts.deadline)?;
        let si = self.index(index)?;
        if xs.is_empty() {
            return Err(Error::config("batch_search: xs must be non-empty"));
        }
        for x in xs {
            self.check_query(&si, x, k)?;
        }
        let reqs = self.shard_search_reqs(&si, k, cascade, opts.deadline, |fields| {
            let arr = Json::arr(
                xs.iter()
                    .map(|x| Json::arr(x.iter().copied().map(Json::num))),
            );
            fields.push(("xs", arr));
        });
        let fan = self.fan_out(&reqs, opts)?;
        let n_legs = reqs.len();
        let mut missing = fan.missing;
        // per_query[q][leg] = that shard's exact top-k for query q
        let mut per_query: Vec<Vec<Vec<ShardNeighbor>>> = vec![Vec::new(); xs.len()];
        for (shard, reply) in &fan.replies {
            if let Err(e) = self.check_ok(reply, *shard) {
                self.degrade_or_fail(e, *shard, &mut missing, opts, n_legs)?;
                continue;
            }
            let results = reply.req_arr("results")?;
            if results.len() != xs.len() {
                return Err(Error::runtime(format!(
                    "shard {shard}: {} results for {} queries",
                    results.len(),
                    xs.len()
                )));
            }
            for (q, r) in results.iter().enumerate() {
                per_query[q].push(parse_neighbors(r.req_arr("neighbors")?)?);
            }
        }
        missing.sort_unstable();
        if !missing.is_empty() {
            self.metrics.partial_replies.fetch_add(1, Ordering::Relaxed);
        }
        Ok(per_query
            .into_iter()
            .map(|lists| self.merge(lists, k, missing.clone()))
            .collect())
    }

    fn check_deadline(&self, deadline: Option<Deadline>) -> Result<()> {
        if let Some(d) = deadline {
            if d.expired() {
                self.metrics
                    .deadlines_exceeded
                    .fetch_add(1, Ordering::Relaxed);
                return Err(d.error());
            }
        }
        Ok(())
    }

    /// An alive shard sent an error *reply* for its leg.  Bad requests
    /// always propagate (the query itself is wrong).  Anything else —
    /// e.g. `not_found` from a shard that restarted empty — counts the
    /// shard as missing when partials are allowed (its answer must
    /// never be faked), and fails the query otherwise.  If every leg is
    /// missing there is nothing exact to return, so even the partial
    /// path degrades to the typed `unavailable` error.
    fn degrade_or_fail(
        &self,
        e: Error,
        shard: usize,
        missing: &mut Vec<usize>,
        opts: QueryOpts,
        n_legs: usize,
    ) -> Result<()> {
        if !opts.allow_partial || matches!(e, Error::Config(_)) {
            return Err(e);
        }
        missing.push(shard);
        self.metrics.partial_failures.fetch_add(1, Ordering::Relaxed);
        if missing.len() >= n_legs {
            return Err(Error::ShardUnavailable {
                shards_ok: self.links.len() - missing.len(),
                shards_total: self.links.len(),
                detail: format!("all {n_legs} shard legs failed; last: {e}"),
            });
        }
        Ok(())
    }

    fn check_query(&self, si: &ShardedIndex, x: &[f64], k: usize) -> Result<()> {
        if k == 0 {
            return Err(Error::config("k must be >= 1"));
        }
        if x.len() != si.t {
            return Err(Error::config(format!(
                "query length {} != index length {}",
                x.len(),
                si.t
            )));
        }
        for (i, v) in x.iter().enumerate() {
            if !v.is_finite() {
                return Err(Error::config(format!("query value [{i}] is not finite")));
            }
        }
        Ok(())
    }

    fn shard_search_reqs(
        &self,
        si: &ShardedIndex,
        k: usize,
        cascade: Option<&str>,
        deadline: Option<Deadline>,
        add_query: impl Fn(&mut Vec<(&'static str, Json)>),
    ) -> Vec<(usize, Json)> {
        si.per_shard_key
            .iter()
            .enumerate()
            .filter_map(|(shard, key)| {
                key.map(|key| {
                    let mut fields = vec![
                        ("proto", Json::num(2.0)),
                        ("op", Json::str("shard_search")),
                        ("shard", Json::num(shard as f64)),
                        ("index", Json::num(key as f64)),
                        ("k", Json::num(k as f64)),
                    ];
                    if let Some(c) = cascade {
                        fields.push(("cascade", Json::str(c)));
                    }
                    if let Some(d) = deadline {
                        // forward the REMAINING budget, so every hop's
                        // clock measures only its own leg
                        let rem_ms = (d.remaining().as_millis() as u64).max(1);
                        fields.push(("deadline_ms", Json::num(rem_ms as f64)));
                    }
                    add_query(&mut fields);
                    (shard, Json::obj(fields))
                })
            })
            .collect()
    }

    fn merge(&self, lists: Vec<Vec<ShardNeighbor>>, k: usize, missing: Vec<usize>) -> ShardedSearch {
        let merge_candidates: usize = lists.iter().map(Vec::len).sum();
        let neighbors = merge_topk(lists, k);
        self.metrics.merges.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .merge_candidates
            .fetch_add(merge_candidates as u64, Ordering::Relaxed);
        let total = self.links.len();
        ShardedSearch {
            neighbors,
            shards_ok: total - missing.len(),
            shards_total: total,
            merge_candidates,
            missing,
        }
    }

    /// Convert a shard's error *reply* (the shard is alive) into a
    /// typed error: `bad_request`/`bad_input` propagate as config
    /// errors, `deadline_exceeded` as the typed deadline error,
    /// anything else as an internal runtime error.
    fn check_ok(&self, reply: &Json, shard: usize) -> Result<()> {
        if reply.get("ok").and_then(Json::as_bool) == Some(true) {
            return Ok(());
        }
        let code = reply.get("code").and_then(Json::as_str).unwrap_or("unknown");
        let msg = reply
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("error reply");
        let addr = &self.links[shard].addr;
        match code {
            "bad_request" | "bad_input" => {
                Err(Error::config(format!("shard {shard} ({addr}): {msg}")))
            }
            "deadline_exceeded" => {
                self.metrics
                    .deadlines_exceeded
                    .fetch_add(1, Ordering::Relaxed);
                let budget = reply
                    .get("budget_ms")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0);
                Err(Error::deadline_exceeded(budget as u64))
            }
            _ => Err(Error::runtime(format!(
                "shard {shard} ({addr}): {code}: {msg}"
            ))),
        }
    }

    /// Issue every request concurrently over the multiplexed links
    /// (all writes first, then collect replies).  A leg whose breaker
    /// is OPEN fails fast without touching the network; other failed
    /// legs are retried once after a capped-backoff reconnect (plus a
    /// topology re-verification, so a different server on the same
    /// port is never adopted).  A drained deadline anywhere turns the
    /// whole fan-out into the typed `deadline_exceeded` error.  Legs
    /// that still fail either degrade the fan-out to the typed
    /// `ShardUnavailable` error (default) or, with `allow_partial`,
    /// come back named in [`FanOut::missing`].
    fn fan_out(&self, reqs: &[(usize, Json)], opts: QueryOpts) -> Result<FanOut> {
        let shards_total = self.links.len();
        self.metrics.fanouts.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .fanout_depth_sum
            .fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let inflight = self.metrics.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics
            .peak_inflight
            .fetch_max(inflight, Ordering::Relaxed);
        let result = self.fan_out_inner(reqs, shards_total, opts);
        self.metrics.inflight.fetch_sub(1, Ordering::Relaxed);
        result
    }

    fn fan_out_inner(
        &self,
        reqs: &[(usize, Json)],
        shards_total: usize,
        opts: QueryOpts,
    ) -> Result<FanOut> {
        let pends: Vec<Result<PendingCall>> = reqs
            .iter()
            .map(|(shard, req)| {
                let link = &self.links[*shard];
                if link.breaker_is_open() {
                    Err(link.fast_fail_err())
                } else {
                    link.begin(req)
                }
            })
            .collect();
        let mut replies: Vec<Option<Json>> = (0..reqs.len()).map(|_| None).collect();
        let mut failures: Vec<(usize, Error)> = Vec::new(); // (req position, error)
        for (i, pend) in pends.into_iter().enumerate() {
            let shard = reqs[i].0;
            let link = &self.links[shard];
            match pend.and_then(|p| link.finish(p, opts.deadline)) {
                Ok((reply, lat)) => {
                    self.record_call(shard, lat);
                    link.record_success();
                    replies[i] = Some(reply);
                }
                Err(e) => {
                    self.metrics.per_shard[shard]
                        .errors
                        .fetch_add(1, Ordering::Relaxed);
                    // A deadline-bounded miss says nothing about shard
                    // health; everything else feeds the breaker.
                    if !matches!(e, Error::DeadlineExceeded { .. }) {
                        link.record_failure();
                    }
                    failures.push((i, e));
                }
            }
        }
        // A drained budget dominates everything (including partials):
        // there is no time left to retry or even to merge usefully.
        if let Some(d) = opts.deadline {
            if failures
                .iter()
                .any(|(_, e)| matches!(e, Error::DeadlineExceeded { .. }))
                || (!failures.is_empty() && d.expired())
            {
                self.metrics
                    .deadlines_exceeded
                    .fetch_add(1, Ordering::Relaxed);
                return Err(d.error());
            }
        }
        // One retry per failed leg — unless the breaker is open, in
        // which case the leg fails fast with no inline backoff.
        let mut still_down: Vec<(usize, String)> = Vec::new(); // (shard, detail)
        for (i, first_err) in failures {
            let (shard, req) = &reqs[i];
            let link = &self.links[*shard];
            let retried = if link.breaker_is_open() {
                Err(link.fast_fail_err())
            } else {
                link.connect()
                    .and_then(|_| self.verify_link(*shard))
                    .and_then(|_| {
                        self.metrics.per_shard[*shard]
                            .reconnects
                            .fetch_add(1, Ordering::Relaxed);
                        link.call(req, opts.deadline)
                    })
            };
            match retried {
                Ok((reply, lat)) => {
                    self.record_call(*shard, lat);
                    link.record_success();
                    replies[i] = Some(reply);
                }
                Err(e) => {
                    self.metrics.per_shard[*shard]
                        .errors
                        .fetch_add(1, Ordering::Relaxed);
                    if matches!(e, Error::DeadlineExceeded { .. }) {
                        self.metrics
                            .deadlines_exceeded
                            .fetch_add(1, Ordering::Relaxed);
                        return Err(e);
                    }
                    link.record_failure();
                    still_down.push((*shard, format!("{first_err}; retry: {e}")));
                }
            }
        }
        if !still_down.is_empty() {
            self.metrics.partial_failures.fetch_add(1, Ordering::Relaxed);
            let all_legs_down = still_down.len() >= reqs.len();
            if !opts.allow_partial || all_legs_down {
                let detail = still_down
                    .iter()
                    .map(|(s, d)| format!("shard {s}: {d}"))
                    .collect::<Vec<_>>()
                    .join("; ");
                return Err(Error::ShardUnavailable {
                    shards_ok: shards_total - still_down.len(),
                    shards_total,
                    detail,
                });
            }
        }
        let missing: Vec<usize> = still_down.iter().map(|(s, _)| *s).collect();
        Ok(FanOut {
            replies: reqs
                .iter()
                .zip(replies)
                .filter_map(|((shard, _), reply)| reply.map(|r| (*shard, r)))
                .collect(),
            missing,
        })
    }

    fn record_call(&self, shard: usize, lat: Duration) {
        let p = &self.metrics.per_shard[shard];
        p.calls.fetch_add(1, Ordering::Relaxed);
        let us = lat.as_micros() as u64;
        p.latency_us_sum.fetch_add(us, Ordering::Relaxed);
        p.latency_us_max.fetch_max(us, Ordering::Relaxed);
    }
}

impl<F: FaultHook> Drop for ShardCoordinator<F> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.probe.lock().unwrap().take() {
            // If the probe thread itself holds the last Arc, this drop
            // runs ON the probe thread — joining ourselves would
            // deadlock, and the thread exits on the stop flag anyway.
            if h.thread().id() != thread::current().id() {
                let _ = h.join();
            }
        }
    }
}

/// Parse a shard reply's neighbor array (global index space).
fn parse_neighbors(arr: &[Json]) -> Result<Vec<ShardNeighbor>> {
    arr.iter()
        .map(|n| {
            Ok(ShardNeighbor {
                dist: n.req_f64("dist")?,
                label: n.req_usize("label")?,
                global_idx: n.req_usize("idx")?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::fault::{ActiveFaults, FaultPlan};
    use std::io::Write as _;
    use std::net::TcpListener;

    /// Minimal line server: replies to every request with an id-echoing
    /// canned object; closes the connection after `max_lines` requests.
    fn canned_server(max_lines: usize) -> (String, thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = thread::spawn(move || {
            for stream in listener.incoming().take(2) {
                let Ok(stream) = stream else { break };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut w = stream;
                let mut line = String::new();
                for _ in 0..max_lines {
                    line.clear();
                    if reader.read_line(&mut line).unwrap_or(0) == 0 {
                        break;
                    }
                    let req = Json::parse(line.trim()).unwrap();
                    let id = req.get("id").and_then(Json::as_f64).unwrap();
                    let reply = Json::obj(vec![
                        ("id", Json::num(id)),
                        ("ok", Json::Bool(true)),
                        ("pong", Json::Bool(true)),
                    ]);
                    writeln!(w, "{}", reply.to_string()).unwrap();
                }
            }
        });
        (addr, h)
    }

    fn test_cfg(addr: &str) -> ShardClientConfig {
        ShardClientConfig {
            addrs: vec![addr.to_string()],
            connect_attempts: 2,
            backoff_base_ms: 5,
            backoff_cap_ms: 10,
            call_timeout_ms: 2_000,
            breaker_threshold: 3,
            probe_interval_ms: 0,
            store: None,
        }
    }

    fn test_link(addr: &str, cfg: &ShardClientConfig) -> ShardLink<NoFaults> {
        ShardLink::new(
            0,
            addr,
            cfg,
            Arc::new(NoFaults),
            Arc::new(AtomicBool::new(false)),
        )
    }

    #[test]
    fn link_multiplexes_ids_and_reconnects() {
        let (addr, h) = canned_server(2);
        let cfg = test_cfg(&addr);
        let link = test_link(&addr, &cfg);
        link.connect().unwrap();
        let ping = Json::obj(vec![("proto", Json::num(2.0)), ("op", Json::str("ping"))]);
        // two requests in flight on one connection
        let a = link.begin(&ping).unwrap();
        let b = link.begin(&ping).unwrap();
        assert_ne!(a.id, b.id);
        let (ra, _) = link.finish(a, None).unwrap();
        let (rb, _) = link.finish(b, None).unwrap();
        assert_eq!(ra.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(rb.get("ok").and_then(Json::as_bool), Some(true));
        // server closed the connection after 2 lines: the next call
        // fails, and an explicit reconnect restores service
        assert!(link.call(&ping, None).is_err());
        link.connect().unwrap();
        assert!(link.call(&ping, None).is_ok());
        drop(link);
        h.join().unwrap();
    }

    #[test]
    fn dead_address_fails_with_unavailable_code() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // nothing listens here any more
        let cfg = test_cfg(&addr);
        let link = test_link(&addr, &cfg);
        let err = link.connect().unwrap_err();
        assert_eq!(err.code(), "unavailable");
        assert_eq!(link.call(&Json::Null, None).unwrap_err().code(), "unavailable");
    }

    #[test]
    fn stop_flag_interrupts_connect_backoff() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let mut cfg = test_cfg(&addr);
        cfg.connect_attempts = 4;
        cfg.backoff_base_ms = 5_000; // would sleep ~15 s without the fix
        cfg.backoff_cap_ms = 5_000;
        let stop = Arc::new(AtomicBool::new(true)); // already shutting down
        let link = ShardLink::new(0, &addr, &cfg, Arc::new(NoFaults), stop);
        let t0 = Instant::now();
        let err = link.connect().unwrap_err();
        assert!(err.to_string().contains("shutting down"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_millis(1_000),
            "backoff was not interrupted: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn breaker_opens_at_threshold_and_closes_on_success() {
        let cfg = test_cfg("127.0.0.1:1");
        let link = test_link("127.0.0.1:1", &cfg);
        assert_eq!(link.breaker_state(), "closed");
        link.record_failure();
        link.record_failure();
        assert_eq!(link.breaker_state(), "closed");
        link.record_failure(); // threshold 3
        assert!(link.breaker_is_open());
        assert_eq!(link.breaker_opens.load(Ordering::Relaxed), 1);
        // probe trial: half-open lets a request through, reopen on fail
        link.set_half_open();
        assert_eq!(link.breaker_state(), "half_open");
        assert!(!link.breaker_is_open());
        link.reopen();
        assert!(link.breaker_is_open());
        // success closes and resets the streak (no double-count of opens)
        link.record_success();
        assert_eq!(link.breaker_state(), "closed");
        link.record_failure();
        assert_eq!(link.breaker_state(), "closed");
        assert_eq!(link.breaker_opens.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn injected_refuse_connect_fails_dial_even_with_live_server() {
        let (addr, h) = canned_server(8);
        let plan = FaultPlan::from_json(
            &Json::parse(r#"{"rules":[{"shard":0,"kind":"refuse_connect","from":0,"count":2}]}"#)
                .unwrap(),
        )
        .unwrap();
        let mut cfg = test_cfg(&addr);
        cfg.connect_attempts = 1; // one dial per connect() call
        let link = ShardLink::new(
            0,
            &addr,
            &cfg,
            Arc::new(ActiveFaults::new(plan)),
            Arc::new(AtomicBool::new(false)),
        );
        // attempts 0 and 1 are refused by the plan, attempt 2 connects
        assert!(link.connect().is_err());
        assert!(link.connect().is_err());
        link.connect().unwrap();
        let ping = Json::obj(vec![("proto", Json::num(2.0)), ("op", Json::str("ping"))]);
        assert!(link.call(&ping, None).is_ok());
        drop(link);
        // the canned server loops twice over incoming(); unblock it
        let _ = TcpStream::connect(&addr);
        let _ = h.join();
    }

    #[test]
    fn deadline_bounds_link_wait_and_maps_to_typed_error() {
        // a server that accepts but never replies
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            thread::sleep(Duration::from_millis(400));
            drop(stream);
        });
        let cfg = test_cfg(&addr); // call_timeout 2 s
        let link = test_link(&addr, &cfg);
        link.connect().unwrap();
        let ping = Json::obj(vec![("proto", Json::num(2.0)), ("op", Json::str("ping"))]);
        let t0 = Instant::now();
        let err = link
            .call(&ping, Some(Deadline::in_ms(50)))
            .unwrap_err();
        assert_eq!(err.code(), "deadline_exceeded", "{err}");
        assert!(
            t0.elapsed() < Duration::from_millis(1_500),
            "deadline did not shorten the flat call timeout"
        );
        h.join().unwrap();
    }
}
