//! The front `ShardCoordinator`: persistent multiplexed TCP links to N
//! shard servers, exact fan-out/merge, health metrics.
//!
//! Each link is one `TcpStream` split into a write half (behind a
//! mutex, shared by every in-flight request) and a dedicated reader
//! thread owning the `BufReader` half.  Requests carry monotonically
//! increasing v2 `id`s; the reader routes each reply line to the
//! waiting caller's channel by its echoed `id`, so any number of
//! requests can be in flight per connection (multiplexing — the front's
//! connection handler threads share the same N links).
//!
//! Failure model: a dead link fails all of its in-flight requests
//! immediately (the reader drops their reply senders on EOF).  The next
//! fan-out retries the shard once after a capped-backoff reconnect; if
//! it stays down the query returns
//! [`Error::ShardUnavailable`](crate::error::Error::ShardUnavailable)
//! with `shards_ok`/`shards_total` — a typed partial-result error,
//! never a silently truncated neighbor list.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::layout::{ShardEntry, ShardLayout, ShardManifest};
use super::{merge_topk, ShardNeighbor};
use crate::coordinator::validate_index_name;
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Connection/retry policy for the front's shard links.
#[derive(Clone, Debug)]
pub struct ShardClientConfig {
    /// Shard server addresses, one per shard, in shard-id order.
    pub addrs: Vec<String>,
    /// Dial attempts per (re)connect, with doubling backoff.
    pub connect_attempts: usize,
    /// First backoff delay.
    pub backoff_base_ms: u64,
    /// Backoff ceiling (capped exponential).
    pub backoff_cap_ms: u64,
    /// Per-request reply timeout.
    pub call_timeout_ms: u64,
    /// Directory for the shard manifest (per-shard content hashes);
    /// `None` disables manifest persistence.
    pub store: Option<PathBuf>,
}

impl Default for ShardClientConfig {
    fn default() -> Self {
        ShardClientConfig {
            addrs: Vec::new(),
            connect_attempts: 4,
            backoff_base_ms: 50,
            backoff_cap_ms: 800,
            call_timeout_ms: 30_000,
            store: None,
        }
    }
}

impl ShardClientConfig {
    pub fn for_addrs(addrs: Vec<String>) -> Self {
        ShardClientConfig {
            addrs,
            ..Default::default()
        }
    }
}

/// A request in flight on a link: the reply arrives on `rx` when the
/// reader thread routes the line with the matching id.
struct PendingCall {
    id: u64,
    rx: mpsc::Receiver<Json>,
    sent_at: Instant,
}

/// Mutable half of a link.  `pending` is re-created per connection so a
/// dying reader only fails its own generation's waiters.
struct LinkState {
    writer: Option<BufWriter<TcpStream>>,
    pending: Arc<Mutex<HashMap<u64, mpsc::Sender<Json>>>>,
}

/// One persistent, multiplexed connection to a shard server.
struct ShardLink {
    shard_id: usize,
    addr: String,
    next_id: AtomicU64,
    state: Mutex<LinkState>,
    connect_attempts: usize,
    backoff_base_ms: u64,
    backoff_cap_ms: u64,
    call_timeout: Duration,
}

impl ShardLink {
    fn new(shard_id: usize, addr: &str, cfg: &ShardClientConfig) -> ShardLink {
        ShardLink {
            shard_id,
            addr: addr.to_string(),
            next_id: AtomicU64::new(1),
            state: Mutex::new(LinkState {
                writer: None,
                pending: Arc::new(Mutex::new(HashMap::new())),
            }),
            connect_attempts: cfg.connect_attempts.max(1),
            backoff_base_ms: cfg.backoff_base_ms,
            backoff_cap_ms: cfg.backoff_cap_ms.max(cfg.backoff_base_ms),
            call_timeout: Duration::from_millis(cfg.call_timeout_ms),
        }
    }

    fn down_err(&self) -> Error {
        Error::coordinator(format!("shard {} ({}): link down", self.shard_id, self.addr))
    }

    /// Dial with capped exponential backoff, then install the stream
    /// and spawn a fresh reader thread for it.
    fn connect(&self) -> Result<()> {
        let mut delay = Duration::from_millis(self.backoff_base_ms);
        let cap = Duration::from_millis(self.backoff_cap_ms);
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..self.connect_attempts {
            if attempt > 0 {
                thread::sleep(delay);
                delay = (delay * 2).min(cap);
            }
            match TcpStream::connect(&self.addr) {
                Ok(stream) => return self.attach(stream),
                Err(e) => last = Some(e),
            }
        }
        Err(Error::coordinator(format!(
            "shard {} ({}): connect failed after {} attempts: {}",
            self.shard_id,
            self.addr,
            self.connect_attempts,
            last.map(|e| e.to_string()).unwrap_or_default()
        )))
    }

    fn attach(&self, stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        let read_half = stream
            .try_clone()
            .map_err(|e| Error::coordinator(format!("shard {}: {e}", self.addr)))?;
        let pending: Arc<Mutex<HashMap<u64, mpsc::Sender<Json>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        {
            let mut st = self.state.lock().unwrap();
            st.writer = Some(BufWriter::new(stream));
            st.pending = Arc::clone(&pending);
        }
        let name = format!("spdtw-shard-link-{}", self.shard_id);
        thread::Builder::new()
            .name(name)
            .spawn(move || {
                let mut reader = BufReader::new(read_half);
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {
                            let Ok(reply) = Json::parse(line.trim()) else {
                                continue;
                            };
                            let Some(id) = reply.get("id").and_then(Json::as_f64) else {
                                continue;
                            };
                            if let Some(tx) = pending.lock().unwrap().remove(&(id as u64)) {
                                let _ = tx.send(reply);
                            }
                        }
                    }
                }
                // EOF or read error: dropping the senders fails every
                // waiter of THIS connection generation immediately.
                pending.lock().unwrap().clear();
            })
            .map_err(|e| Error::coordinator(format!("shard link thread: {e}")))?;
        Ok(())
    }

    fn is_up(&self) -> bool {
        self.state.lock().unwrap().writer.is_some()
    }

    /// Send `req` (id injected) without waiting for the reply.
    fn begin(&self, req: &Json) -> Result<PendingCall> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = req.clone();
        if let Json::Obj(m) = &mut req {
            m.insert("id".to_string(), Json::num(id as f64));
        }
        let line = req.to_string();
        let (tx, rx) = mpsc::channel();
        let mut st = self.state.lock().unwrap();
        let Some(writer) = st.writer.as_mut() else {
            return Err(self.down_err());
        };
        st.pending.lock().unwrap().insert(id, tx);
        let wrote = writer
            .write_all(line.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush());
        if let Err(e) = wrote {
            st.pending.lock().unwrap().remove(&id);
            st.writer = None; // mark the link dead for later callers
            return Err(Error::coordinator(format!(
                "shard {} ({}): write failed: {e}",
                self.shard_id, self.addr
            )));
        }
        Ok(PendingCall {
            id,
            rx,
            sent_at: Instant::now(),
        })
    }

    /// Wait for the reply to a [`begin`](Self::begin).
    fn finish(&self, call: PendingCall) -> Result<(Json, Duration)> {
        match call.rx.recv_timeout(self.call_timeout) {
            Ok(reply) => Ok((reply, call.sent_at.elapsed())),
            Err(_) => {
                // Timeout, or the reader died and dropped our sender.
                let st = self.state.lock().unwrap();
                st.pending.lock().unwrap().remove(&call.id);
                Err(Error::coordinator(format!(
                    "shard {} ({}): no reply (link lost or timed out)",
                    self.shard_id, self.addr
                )))
            }
        }
    }

    fn call(&self, req: &Json) -> Result<(Json, Duration)> {
        self.finish(self.begin(req)?)
    }
}

/// Per-link health counters.
#[derive(Default)]
struct PerShardMetrics {
    calls: AtomicU64,
    errors: AtomicU64,
    reconnects: AtomicU64,
    latency_us_sum: AtomicU64,
    latency_us_max: AtomicU64,
}

/// Fan-out/merge health counters for the whole front.
#[derive(Default)]
struct ShardMetrics {
    per_shard: Vec<PerShardMetrics>,
    fanouts: AtomicU64,
    fanout_depth_sum: AtomicU64,
    inflight: AtomicU64,
    peak_inflight: AtomicU64,
    merges: AtomicU64,
    merge_candidates: AtomicU64,
    partial_failures: AtomicU64,
}

/// Point-in-time stats for one shard link.
#[derive(Clone, Debug)]
pub struct ShardLinkStats {
    pub addr: String,
    pub up: bool,
    pub calls: u64,
    pub errors: u64,
    pub reconnects: u64,
    pub mean_latency_us: f64,
    pub max_latency_us: u64,
}

/// Point-in-time view of the front's health metrics.
#[derive(Clone, Debug)]
pub struct ShardMetricsSnapshot {
    pub shards: Vec<ShardLinkStats>,
    pub fanouts: u64,
    pub mean_fanout_depth: f64,
    pub inflight: u64,
    pub peak_inflight: u64,
    pub merges: u64,
    pub merge_candidates: u64,
    pub partial_failures: u64,
}

impl ShardMetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let shards = self.shards.iter().map(|s| {
            Json::obj(vec![
                ("addr", Json::str(s.addr.clone())),
                ("up", Json::Bool(s.up)),
                ("calls", Json::num(s.calls as f64)),
                ("errors", Json::num(s.errors as f64)),
                ("reconnects", Json::num(s.reconnects as f64)),
                ("mean_latency_us", Json::num(s.mean_latency_us)),
                ("max_latency_us", Json::num(s.max_latency_us as f64)),
            ])
        });
        Json::obj(vec![
            ("shards", Json::arr(shards)),
            ("fanouts", Json::num(self.fanouts as f64)),
            ("mean_fanout_depth", Json::num(self.mean_fanout_depth)),
            ("inflight", Json::num(self.inflight as f64)),
            ("peak_inflight", Json::num(self.peak_inflight as f64)),
            ("merges", Json::num(self.merges as f64)),
            ("merge_candidates", Json::num(self.merge_candidates as f64)),
            ("partial_failures", Json::num(self.partial_failures as f64)),
        ])
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "shard front: fanouts={} mean_depth={:.2} peak_inflight={} merges={} \
             merge_candidates={} partial_failures={}\n",
            self.fanouts,
            self.mean_fanout_depth,
            self.peak_inflight,
            self.merges,
            self.merge_candidates,
            self.partial_failures
        );
        for (i, sh) in self.shards.iter().enumerate() {
            s.push_str(&format!(
                "  shard {i} {}: up={} calls={} errors={} reconnects={} \
                 mean_latency={:.1}us max_latency={}us\n",
                sh.addr, sh.up, sh.calls, sh.errors, sh.reconnects, sh.mean_latency_us,
                sh.max_latency_us
            ));
        }
        s
    }
}

/// A corpus registered through the front: per-shard index keys (on the
/// remote shard servers) plus the content hashes used for drift
/// detection.
#[derive(Clone, Debug)]
pub struct ShardedIndex {
    pub key: u64,
    pub name: Option<String>,
    pub t: usize,
    pub total: usize,
    /// Remote `register_index` key per shard; `None` for shards the
    /// layout left empty (corpus smaller than the fleet).
    pub per_shard_key: Vec<Option<u64>>,
    pub per_shard_count: Vec<usize>,
    pub content_hashes: Vec<Option<String>>,
}

/// A corpus to register through the front.
#[derive(Clone, Debug, Default)]
pub struct ShardRegistration {
    pub name: Option<String>,
    pub series: Vec<Vec<f64>>,
    pub labels: Vec<usize>,
    /// Sakoe-Chiba band for the default banded-DTW index (server-side
    /// default: unconstrained).
    pub band: Option<usize>,
    /// Measure spec forwarded verbatim to every shard (see
    /// `MeasureSpec::from_json`).
    pub measure: Option<Json>,
}

/// An exactly merged fan-out result.
#[derive(Clone, Debug)]
pub struct ShardedSearch {
    pub neighbors: Vec<ShardNeighbor>,
    pub shards_ok: usize,
    pub shards_total: usize,
    /// Candidates that entered the merge (Σ per-shard top-k sizes).
    pub merge_candidates: usize,
}

struct FrontTables {
    next_key: u64,
    by_key: HashMap<u64, Arc<ShardedIndex>>,
    by_name: HashMap<String, u64>,
}

/// The front coordinator: owns the links, the sharded-index registry,
/// and the merge.
pub struct ShardCoordinator {
    cfg: ShardClientConfig,
    layout: ShardLayout,
    links: Vec<ShardLink>,
    metrics: ShardMetrics,
    tables: Mutex<FrontTables>,
}

impl ShardCoordinator {
    /// Connect to every shard server (capped backoff per link) and
    /// verify the fleet topology: each server must carry the matching
    /// [`ShardRole`](crate::config::ShardRole).
    pub fn connect(cfg: ShardClientConfig) -> Result<Arc<ShardCoordinator>> {
        let layout = ShardLayout::new(cfg.addrs.len())
            .map_err(|_| Error::config("shard front needs at least one shard address"))?;
        let links: Vec<ShardLink> = cfg
            .addrs
            .iter()
            .enumerate()
            .map(|(i, a)| ShardLink::new(i, a, &cfg))
            .collect();
        let metrics = ShardMetrics {
            per_shard: links.iter().map(|_| PerShardMetrics::default()).collect(),
            ..Default::default()
        };
        let sc = Arc::new(ShardCoordinator {
            cfg,
            layout,
            links,
            metrics,
            tables: Mutex::new(FrontTables {
                next_key: 0,
                by_key: HashMap::new(),
                by_name: HashMap::new(),
            }),
        });
        let total = sc.links.len();
        for link in &sc.links {
            link.connect()?;
            let (info, _) = link.call(&Json::obj(vec![
                ("proto", Json::num(2.0)),
                ("op", Json::str("info")),
            ]))?;
            let sid = info.get("shard_id").and_then(Json::as_usize);
            let stot = info.get("shards_total").and_then(Json::as_usize);
            match (sid, stot) {
                (Some(s), Some(n)) if s == link.shard_id && n == total => {}
                (None, _) => {
                    return Err(Error::config(format!(
                        "{} is not a shard server (start it with `spdtw shard-serve`)",
                        link.addr
                    )))
                }
                (s, n) => {
                    return Err(Error::config(format!(
                        "shard topology mismatch at {}: server reports shard {:?}/{:?}, \
                         front expects shard {}/{}",
                        link.addr, s, n, link.shard_id, total
                    )))
                }
            }
        }
        Ok(sc)
    }

    pub fn shards_total(&self) -> usize {
        self.links.len()
    }

    pub fn layout(&self) -> ShardLayout {
        self.layout
    }

    /// Per-link liveness, in shard order.
    pub fn links_up(&self) -> Vec<bool> {
        self.links.iter().map(|l| l.is_up()).collect()
    }

    pub fn addrs(&self) -> &[String] {
        &self.cfg.addrs
    }

    pub fn metrics(&self) -> ShardMetricsSnapshot {
        let m = &self.metrics;
        let shards = self
            .links
            .iter()
            .zip(&m.per_shard)
            .map(|(l, p)| {
                let calls = p.calls.load(Ordering::Relaxed);
                let sum = p.latency_us_sum.load(Ordering::Relaxed);
                ShardLinkStats {
                    addr: l.addr.clone(),
                    up: l.is_up(),
                    calls,
                    errors: p.errors.load(Ordering::Relaxed),
                    reconnects: p.reconnects.load(Ordering::Relaxed),
                    mean_latency_us: if calls > 0 { sum as f64 / calls as f64 } else { 0.0 },
                    max_latency_us: p.latency_us_max.load(Ordering::Relaxed),
                }
            })
            .collect();
        let fanouts = m.fanouts.load(Ordering::Relaxed);
        let depth_sum = m.fanout_depth_sum.load(Ordering::Relaxed);
        ShardMetricsSnapshot {
            shards,
            fanouts,
            mean_fanout_depth: if fanouts > 0 {
                depth_sum as f64 / fanouts as f64
            } else {
                0.0
            },
            inflight: m.inflight.load(Ordering::Relaxed),
            peak_inflight: m.peak_inflight.load(Ordering::Relaxed),
            merges: m.merges.load(Ordering::Relaxed),
            merge_candidates: m.merge_candidates.load(Ordering::Relaxed),
            partial_failures: m.partial_failures.load(Ordering::Relaxed),
        }
    }

    /// Look up a registered sharded index by front key.
    pub fn index(&self, key: u64) -> Result<Arc<ShardedIndex>> {
        self.tables
            .lock()
            .unwrap()
            .by_key
            .get(&key)
            .cloned()
            .ok_or(Error::NotFound {
                kind: "index",
                name: key.to_string(),
            })
    }

    pub fn key_by_name(&self, name: &str) -> Option<u64> {
        self.tables.lock().unwrap().by_name.get(name).copied()
    }

    /// Split the corpus across the layout and register each slice on
    /// its shard (with `global_ids` so shards reply in global index
    /// space).  All fan-out legs must succeed; per-shard content hashes
    /// land in the shard manifest when a store directory is configured.
    pub fn register(&self, reg: &ShardRegistration) -> Result<Arc<ShardedIndex>> {
        let n = reg.series.len();
        if n == 0 {
            return Err(Error::config("register: series must be non-empty"));
        }
        let t = reg.series[0].len();
        if t == 0 {
            return Err(Error::config("register: series must have length >= 1"));
        }
        for (i, s) in reg.series.iter().enumerate() {
            if s.len() != t {
                return Err(Error::config(format!(
                    "register: series {i} has length {} != {t}",
                    s.len()
                )));
            }
        }
        if reg.labels.len() != n {
            return Err(Error::config(format!(
                "register: {} labels for {n} series",
                reg.labels.len()
            )));
        }
        if let Some(name) = &reg.name {
            validate_index_name(name)?;
        }

        let parts = self.layout.split(n);
        let mut reqs: Vec<(usize, Json)> = Vec::new();
        for (shard, part) in parts.iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let series = Json::arr(
                part.iter()
                    .map(|&g| Json::arr(reg.series[g].iter().copied().map(Json::num))),
            );
            let labels = Json::arr(part.iter().map(|&g| Json::num(reg.labels[g] as f64)));
            let global_ids = Json::arr(part.iter().map(|&g| Json::num(g as f64)));
            let mut fields = vec![
                ("proto", Json::num(2.0)),
                ("op", Json::str("register_index")),
                ("shard", Json::num(shard as f64)),
                ("global_ids", global_ids),
                ("series", series),
                ("labels", labels),
            ];
            if let Some(b) = reg.band {
                fields.push(("band", Json::num(b as f64)));
            }
            if let Some(m) = &reg.measure {
                fields.push(("measure", m.clone()));
            }
            reqs.push((shard, Json::obj(fields)));
        }

        let replies = self.fan_out(&reqs)?;
        let total = self.links.len();
        let mut per_shard_key = vec![None; total];
        let mut per_shard_count = vec![0usize; total];
        let mut content_hashes = vec![None; total];
        for (shard, reply) in &replies {
            self.check_ok(reply, *shard)?;
            per_shard_key[*shard] = Some(reply.req_usize("index")? as u64);
            per_shard_count[*shard] = parts[*shard].len();
            content_hashes[*shard] = reply
                .get("content_hash")
                .and_then(Json::as_str)
                .map(str::to_string);
        }

        let si = {
            let mut tb = self.tables.lock().unwrap();
            let key = tb.next_key;
            tb.next_key += 1;
            let si = Arc::new(ShardedIndex {
                key,
                name: reg.name.clone(),
                t,
                total: n,
                per_shard_key,
                per_shard_count,
                content_hashes,
            });
            tb.by_key.insert(key, Arc::clone(&si));
            if let Some(name) = &reg.name {
                tb.by_name.insert(name.clone(), key);
            }
            si
        };

        if let (Some(dir), Some(name)) = (&self.cfg.store, &reg.name) {
            let manifest = ShardManifest {
                name: name.clone(),
                shards_total: total,
                total: n,
                t,
                entries: (0..total)
                    .map(|s| ShardEntry {
                        shard_id: s,
                        count: si.per_shard_count[s],
                        content_hash: si.content_hashes[s].clone(),
                    })
                    .collect(),
            };
            if let Err(e) = manifest.save(dir) {
                eprintln!("spdtw: shard manifest save failed (continuing): {e}");
            }
        }
        Ok(si)
    }

    /// Exact k-NN over all shards: fan out `shard_search`, merge the
    /// per-shard exact top-k lists under `(dist, global_idx)`.
    pub fn search(
        &self,
        index: u64,
        x: &[f64],
        k: usize,
        cascade: Option<&str>,
    ) -> Result<ShardedSearch> {
        let si = self.index(index)?;
        self.check_query(&si, x, k)?;
        let reqs = self.shard_search_reqs(&si, k, cascade, |fields| {
            fields.push(("x", Json::arr(x.iter().copied().map(Json::num))));
        });
        let replies = self.fan_out(&reqs)?;
        let mut lists = Vec::with_capacity(replies.len());
        for (shard, reply) in &replies {
            self.check_ok(reply, *shard)?;
            lists.push(parse_neighbors(reply.req_arr("neighbors")?)?);
        }
        Ok(self.merge(lists, k))
    }

    /// Batched exact k-NN: one `shard_search` leg per shard carrying
    /// every query, merged per query.
    pub fn batch_search(
        &self,
        index: u64,
        xs: &[Vec<f64>],
        k: usize,
        cascade: Option<&str>,
    ) -> Result<Vec<ShardedSearch>> {
        let si = self.index(index)?;
        if xs.is_empty() {
            return Err(Error::config("batch_search: xs must be non-empty"));
        }
        for x in xs {
            self.check_query(&si, x, k)?;
        }
        let reqs = self.shard_search_reqs(&si, k, cascade, |fields| {
            let arr = Json::arr(
                xs.iter()
                    .map(|x| Json::arr(x.iter().copied().map(Json::num))),
            );
            fields.push(("xs", arr));
        });
        let replies = self.fan_out(&reqs)?;
        // per_query[q][leg] = that shard's exact top-k for query q
        let mut per_query: Vec<Vec<Vec<ShardNeighbor>>> = vec![Vec::new(); xs.len()];
        for (shard, reply) in &replies {
            self.check_ok(reply, *shard)?;
            let results = reply.req_arr("results")?;
            if results.len() != xs.len() {
                return Err(Error::runtime(format!(
                    "shard {shard}: {} results for {} queries",
                    results.len(),
                    xs.len()
                )));
            }
            for (q, r) in results.iter().enumerate() {
                per_query[q].push(parse_neighbors(r.req_arr("neighbors")?)?);
            }
        }
        Ok(per_query
            .into_iter()
            .map(|lists| self.merge(lists, k))
            .collect())
    }

    fn check_query(&self, si: &ShardedIndex, x: &[f64], k: usize) -> Result<()> {
        if k == 0 {
            return Err(Error::config("k must be >= 1"));
        }
        if x.len() != si.t {
            return Err(Error::config(format!(
                "query length {} != index length {}",
                x.len(),
                si.t
            )));
        }
        for (i, v) in x.iter().enumerate() {
            if !v.is_finite() {
                return Err(Error::config(format!("query value [{i}] is not finite")));
            }
        }
        Ok(())
    }

    fn shard_search_reqs(
        &self,
        si: &ShardedIndex,
        k: usize,
        cascade: Option<&str>,
        add_query: impl Fn(&mut Vec<(&'static str, Json)>),
    ) -> Vec<(usize, Json)> {
        si.per_shard_key
            .iter()
            .enumerate()
            .filter_map(|(shard, key)| {
                key.map(|key| {
                    let mut fields = vec![
                        ("proto", Json::num(2.0)),
                        ("op", Json::str("shard_search")),
                        ("shard", Json::num(shard as f64)),
                        ("index", Json::num(key as f64)),
                        ("k", Json::num(k as f64)),
                    ];
                    if let Some(c) = cascade {
                        fields.push(("cascade", Json::str(c)));
                    }
                    add_query(&mut fields);
                    (shard, Json::obj(fields))
                })
            })
            .collect()
    }

    fn merge(&self, lists: Vec<Vec<ShardNeighbor>>, k: usize) -> ShardedSearch {
        let merge_candidates: usize = lists.iter().map(Vec::len).sum();
        let neighbors = merge_topk(lists, k);
        self.metrics.merges.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .merge_candidates
            .fetch_add(merge_candidates as u64, Ordering::Relaxed);
        let total = self.links.len();
        ShardedSearch {
            neighbors,
            shards_ok: total,
            shards_total: total,
            merge_candidates,
        }
    }

    /// Convert a shard's error *reply* (the shard is alive) into a
    /// typed error: `bad_request`/`bad_input` propagate as config
    /// errors, anything else as an internal runtime error.
    fn check_ok(&self, reply: &Json, shard: usize) -> Result<()> {
        if reply.get("ok").and_then(Json::as_bool) == Some(true) {
            return Ok(());
        }
        let code = reply.get("code").and_then(Json::as_str).unwrap_or("unknown");
        let msg = reply
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("error reply");
        let addr = &self.links[shard].addr;
        match code {
            "bad_request" | "bad_input" => {
                Err(Error::config(format!("shard {shard} ({addr}): {msg}")))
            }
            _ => Err(Error::runtime(format!(
                "shard {shard} ({addr}): {code}: {msg}"
            ))),
        }
    }

    /// Issue every request concurrently over the multiplexed links
    /// (all writes first, then collect replies), retrying each failed
    /// leg once after a capped-backoff reconnect.  If any leg still
    /// fails, the whole fan-out degrades to the typed
    /// `ShardUnavailable` partial-result error.
    fn fan_out(&self, reqs: &[(usize, Json)]) -> Result<Vec<(usize, Json)>> {
        let shards_total = self.links.len();
        self.metrics.fanouts.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .fanout_depth_sum
            .fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let inflight = self.metrics.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics
            .peak_inflight
            .fetch_max(inflight, Ordering::Relaxed);
        let result = self.fan_out_inner(reqs, shards_total);
        self.metrics.inflight.fetch_sub(1, Ordering::Relaxed);
        result
    }

    fn fan_out_inner(
        &self,
        reqs: &[(usize, Json)],
        shards_total: usize,
    ) -> Result<Vec<(usize, Json)>> {
        let pends: Vec<Result<PendingCall>> = reqs
            .iter()
            .map(|(shard, req)| self.links[*shard].begin(req))
            .collect();
        let mut replies: Vec<Option<Json>> = (0..reqs.len()).map(|_| None).collect();
        let mut failures: Vec<(usize, String)> = Vec::new(); // (req position, detail)
        for (i, pend) in pends.into_iter().enumerate() {
            let shard = reqs[i].0;
            match pend.and_then(|p| self.links[shard].finish(p)) {
                Ok((reply, lat)) => {
                    self.record_call(shard, lat);
                    replies[i] = Some(reply);
                }
                Err(e) => {
                    self.metrics.per_shard[shard]
                        .errors
                        .fetch_add(1, Ordering::Relaxed);
                    failures.push((i, e.to_string()));
                }
            }
        }
        // One retry per failed leg: reconnect (capped backoff), resend.
        let mut still_down: Vec<(usize, String)> = Vec::new(); // (shard, detail)
        for (i, first_err) in failures {
            let (shard, req) = &reqs[i];
            let retried = self.links[*shard].connect().and_then(|_| {
                self.metrics.per_shard[*shard]
                    .reconnects
                    .fetch_add(1, Ordering::Relaxed);
                self.links[*shard].call(req)
            });
            match retried {
                Ok((reply, lat)) => {
                    self.record_call(*shard, lat);
                    replies[i] = Some(reply);
                }
                Err(e) => {
                    self.metrics.per_shard[*shard]
                        .errors
                        .fetch_add(1, Ordering::Relaxed);
                    still_down.push((*shard, format!("{first_err}; retry: {e}")));
                }
            }
        }
        if !still_down.is_empty() {
            self.metrics.partial_failures.fetch_add(1, Ordering::Relaxed);
            let detail = still_down
                .iter()
                .map(|(s, d)| format!("shard {s}: {d}"))
                .collect::<Vec<_>>()
                .join("; ");
            return Err(Error::ShardUnavailable {
                shards_ok: shards_total - still_down.len(),
                shards_total,
                detail,
            });
        }
        Ok(reqs
            .iter()
            .zip(replies)
            .map(|((shard, _), reply)| (*shard, reply.expect("reply present")))
            .collect())
    }

    fn record_call(&self, shard: usize, lat: Duration) {
        let p = &self.metrics.per_shard[shard];
        p.calls.fetch_add(1, Ordering::Relaxed);
        let us = lat.as_micros() as u64;
        p.latency_us_sum.fetch_add(us, Ordering::Relaxed);
        p.latency_us_max.fetch_max(us, Ordering::Relaxed);
    }
}

/// Parse a shard reply's neighbor array (global index space).
fn parse_neighbors(arr: &[Json]) -> Result<Vec<ShardNeighbor>> {
    arr.iter()
        .map(|n| {
            Ok(ShardNeighbor {
                dist: n.req_f64("dist")?,
                label: n.req_usize("label")?,
                global_idx: n.req_usize("idx")?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::TcpListener;

    /// Minimal line server: replies to every request with an id-echoing
    /// canned object; closes the connection after `max_lines` requests.
    fn canned_server(max_lines: usize) -> (String, thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = thread::spawn(move || {
            for stream in listener.incoming().take(2) {
                let Ok(stream) = stream else { break };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut w = stream;
                let mut line = String::new();
                for _ in 0..max_lines {
                    line.clear();
                    if reader.read_line(&mut line).unwrap_or(0) == 0 {
                        break;
                    }
                    let req = Json::parse(line.trim()).unwrap();
                    let id = req.get("id").and_then(Json::as_f64).unwrap();
                    let reply = Json::obj(vec![
                        ("id", Json::num(id)),
                        ("ok", Json::Bool(true)),
                        ("pong", Json::Bool(true)),
                    ]);
                    writeln!(w, "{}", reply.to_string()).unwrap();
                }
            }
        });
        (addr, h)
    }

    fn test_cfg(addr: &str) -> ShardClientConfig {
        ShardClientConfig {
            addrs: vec![addr.to_string()],
            connect_attempts: 2,
            backoff_base_ms: 5,
            backoff_cap_ms: 10,
            call_timeout_ms: 2_000,
            store: None,
        }
    }

    #[test]
    fn link_multiplexes_ids_and_reconnects() {
        let (addr, h) = canned_server(2);
        let cfg = test_cfg(&addr);
        let link = ShardLink::new(0, &addr, &cfg);
        link.connect().unwrap();
        let ping = Json::obj(vec![("proto", Json::num(2.0)), ("op", Json::str("ping"))]);
        // two requests in flight on one connection
        let a = link.begin(&ping).unwrap();
        let b = link.begin(&ping).unwrap();
        assert_ne!(a.id, b.id);
        let (ra, _) = link.finish(a).unwrap();
        let (rb, _) = link.finish(b).unwrap();
        assert_eq!(ra.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(rb.get("ok").and_then(Json::as_bool), Some(true));
        // server closed the connection after 2 lines: the next call
        // fails, and an explicit reconnect restores service
        assert!(link.call(&ping).is_err());
        link.connect().unwrap();
        assert!(link.call(&ping).is_ok());
        drop(link);
        h.join().unwrap();
    }

    #[test]
    fn dead_address_fails_with_unavailable_code() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // nothing listens here any more
        let cfg = test_cfg(&addr);
        let link = ShardLink::new(0, &addr, &cfg);
        let err = link.connect().unwrap_err();
        assert_eq!(err.code(), "unavailable");
        assert_eq!(link.call(&Json::Null).unwrap_err().code(), "unavailable");
    }
}
