//! Sharded multi-node serving: exact k-NN fan-out over index shards.
//!
//! One process cannot hold an arbitrarily large corpus, and
//! approximation is the wrong lever for scaling DTW-family search (the
//! paper's measures are only worth serving exactly).  This module
//! splits the logical index across N *shard servers* — each an
//! ordinary [`crate::coordinator::Coordinator`] +
//! [`crate::coordinator::Server`] started with a
//! [`crate::config::ShardRole`] — and puts a thin *front* in charge of
//! fan-out and merge:
//!
//! ```text
//!                       ┌──────────────────────────┐
//!   client ── TCP ────▶ │ FrontServer              │
//!                       │  └ ShardCoordinator      │
//!                       │     ├ link 0 ──────────┐ │
//!                       │     ├ link 1 ────────┐ │ │
//!                       │     └ merge (heap)   │ │ │
//!                       └──────────────────────┼─┼─┘
//!                              persistent TCP  │ │
//!                       ┌──────────────────────┘ │
//!                       ▼                        ▼
//!                ┌─────────────┐          ┌─────────────┐
//!                │ shard 1     │          │ shard 0     │
//!                │ Coordinator │          │ Coordinator │
//!                │ + cascade   │          │ + cascade   │
//!                └─────────────┘          └─────────────┘
//! ```
//!
//! ## Exactness
//!
//! Each shard runs today's full cascade + early-abandon engine locally
//! and returns its *exact* top-k as `(dist, global_idx)` pairs.  Two
//! facts make the merged answer bit-identical to a single-index engine
//! over the union corpus:
//!
//! 1. **Per-shard order equals global order.**  The engine tie-breaks
//!    equal distances on the *local* train index; registration requires
//!    the per-shard `global_ids` to be strictly increasing in local
//!    index, so `(dist, local_idx)` and `(dist, global_idx)` induce the
//!    same order within a shard.  Round-robin assignment
//!    (`g = shard + i·N`, see [`ShardLayout`]) satisfies this, as does
//!    any contiguous split.
//! 2. **The union of per-shard top-k contains the global top-k.**  Any
//!    neighbor in the global top-k is in the top-k of its own shard, so
//!    merging the per-shard lists under the same total order —
//!    `(f64::total_cmp` on dist`, global_idx)` — with a bounded
//!    [`std::collections::BinaryHeap`] ([`merge_topk`]) reproduces the
//!    single-engine list exactly, including sentinel
//!    (`BIG + BIG`) ties from unreachable SP-DTW corners.
//!
//! Distances survive the wire bit-exactly: the JSON writer emits the
//! shortest round-trip form of every non-integral `f64` and the parser
//! rounds correctly, so `to_bits` equality holds end to end (asserted
//! by `tests/integration_shard.rs`).
//!
//! ## Degradation
//!
//! A dead shard never yields a silently truncated answer — degraded
//! service is always *typed* and *opt-in*:
//!
//! * **Default**: the fan-out retries the link once with
//!   capped-backoff reconnection; if the shard stays down, the query
//!   fails with the typed
//!   [`Error::ShardUnavailable`](crate::error::Error::ShardUnavailable)
//!   partial-result error (wire code `unavailable`, carrying
//!   `shards_ok`/`shards_total`).
//! * **Opt-in partial results**: `allow_partial: true` on
//!   `search`/`batch_search` instead merges the *exact* top-k over the
//!   responsive shards and flags the reply with a
//!   `partial: {shards_ok, shards_total, missing}` block naming the
//!   absent shards — an exact answer over a declared subset, never an
//!   undeclared one.
//! * **Circuit breakers**: after `breaker_threshold` consecutive
//!   failures a link opens and requests fail fast (no inline connect
//!   backoff); a background probe thread re-checks open links every
//!   `probe_interval_ms` and closes them on a verified reconnect.
//! * **Deadlines**: a client `deadline_ms` budget propagates
//!   front → shard with the *remaining* budget per leg; exhaustion
//!   anywhere returns the typed `deadline_exceeded` code.
//! * **Fault injection**: the [`fault`] module injects deterministic,
//!   seed-reproducible faults (refused connects, delayed / garbled /
//!   torn replies, capped connections) at both ends of the shard link
//!   so every one of these paths is exercised by tests and the chaos
//!   CI job rather than waited for in production.
//!
//! Submodules: [`layout`] (split/assign + on-disk shard manifest),
//! [`coordinator`] (persistent multiplexed links, fan-out, merge,
//! breakers, metrics), [`front`] (TCP front-end speaking the v1/v2 line
//! protocol), [`fault`] (deterministic fault plans + injection hooks).

pub mod coordinator;
pub mod fault;
pub mod front;
pub mod layout;

pub use coordinator::{
    QueryOpts, ShardClientConfig, ShardCoordinator, ShardMetricsSnapshot, ShardRegistration,
    ShardedIndex, ShardedSearch,
};
pub use fault::{ActiveFaults, FaultHook, FaultKind, FaultPlan, FaultRule, NoFaults};
pub use front::FrontServer;
pub use layout::{ShardEntry, ShardLayout, ShardManifest};

/// One exact candidate streamed back from a shard: distance, class
/// label, and the *global* train index (already remapped by the shard
/// via its registered `global_ids`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardNeighbor {
    pub dist: f64,
    pub label: usize,
    pub global_idx: usize,
}

/// Total order over candidates: `(dist, global_idx)` lexicographic,
/// distances via `f64::total_cmp`.  This is the same order the
/// single-index engine uses (with local == global index), which is what
/// makes the merge exact.
fn cmp_neighbor(a: &ShardNeighbor, b: &ShardNeighbor) -> std::cmp::Ordering {
    a.dist
        .total_cmp(&b.dist)
        .then(a.global_idx.cmp(&b.global_idx))
}

/// Max-heap wrapper: the *worst* candidate under [`cmp_neighbor`] sits
/// on top, so a bounded heap of size k keeps the k best seen so far.
struct HeapItem(ShardNeighbor);

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        cmp_neighbor(&self.0, &other.0) == std::cmp::Ordering::Equal
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        cmp_neighbor(&self.0, &other.0)
    }
}

/// Merge per-shard exact top-k candidate lists into the global exact
/// top-k with a bounded binary heap (never holds more than k+1 items).
///
/// Returns the candidates sorted ascending by `(dist, global_idx)` —
/// bit-identical to what a single-index engine over the union corpus
/// would return, provided each input list is that shard's exact top-k
/// under the same order (see the module docs for the argument).
pub fn merge_topk<I>(per_shard: I, k: usize) -> Vec<ShardNeighbor>
where
    I: IntoIterator<Item = Vec<ShardNeighbor>>,
{
    let mut heap = std::collections::BinaryHeap::with_capacity(k + 1);
    for list in per_shard {
        for n in list {
            heap.push(HeapItem(n));
            if heap.len() > k {
                heap.pop(); // drop the current worst
            }
            // Boundedness invariant: the heap never outgrows its
            // `with_capacity(k + 1)` reservation, so merging huge
            // fleets stays O(k) memory.
            debug_assert!(heap.len() <= k + 1, "merge heap exceeded k+1 items");
        }
    }
    let mut out: Vec<ShardNeighbor> = heap.into_iter().map(|h| h.0).collect();
    out.sort_by(cmp_neighbor);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n(dist: f64, global_idx: usize) -> ShardNeighbor {
        ShardNeighbor {
            dist,
            label: 0,
            global_idx,
        }
    }

    #[test]
    fn merge_orders_by_dist_then_global_idx() {
        let a = vec![n(1.0, 4), n(2.0, 0)];
        let b = vec![n(1.0, 1), n(3.0, 3)];
        let got = merge_topk(vec![a, b], 3);
        let idx: Vec<usize> = got.iter().map(|x| x.global_idx).collect();
        assert_eq!(idx, vec![1, 4, 0]); // ties on dist=1.0 break by global idx
    }

    #[test]
    fn merge_bounds_at_k_and_handles_short_lists() {
        let lists = vec![vec![n(5.0, 0)], vec![], vec![n(1.0, 2), n(2.0, 1)]];
        let got = merge_topk(lists, 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].global_idx, 2);
        assert_eq!(got[1].global_idx, 1);
    }

    #[test]
    fn merge_is_bit_exact_on_sentinel_ties() {
        use crate::measures::BIG;
        let s = BIG + BIG; // unreachable-corner sentinel, finite
        let got = merge_topk(vec![vec![n(s, 3)], vec![n(s, 1)], vec![n(s, 2)]], 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].global_idx, 1);
        assert_eq!(got[1].global_idx, 2);
        assert_eq!(got[0].dist.to_bits(), s.to_bits());
    }

    #[test]
    fn merge_k_zero_is_empty() {
        assert!(merge_topk(vec![vec![n(1.0, 0)]], 0).is_empty());
    }
}
