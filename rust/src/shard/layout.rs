//! Shard layout (global-index → shard assignment) and the on-disk
//! shard manifest that records per-shard content hashes.
//!
//! The layout is deterministic round-robin: global index `g` lives on
//! shard `g % N`, and shard `s` holds globals `s, s+N, s+2N, …` — which
//! are strictly increasing in local index, the property the exactness
//! proof in [`crate::shard`] relies on.  [`ShardLayout::moved_on_resize`]
//! reports exactly which globals change shard when servers are added or
//! removed, so a re-balance only re-registers what moved.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// File name of the shard manifest, written next to the front's index
/// store.
pub const SHARD_MANIFEST_FILE: &str = "shard_manifest.json";

/// Deterministic round-robin assignment of global train indices to
/// shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    shards_total: usize,
}

impl ShardLayout {
    pub fn new(shards_total: usize) -> Result<ShardLayout> {
        if shards_total == 0 {
            return Err(Error::config("shard layout needs at least 1 shard"));
        }
        Ok(ShardLayout { shards_total })
    }

    pub fn shards_total(&self) -> usize {
        self.shards_total
    }

    /// Shard owning global index `g`.
    pub fn assign(&self, global_idx: usize) -> usize {
        global_idx % self.shards_total
    }

    /// Split a corpus of `n` series into per-shard global-id lists.
    /// Each inner list is strictly increasing (the exactness
    /// precondition for per-shard tie-breaks).
    pub fn split(&self, n: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::with_capacity(n.div_ceil(self.shards_total)); self.shards_total];
        for g in 0..n {
            out[self.assign(g)].push(g);
        }
        // Postcondition backing the exact-merge argument (and the wire
        // validator's strictly-increasing `global_ids` requirement):
        // ascending `g` insertion keeps every per-shard list strictly
        // increasing.
        debug_assert!(
            out.iter()
                .all(|part| part.windows(2).all(|w| w[0] < w[1])),
            "split produced a non-increasing shard slice"
        );
        out
    }

    /// Global indices whose shard changes when the fleet resizes from
    /// `self.shards_total` to `new_total` (shard add/remove).  These are
    /// the only series a re-balance has to move.
    pub fn moved_on_resize(&self, n: usize, new_total: usize) -> Result<Vec<usize>> {
        let new = ShardLayout::new(new_total)?;
        Ok((0..n).filter(|&g| self.assign(g) != new.assign(g)).collect())
    }
}

/// One shard's slice of a sharded index, as recorded in the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    pub shard_id: usize,
    /// Series count on this shard (0 for shards left empty by a small
    /// corpus).
    pub count: usize,
    /// Content hash reported by the shard's `register_index` reply
    /// (format `{:016x}`), `None` for empty shards.
    pub content_hash: Option<String>,
}

/// On-disk record of one sharded index registration: which layout split
/// it, and the per-shard content hashes to detect drift when shards
/// restart or re-register.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardManifest {
    pub name: String,
    pub shards_total: usize,
    /// Total series across all shards.
    pub total: usize,
    /// Series length.
    pub t: usize,
    pub entries: Vec<ShardEntry>,
}

impl ShardManifest {
    pub fn to_json(&self) -> Json {
        let entries = self.entries.iter().map(|e| {
            Json::obj(vec![
                ("shard_id", Json::num(e.shard_id as f64)),
                ("count", Json::num(e.count as f64)),
                (
                    "content_hash",
                    match &e.content_hash {
                        Some(h) => Json::str(h.clone()),
                        None => Json::Null,
                    },
                ),
            ])
        });
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("name", Json::str(self.name.clone())),
            ("shards_total", Json::num(self.shards_total as f64)),
            ("total", Json::num(self.total as f64)),
            ("t", Json::num(self.t as f64)),
            ("entries", Json::arr(entries)),
        ])
    }

    pub fn from_json(json: &Json) -> Result<ShardManifest> {
        let name = json.req_str("name")?.to_string();
        let shards_total = json.req_usize("shards_total")?;
        let total = json.req_usize("total")?;
        let t = json.req_usize("t")?;
        let mut entries = Vec::new();
        for e in json.req_arr("entries")? {
            entries.push(ShardEntry {
                shard_id: e.req_usize("shard_id")?,
                count: e.req_usize("count")?,
                content_hash: e
                    .get("content_hash")
                    .and_then(Json::as_str)
                    .map(str::to_string),
            });
        }
        if entries.len() != shards_total {
            return Err(Error::data(format!(
                "shard manifest '{name}': {} entries for {shards_total} shards",
                entries.len()
            )));
        }
        Ok(ShardManifest {
            name,
            shards_total,
            total,
            t,
            entries,
        })
    }

    /// Atomically write the manifest to `<dir>/shard_manifest.json`
    /// (temp file + rename, same discipline as the index store).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::data(format!("{}: {e}", dir.display())))?;
        let path = dir.join(SHARD_MANIFEST_FILE);
        let tmp = dir.join(format!("{SHARD_MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, self.to_json().to_pretty())
            .map_err(|e| Error::data(format!("{}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, &path).map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            Error::data(format!("{}: {e}", path.display()))
        })
    }

    pub fn load(dir: &Path) -> Result<ShardManifest> {
        let path = dir.join(SHARD_MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::data(format!("{}: {e}", path.display())))?;
        ShardManifest::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_round_robin_and_increasing() {
        let l = ShardLayout::new(3).unwrap();
        let parts = l.split(8);
        assert_eq!(parts, vec![vec![0, 3, 6], vec![1, 4, 7], vec![2, 5]]);
        for (s, part) in parts.iter().enumerate() {
            for (i, &g) in part.iter().enumerate() {
                assert_eq!(l.assign(g), s);
                assert_eq!(g, s + i * 3); // strictly increasing by construction
            }
        }
    }

    #[test]
    fn zero_shards_rejected() {
        assert!(ShardLayout::new(0).is_err());
    }

    #[test]
    fn small_corpus_leaves_trailing_shards_empty() {
        let parts = ShardLayout::new(4).unwrap().split(2);
        assert_eq!(parts[2], Vec::<usize>::new());
        assert_eq!(parts[3], Vec::<usize>::new());
    }

    #[test]
    fn moved_on_resize_names_exactly_the_movers() {
        let l = ShardLayout::new(2).unwrap();
        let moved = l.moved_on_resize(6, 3).unwrap();
        // g%2 vs g%3: g=1 (1→1 stays), check each explicitly
        let want: Vec<usize> = (0..6).filter(|g| g % 2 != g % 3).collect();
        assert_eq!(moved, want);
        assert!(l.moved_on_resize(6, 2).unwrap().is_empty());
        assert!(l.moved_on_resize(6, 0).is_err());
    }

    #[test]
    fn manifest_roundtrip() {
        let m = ShardManifest {
            name: "corpus".into(),
            shards_total: 2,
            total: 3,
            t: 16,
            entries: vec![
                ShardEntry {
                    shard_id: 0,
                    count: 2,
                    content_hash: Some("00deadbeef00cafe".into()),
                },
                ShardEntry {
                    shard_id: 1,
                    count: 1,
                    content_hash: None,
                },
            ],
        };
        let back = ShardManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);

        let dir = std::env::temp_dir().join(format!("spdtw_shard_manifest_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        m.save(&dir).unwrap();
        assert_eq!(ShardManifest::load(&dir).unwrap(), m);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
