//! Deterministic fault injection for the sharded serving path.
//!
//! A [`FaultPlan`] is a seeded, serializable schedule of per-shard
//! faults — refuse-connect, drop-mid-reply, delay-reply, garble-line,
//! close-after-N — that both ends of a shard link can act out:
//!
//! - the front's `ShardLink` consults the hook before dialing a shard
//!   (connect-class faults), and
//! - a shard server started with `spdtw shard-serve --fault-plan`
//!   consults it before writing each reply (reply-class faults), so
//!   chaos runs exercise real sockets, real reader threads, and the
//!   real breaker/deadline machinery.
//!
//! Injection happens behind the [`FaultHook`] trait.  Production code
//! is generic over the hook and instantiated with the [`NoFaults`] ZST,
//! whose methods are trivial `#[inline]` constants — monomorphization
//! erases the harness entirely from non-chaos builds (the zero-cost
//! requirement of the fault-tolerance tentpole).
//!
//! **Determinism contract:** [`ActiveFaults`] decides every fault from
//! per-shard *event counters* alone (nth connection attempt, nth reply
//! written).  No wall clock, no runtime randomness — the seed only
//! parameterizes [`FaultPlan::generate`].  The same plan against the
//! same request script therefore reproduces the same fault sequence,
//! which is what makes the chaos suite's replies assertable.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// One kind of injectable fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Connect-class: the nth connection attempt to the shard is
    /// refused (the dial fails as if the port were closed).
    RefuseConnect,
    /// Connect-class: the nth accepted connection is torn down by the
    /// server after `replies` replies have been written.
    CloseAfter { replies: u64 },
    /// Reply-class: the nth reply is delayed by `ms` milliseconds
    /// before being written (exercises deadlines and slow-shard legs).
    DelayReply { ms: u64 },
    /// Reply-class: the nth reply is replaced by a non-JSON line
    /// (exercises the reader's corrupt-stream handling).
    GarbleLine,
    /// Reply-class: the connection is dropped mid-reply — a partial
    /// line is written, then the socket closes.
    DropMidReply,
}

impl FaultKind {
    fn name(&self) -> &'static str {
        match self {
            FaultKind::RefuseConnect => "refuse_connect",
            FaultKind::CloseAfter { .. } => "close_after",
            FaultKind::DelayReply { .. } => "delay_reply",
            FaultKind::GarbleLine => "garble_line",
            FaultKind::DropMidReply => "drop_mid_reply",
        }
    }

    fn is_connect_class(&self) -> bool {
        matches!(self, FaultKind::RefuseConnect | FaultKind::CloseAfter { .. })
    }
}

/// One scheduled fault: `kind` fires on shard `shard` for the event
/// counter window `[from, from + count)` (connect attempts for
/// connect-class kinds, written replies for reply-class kinds).
#[derive(Clone, Debug)]
pub struct FaultRule {
    pub shard: usize,
    pub kind: FaultKind,
    /// First event index (0-based) the rule applies to.
    pub from: u64,
    /// How many consecutive events it applies to (`u64::MAX` = forever).
    pub count: u64,
}

impl FaultRule {
    fn matches(&self, shard: usize, event: u64) -> bool {
        self.shard == shard && event >= self.from && event - self.from < self.count
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("shard", Json::num(self.shard as f64)),
            ("kind", Json::str(self.kind.name())),
            ("from", Json::num(self.from as f64)),
        ];
        if self.count != u64::MAX {
            fields.push(("count", Json::num(self.count as f64)));
        }
        match self.kind {
            FaultKind::CloseAfter { replies } => {
                fields.push(("replies", Json::num(replies as f64)));
            }
            FaultKind::DelayReply { ms } => fields.push(("ms", Json::num(ms as f64))),
            _ => {}
        }
        Json::obj(fields)
    }

    fn from_json(rule: &Json) -> Result<FaultRule> {
        let shard = rule.req_usize("shard")?;
        let from = opt_u64(rule, "from")?.unwrap_or(0);
        let count = opt_u64(rule, "count")?.unwrap_or(u64::MAX);
        let kind = match rule.req_str("kind")? {
            "refuse_connect" => FaultKind::RefuseConnect,
            "close_after" => FaultKind::CloseAfter {
                replies: opt_u64(rule, "replies")?.ok_or_else(|| {
                    Error::config("fault plan: 'close_after' requires 'replies'")
                })?,
            },
            "delay_reply" => FaultKind::DelayReply {
                ms: opt_u64(rule, "ms")?
                    .ok_or_else(|| Error::config("fault plan: 'delay_reply' requires 'ms'"))?,
            },
            "garble_line" => FaultKind::GarbleLine,
            "drop_mid_reply" => FaultKind::DropMidReply,
            other => {
                return Err(Error::config(format!(
                    "fault plan: unknown fault kind '{other}' (expected refuse_connect, \
                     close_after, delay_reply, garble_line or drop_mid_reply)"
                )))
            }
        };
        Ok(FaultRule {
            shard,
            kind,
            from,
            count,
        })
    }
}

fn opt_u64(obj: &Json, key: &str) -> Result<Option<u64>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => {
            let f = v.as_f64().ok_or_else(|| {
                Error::config(format!("fault plan: '{key}' must be a non-negative integer"))
            })?;
            if !f.is_finite() || f < 0.0 || f.fract() != 0.0 || f > u64::MAX as f64 {
                return Err(Error::config(format!(
                    "fault plan: '{key}' must be a non-negative integer"
                )));
            }
            Ok(Some(f as u64))
        }
    }
}

/// A serializable schedule of per-shard faults.
///
/// Wire format (one JSON object, `spdtw shard-serve --fault-plan FILE`):
///
/// ```json
/// {"version": 1, "seed": 42, "rules": [
///   {"shard": 0, "kind": "refuse_connect", "from": 0, "count": 2},
///   {"shard": 1, "kind": "delay_reply", "ms": 150},
///   {"shard": 0, "kind": "garble_line", "from": 3, "count": 1},
///   {"shard": 0, "kind": "close_after", "replies": 5}
/// ]}
/// ```
///
/// `from` defaults to 0 and `count` to "forever"; the first matching
/// rule in plan order wins when several cover the same event.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// The seed the plan was generated from (0 for hand-written plans);
    /// recorded so a chaos log names its plan reproducibly.
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Generate a pseudo-random plan: `n_rules` rules over `shards`
    /// shards, fully determined by `seed`.
    pub fn generate(seed: u64, shards: usize, n_rules: usize) -> FaultPlan {
        let mut rng = Pcg64::new(seed);
        let shards = shards.max(1);
        let rules = (0..n_rules)
            .map(|_| {
                let shard = rng.below(shards);
                let from = rng.below(4) as u64;
                let count = 1 + rng.below(3) as u64;
                let kind = match rng.below(5) {
                    0 => FaultKind::RefuseConnect,
                    1 => FaultKind::CloseAfter {
                        replies: 1 + rng.below(5) as u64,
                    },
                    2 => FaultKind::DelayReply {
                        ms: 50 + rng.below(150) as u64,
                    },
                    3 => FaultKind::GarbleLine,
                    _ => FaultKind::DropMidReply,
                };
                FaultRule {
                    shard,
                    kind,
                    from,
                    count,
                }
            })
            .collect();
        FaultPlan { seed, rules }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("seed", Json::num(self.seed as f64)),
            ("rules", Json::arr(self.rules.iter().map(|r| r.to_json()))),
        ])
    }

    pub fn from_json(plan: &Json) -> Result<FaultPlan> {
        if let Some(v) = plan.get("version") {
            if v.as_usize() != Some(1) {
                return Err(Error::config("fault plan: unsupported version (expected 1)"));
            }
        }
        let seed = opt_u64(plan, "seed")?.unwrap_or(0);
        let rules = plan
            .req_arr("rules")?
            .iter()
            .map(FaultRule::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(FaultPlan { seed, rules })
    }

    /// Parse a plan from a JSON file on disk.
    pub fn load(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::config(format!("fault plan {}: {e}", path.display()))
        })?;
        FaultPlan::from_json(&Json::parse(&text)?)
    }

    /// Serialize to the wire format (deterministic field order).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")?;
        Ok(())
    }

    /// Highest shard id any rule names (counter-array sizing).
    fn max_shard(&self) -> usize {
        self.rules.iter().map(|r| r.shard).max().unwrap_or(0)
    }
}

/// Fault decision for one connection attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnectFault {
    None,
    /// Fail the dial as if the shard refused the connection.
    Refuse,
    /// Accept, but tear the connection down after N replies.
    CloseAfterReplies(u64),
}

/// Fault decision for one reply about to be written.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplyFault {
    None,
    /// Sleep this long before writing the reply.
    Delay(Duration),
    /// Write a non-JSON line instead of the reply.
    Garble,
    /// Write a partial reply line, then drop the connection.
    DropConnection,
}

/// The injection seam.  Production code is generic over this trait and
/// monomorphized with [`NoFaults`], so the default bodies below compile
/// to nothing on the non-chaos path.
pub trait FaultHook: Send + Sync + 'static {
    /// Called once per connection attempt to `shard`.
    #[inline]
    fn connect_fault(&self, _shard: usize) -> ConnectFault {
        ConnectFault::None
    }

    /// Called once per reply about to be written for `shard`.
    #[inline]
    fn reply_fault(&self, _shard: usize) -> ReplyFault {
        ReplyFault::None
    }
}

/// The production hook: no faults, ever.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoFaults;

impl FaultHook for NoFaults {}

/// A [`FaultPlan`] armed with per-shard event counters — the live,
/// thread-safe [`FaultHook`] a chaos run injects.
pub struct ActiveFaults {
    plan: FaultPlan,
    connects: Vec<AtomicU64>,
    replies: Vec<AtomicU64>,
}

impl ActiveFaults {
    pub fn new(plan: FaultPlan) -> ActiveFaults {
        let n = plan.max_shard() + 1;
        ActiveFaults {
            plan,
            connects: (0..n).map(|_| AtomicU64::new(0)).collect(),
            replies: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    fn first_match(&self, shard: usize, event: u64, connect_class: bool) -> Option<FaultKind> {
        self.plan
            .rules
            .iter()
            .find(|r| r.kind.is_connect_class() == connect_class && r.matches(shard, event))
            .map(|r| r.kind)
    }
}

impl FaultHook for ActiveFaults {
    fn connect_fault(&self, shard: usize) -> ConnectFault {
        let Some(counter) = self.connects.get(shard) else {
            return ConnectFault::None;
        };
        let event = counter.fetch_add(1, Ordering::Relaxed);
        match self.first_match(shard, event, true) {
            Some(FaultKind::RefuseConnect) => ConnectFault::Refuse,
            Some(FaultKind::CloseAfter { replies }) => ConnectFault::CloseAfterReplies(replies),
            _ => ConnectFault::None,
        }
    }

    fn reply_fault(&self, shard: usize) -> ReplyFault {
        let Some(counter) = self.replies.get(shard) else {
            return ReplyFault::None;
        };
        let event = counter.fetch_add(1, Ordering::Relaxed);
        match self.first_match(shard, event, false) {
            Some(FaultKind::DelayReply { ms }) => ReplyFault::Delay(Duration::from_millis(ms)),
            Some(FaultKind::GarbleLine) => ReplyFault::Garble,
            Some(FaultKind::DropMidReply) => ReplyFault::DropConnection,
            _ => ReplyFault::None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_json_roundtrip_is_exact() {
        let plan = FaultPlan {
            seed: 42,
            rules: vec![
                FaultRule {
                    shard: 0,
                    kind: FaultKind::RefuseConnect,
                    from: 0,
                    count: 2,
                },
                FaultRule {
                    shard: 1,
                    kind: FaultKind::DelayReply { ms: 150 },
                    from: 0,
                    count: u64::MAX,
                },
                FaultRule {
                    shard: 0,
                    kind: FaultKind::CloseAfter { replies: 5 },
                    from: 3,
                    count: 1,
                },
            ],
        };
        let text = plan.to_json().to_string();
        let back = FaultPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.seed, 42);
        assert_eq!(back.rules.len(), 3);
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn malformed_plans_are_config_errors() {
        for bad in [
            r#"{"rules":[{"shard":0,"kind":"mystery"}]}"#,
            r#"{"rules":[{"shard":0,"kind":"delay_reply"}]}"#,
            r#"{"rules":[{"shard":0,"kind":"close_after"}]}"#,
            r#"{"version":9,"rules":[]}"#,
            r#"{"rules":[{"shard":0,"kind":"refuse_connect","from":-1}]}"#,
        ] {
            let err = FaultPlan::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code(), "bad_request", "{bad}");
        }
    }

    #[test]
    fn counters_drive_fault_windows_deterministically() {
        let plan = FaultPlan::from_json(
            &Json::parse(
                r#"{"rules":[
                    {"shard":0,"kind":"refuse_connect","from":0,"count":2},
                    {"shard":0,"kind":"garble_line","from":1,"count":1},
                    {"shard":1,"kind":"delay_reply","ms":30}
                ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let hook = ActiveFaults::new(plan);

        // connect attempts 0 and 1 refused, 2+ clean
        assert_eq!(hook.connect_fault(0), ConnectFault::Refuse);
        assert_eq!(hook.connect_fault(0), ConnectFault::Refuse);
        assert_eq!(hook.connect_fault(0), ConnectFault::None);

        // shard 0 replies: only event 1 garbled
        assert_eq!(hook.reply_fault(0), ReplyFault::None);
        assert_eq!(hook.reply_fault(0), ReplyFault::Garble);
        assert_eq!(hook.reply_fault(0), ReplyFault::None);

        // shard 1: every reply delayed (count defaults to forever)
        for _ in 0..4 {
            assert_eq!(
                hook.reply_fault(1),
                ReplyFault::Delay(Duration::from_millis(30))
            );
        }

        // shards beyond the plan never fault
        assert_eq!(hook.connect_fault(7), ConnectFault::None);
        assert_eq!(hook.reply_fault(7), ReplyFault::None);
    }

    #[test]
    fn generate_is_seed_deterministic() {
        let a = FaultPlan::generate(0xc4a0_5001, 3, 8);
        let b = FaultPlan::generate(0xc4a0_5001, 3, 8);
        let c = FaultPlan::generate(0xc4a0_5002, 3, 8);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_ne!(a.to_json().to_string(), c.to_json().to_string());
        assert_eq!(a.rules.len(), 8);
        assert!(a.rules.iter().all(|r| r.shard < 3));
    }

    #[test]
    fn no_faults_hook_is_inert() {
        assert_eq!(NoFaults.connect_fault(0), ConnectFault::None);
        assert_eq!(NoFaults.reply_fault(0), ReplyFault::None);
    }
}
