//! Configuration system: experiment + coordinator settings with JSON
//! file loading, CLI overrides and validation.
//!
//! ## Measure specs in config files
//!
//! Wherever a config names a measure it uses the serializable
//! [`MeasureSpec`] JSON shape (one `"kind"` discriminator plus that
//! kind's parameters) — the same object the TCP protocol v2 `dist` /
//! `kernel` / `register_measure` ops accept:
//!
//! ```json
//! {"kind":"euclidean"}                       {"kind":"minkowski","p":3}
//! {"kind":"corr"}                            {"kind":"daco","lags":10}
//! {"kind":"dtw"}                             {"kind":"banded_dtw","band_cells":12}
//! {"kind":"sakoe_chiba","band_pct":10}       {"kind":"itakura"}
//! {"kind":"krdtw","nu":0.5,"band_cells":8}   {"kind":"kga","nu":0.5}
//! {"kind":"spdtw","grid":{"kind":"corridor","t":60,"band":5}}
//! {"kind":"spkrdtw","nu":0.5,"grid":{"kind":"learned","theta":0.5,"gamma":0}}
//! ```
//!
//! Grid references inside `spdtw`/`spkrdtw` specs are
//! `{"kind":"full","t":T}`, `{"kind":"corridor","t":T,"band":B}`,
//! `{"kind":"learned","theta":θ,"gamma":γ}` (resolved against a train
//! set) or `{"kind":"registered","key":K}` (a coordinator
//! `register_grid` key; wire only).  Parameters are validated when the
//! spec is parsed, and every f64 round-trips JSON ⇄ typed bit-exactly.
//! [`SearchConfig::measure`] consumes this shape to pick the index
//! family for `spdtw search`.

pub mod cli;

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::measures::spec::MeasureSpec;
use crate::pool;
use crate::util::json::Json;

/// Settings for experiment runs (tables/figures regeneration).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Master seed for the synthetic archive + all tie-breaking RNGs.
    pub seed: u64,
    /// Stratified caps applied to every dataset split (`--full` lifts
    /// them to the Table-I sizes).
    pub max_train: usize,
    pub max_test: usize,
    /// Run the full Table-I sizes (can take many hours for the biggest
    /// datasets — the paper's own protocol).
    pub full: bool,
    /// Worker threads.
    pub threads: usize,
    /// Datasets to include (empty = all 30).
    pub datasets: Vec<String>,
    /// Output directory for reports, figures, JSON results.
    pub out_dir: PathBuf,
    /// Artifacts directory for the PJRT backend.
    pub artifacts_dir: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 42,
            max_train: 40,
            max_test: 60,
            full: false,
            threads: pool::default_threads(),
            datasets: Vec::new(),
            out_dir: PathBuf::from("out"),
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file; missing fields fall back to defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = json.get("seed").and_then(Json::as_usize) {
            cfg.seed = v as u64;
        }
        if let Some(v) = json.get("max_train").and_then(Json::as_usize) {
            cfg.max_train = v;
        }
        if let Some(v) = json.get("max_test").and_then(Json::as_usize) {
            cfg.max_test = v;
        }
        if let Some(v) = json.get("full").and_then(Json::as_bool) {
            cfg.full = v;
        }
        if let Some(v) = json.get("threads").and_then(Json::as_usize) {
            cfg.threads = v;
        }
        if let Some(arr) = json.get("datasets").and_then(Json::as_arr) {
            cfg.datasets = arr
                .iter()
                .filter_map(|d| d.as_str().map(String::from))
                .collect();
        }
        if let Some(v) = json.get("out_dir").and_then(Json::as_str) {
            cfg.out_dir = PathBuf::from(v);
        }
        if let Some(v) = json.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(v);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.threads == 0 {
            return Err(Error::config("threads must be >= 1"));
        }
        if self.max_train < 2 && !self.full {
            return Err(Error::config("max_train must be >= 2"));
        }
        for d in &self.datasets {
            if crate::data::registry::find(d).is_none() {
                return Err(Error::Unknown {
                    kind: "dataset",
                    name: d.clone(),
                });
            }
        }
        Ok(())
    }

    /// Dataset list resolved against the registry (empty = all).
    pub fn dataset_names(&self) -> Vec<&str> {
        if self.datasets.is_empty() {
            crate::data::registry::names()
        } else {
            self.datasets.iter().map(String::as_str).collect()
        }
    }

    /// Effective split caps.
    pub fn caps(&self) -> (usize, usize) {
        if self.full {
            (usize::MAX, usize::MAX)
        } else {
            (self.max_train, self.max_test)
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("max_train", Json::num(self.max_train as f64)),
            ("max_test", Json::num(self.max_test as f64)),
            ("full", Json::Bool(self.full)),
            ("threads", Json::num(self.threads as f64)),
            (
                "datasets",
                Json::arr(self.datasets.iter().map(|d| Json::str(d.clone()))),
            ),
            ("out_dir", Json::str(self.out_dir.display().to_string())),
            (
                "artifacts_dir",
                Json::str(self.artifacts_dir.display().to_string()),
            ),
        ])
    }
}

/// Similarity-search settings: cascade stage toggles + query shape
/// (the `spdtw search` CLI knobs; see `search::Cascade`).
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Neighbors per query.
    pub k: usize,
    /// Sakoe-Chiba band in *cells* for the banded-DTW engine;
    /// `usize::MAX` = unconstrained DTW.
    pub band_cells: usize,
    /// Cascade stage toggles (all default on).
    pub kim: bool,
    pub keogh: bool,
    pub keogh_rev: bool,
    pub early_abandon: bool,
    pub order_by_lb: bool,
    /// z-normalize train series at index build and queries at query
    /// time (banded-DTW indexes only).
    pub znormalize: bool,
    /// Load the index from this `.spix` file (`search::persist`)
    /// instead of building one — the warm-start path for `spdtw search`
    /// and the default destination of `spdtw index save`.
    pub index_file: Option<PathBuf>,
    /// Searchable measure the index should evaluate (module docs have
    /// the JSON shape).  `None` falls back to banded DTW over
    /// [`Self::band_cells`]; a spec here takes precedence.
    pub measure: Option<MeasureSpec>,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            k: 1,
            band_cells: usize::MAX,
            kim: true,
            keogh: true,
            keogh_rev: true,
            early_abandon: true,
            order_by_lb: true,
            znormalize: false,
            index_file: None,
            measure: None,
        }
    }
}

impl SearchConfig {
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            return Err(Error::config("search k must be >= 1"));
        }
        if let Some(m) = &self.measure {
            m.validate()?;
        }
        Ok(())
    }

    /// The measure spec the search index should be built for:
    /// [`Self::measure`] verbatim when set, otherwise the banded-DTW
    /// family [`Self::band_cells`] describes (`usize::MAX` =
    /// unconstrained DTW).
    pub fn index_spec(&self) -> MeasureSpec {
        match &self.measure {
            Some(m) => m.clone(),
            None if self.band_cells == usize::MAX => MeasureSpec::Dtw,
            None => MeasureSpec::BandedDtw { band_cells: self.band_cells },
        }
    }

    /// The stage-toggle view consumed by the engine.
    pub fn cascade(&self) -> crate::search::Cascade {
        crate::search::Cascade {
            kim: self.kim,
            keogh: self.keogh,
            keogh_rev: self.keogh_rev,
            early_abandon: self.early_abandon,
            order_by_lb: self.order_by_lb,
        }
    }

    /// Load from JSON; missing fields fall back to defaults
    /// (`band_cells` omitted or null means unconstrained).
    pub fn from_json(json: &Json) -> Result<Self> {
        let mut cfg = SearchConfig::default();
        if let Some(v) = json.get("k").and_then(Json::as_usize) {
            cfg.k = v;
        }
        if let Some(v) = json.get("band_cells").and_then(Json::as_usize) {
            cfg.band_cells = v;
        }
        let flag = |key: &str, default: bool| -> bool {
            json.get(key).and_then(Json::as_bool).unwrap_or(default)
        };
        cfg.kim = flag("kim", cfg.kim);
        cfg.keogh = flag("keogh", cfg.keogh);
        cfg.keogh_rev = flag("keogh_rev", cfg.keogh_rev);
        cfg.early_abandon = flag("early_abandon", cfg.early_abandon);
        cfg.order_by_lb = flag("order_by_lb", cfg.order_by_lb);
        cfg.znormalize = flag("znormalize", cfg.znormalize);
        if let Some(v) = json.get("index_file").and_then(Json::as_str) {
            cfg.index_file = Some(PathBuf::from(v));
        }
        if let Some(m) = json.get("measure") {
            cfg.measure = Some(MeasureSpec::from_json(m)?);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("k", Json::num(self.k as f64)),
            ("kim", Json::Bool(self.kim)),
            ("keogh", Json::Bool(self.keogh)),
            ("keogh_rev", Json::Bool(self.keogh_rev)),
            ("early_abandon", Json::Bool(self.early_abandon)),
            ("order_by_lb", Json::Bool(self.order_by_lb)),
            ("znormalize", Json::Bool(self.znormalize)),
        ];
        if self.band_cells != usize::MAX {
            fields.push(("band_cells", Json::num(self.band_cells as f64)));
        }
        if let Some(p) = &self.index_file {
            fields.push(("index_file", Json::str(p.display().to_string())));
        }
        if let Some(m) = &self.measure {
            fields.push(("measure", m.to_json()));
        }
        Json::obj(fields)
    }
}

/// The shard identity of a coordinator participating in a sharded
/// fleet (`spdtw shard-serve`): this server owns shard `shard_id` of
/// `shards_total`.  A coordinator with a role serves the `shard_search`
/// fan-out op and accepts sharded `register_index` requests for its own
/// shard id only (see `crate::shard` for the topology and exactness
/// argument).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRole {
    pub shard_id: usize,
    pub shards_total: usize,
}

/// Coordinator service settings.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads executing batches.
    pub workers: usize,
    /// Max pairs per PJRT batch (must match an artifact's B to use the
    /// PJRT backend; the batcher pads the final partial batch).
    pub batch_size: usize,
    /// Flush a partial batch after this many microseconds of inactivity.
    pub flush_us: u64,
    /// Bound on queued batches (backpressure).
    pub queue_cap: usize,
    /// Prefer the PJRT backend when an artifact bucket matches.
    pub prefer_pjrt: bool,
    /// Directory of the persistent index store (`.spix` files recorded
    /// in its `manifest.json`, conventionally the artifacts dir so the
    /// indexes live next to the PJRT manifest).  `None` disables
    /// persistence entirely.
    pub index_store: Option<PathBuf>,
    /// Reload every store-manifest index at boot (no-op without
    /// `index_store`).  Corrupt or stale files are rejected and skipped,
    /// never served.
    pub warm_start: bool,
    /// Byte budget for the on-disk index store.  When a save pushes the
    /// store past this, least-recently-used `.spix` files (recency =
    /// last save or named lookup, oldest first; manifest entries never
    /// registered this session — e.g. stale files skipped at warm start
    /// — count as oldest of all) are evicted — file and manifest entry
    /// removed, counted in `index_evictions` — until the store fits.
    /// The index just written is never evicted, even if it alone
    /// exceeds the budget.  Eviction is store-only: an in-memory
    /// registration keeps serving; the index simply won't warm-start.
    /// `None` (default) disables the budget.
    pub index_store_max_bytes: Option<u64>,
    /// This coordinator's identity in a sharded fleet (`None` = a
    /// plain single-node server; the fan-out ops are refused).
    pub shard: Option<ShardRole>,
    /// Shard server addresses for the *front* role (`spdtw serve
    /// --shards host:port,...`).  Consumed by the CLI to start a
    /// `shard::ShardCoordinator` instead of a local serving
    /// coordinator; mutually exclusive with [`Self::shard`].
    pub shards: Vec<String>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: pool::default_threads(),
            batch_size: 32,
            flush_us: 2_000,
            queue_cap: 64,
            prefer_pjrt: false,
            index_store: None,
            warm_start: true,
            index_store_max_bytes: None,
            shard: None,
            shards: Vec::new(),
        }
    }
}

impl CoordinatorConfig {
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.batch_size == 0 || self.queue_cap == 0 {
            return Err(Error::config(
                "workers, batch_size and queue_cap must be >= 1",
            ));
        }
        if self.index_store_max_bytes == Some(0) {
            return Err(Error::config(
                "index_store_max_bytes must be >= 1 (use None to disable)",
            ));
        }
        if let Some(role) = &self.shard {
            if role.shards_total == 0 {
                return Err(Error::config("shards_total must be >= 1"));
            }
            if role.shard_id >= role.shards_total {
                return Err(Error::config(format!(
                    "shard_id {} out of range (shards_total {})",
                    role.shard_id, role.shards_total
                )));
            }
            if !self.shards.is_empty() {
                return Err(Error::config(
                    "a process is either a shard server (shard) or a fan-out \
                     front (shards), not both",
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_valid() {
        ExperimentConfig::default().validate().unwrap();
        CoordinatorConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = 7;
        cfg.datasets = vec!["CBF".into(), "Wine".into()];
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.seed, 7);
        assert_eq!(back.datasets, cfg.datasets);
    }

    #[test]
    fn rejects_unknown_dataset() {
        let j = Json::parse(r#"{"datasets": ["NotReal"]}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_zero_threads() {
        let j = Json::parse(r#"{"threads": 0}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn search_config_roundtrip_and_validation() {
        let mut cfg = SearchConfig::default();
        cfg.k = 3;
        cfg.band_cells = 12;
        cfg.keogh_rev = false;
        let back = SearchConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.k, 3);
        assert_eq!(back.band_cells, 12);
        assert!(!back.keogh_rev && back.kim);

        // omitted band_cells means unconstrained
        let open = SearchConfig::from_json(&Json::parse(r#"{"k":2}"#).unwrap()).unwrap();
        assert_eq!(open.band_cells, usize::MAX);
        assert_eq!(open.index_file, None);

        // index_file roundtrips
        let mut with_file = SearchConfig::default();
        with_file.index_file = Some(PathBuf::from("store/cbf.spix"));
        let back = SearchConfig::from_json(&with_file.to_json()).unwrap();
        assert_eq!(back.index_file, Some(PathBuf::from("store/cbf.spix")));

        assert!(SearchConfig::from_json(&Json::parse(r#"{"k":0}"#).unwrap()).is_err());

        let cas = cfg.cascade();
        assert!(cas.kim && !cas.keogh_rev && cas.early_abandon);
    }

    #[test]
    fn search_config_measure_spec_roundtrip_and_precedence() {
        // no measure: band_cells drives the spec
        let mut cfg = SearchConfig::default();
        assert_eq!(cfg.index_spec(), MeasureSpec::Dtw);
        cfg.band_cells = 7;
        assert_eq!(cfg.index_spec(), MeasureSpec::BandedDtw { band_cells: 7 });

        // an explicit spec wins and round-trips through JSON
        cfg.measure = Some(MeasureSpec::SakoeChiba { band_pct: 12.5 });
        let back = SearchConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.measure, cfg.measure);
        assert_eq!(back.index_spec(), MeasureSpec::SakoeChiba { band_pct: 12.5 });

        // invalid specs are rejected at parse time
        let bad = Json::parse(r#"{"measure":{"kind":"krdtw","nu":-1}}"#).unwrap();
        assert!(SearchConfig::from_json(&bad).is_err());
        let unknown = Json::parse(r#"{"measure":{"kind":"zzz"}}"#).unwrap();
        assert!(SearchConfig::from_json(&unknown).is_err());
    }

    #[test]
    fn shard_role_validation() {
        let mut cfg = CoordinatorConfig::default();
        cfg.shard = Some(ShardRole {
            shard_id: 0,
            shards_total: 2,
        });
        cfg.validate().unwrap();
        cfg.shard = Some(ShardRole {
            shard_id: 2,
            shards_total: 2,
        });
        assert!(cfg.validate().is_err());
        cfg.shard = Some(ShardRole {
            shard_id: 0,
            shards_total: 0,
        });
        assert!(cfg.validate().is_err());
        // shard server and fan-out front are mutually exclusive roles
        cfg.shard = Some(ShardRole {
            shard_id: 0,
            shards_total: 1,
        });
        cfg.shards = vec!["127.0.0.1:1".into()];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn caps_full_mode() {
        let mut cfg = ExperimentConfig::default();
        cfg.full = true;
        assert_eq!(cfg.caps(), (usize::MAX, usize::MAX));
    }
}
