//! Hand-rolled CLI argument parser (clap is not in the vendored crate
//! set).  Supports `--flag`, `--key value`, `--key=value`, positional
//! arguments, and generates usage text from a declarative spec.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declarative option spec for usage/help text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name) against a spec.
    pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let known = spec.iter().find(|s| s.name == key);
                match known {
                    None => {
                        return Err(Error::config(format!(
                            "unknown option --{key}\n{}",
                            usage(spec)
                        )))
                    }
                    Some(s) if s.takes_value => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| Error::config(format!("--{key} needs a value")))?
                            }
                        };
                        out.options.insert(key, val);
                    }
                    Some(_) => {
                        if inline_val.is_some() {
                            return Err(Error::config(format!("--{key} takes no value")));
                        }
                        out.flags.push(key);
                    }
                }
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| Error::config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| Error::config(format!("--{name} expects a number, got '{v}'"))),
        }
    }
}

/// Render usage text for a spec.
pub fn usage(spec: &[OptSpec]) -> String {
    let mut out = String::from("options:\n");
    for s in spec {
        let val = if s.takes_value { " <value>" } else { "" };
        out.push_str(&format!("  --{}{:<14} {}\n", s.name, val, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "seed", takes_value: true, help: "rng seed" },
            OptSpec { name: "full", takes_value: false, help: "full sizes" },
            OptSpec { name: "gamma", takes_value: true, help: "weight exponent" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let argv = sv(&["run", "--seed", "7", "--full", "--gamma=2.5", "CBF"]);
        let a = Args::parse(&argv, &spec()).unwrap();
        assert_eq!(a.positional, vec!["run", "CBF"]);
        assert_eq!(a.get_usize("seed").unwrap(), Some(7));
        assert!(a.flag("full"));
        assert_eq!(a.get_f64("gamma").unwrap(), Some(2.5));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&sv(&["--nope"]), &spec()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&sv(&["--seed"]), &spec()).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["--full=yes"]), &spec()).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let a = Args::parse(&sv(&["--seed", "abc"]), &spec()).unwrap();
        assert!(a.get_usize("seed").is_err());
    }
}
