//! Deterministic pseudo-random number generation.
//!
//! PCG64 (O'Neill's PCG-XSL-RR 128/64) seeded through SplitMix64 — small,
//! fast, and with exactly reproducible streams across platforms, which the
//! synthetic UCR archive depends on (every generated dataset is a pure
//! function of its name + seed).

/// Permuted congruential generator, 128-bit state / 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream derived via SplitMix).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let i = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let mut rng = Pcg64 {
            state: 0,
            inc: (i << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(s);
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (for per-dataset / per-class use).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine here: the
        // bias for n << 2^64 is negligible for data generation.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity — generation cost is irrelevant at our scales).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// SplitMix64 — used for seeding and cheap hashing-style streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// Stable 64-bit hash of a string (FNV-1a), for deriving per-name seeds.
pub fn hash64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(9);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn hash64_stable() {
        assert_eq!(hash64("CBF"), hash64("CBF"));
        assert_ne!(hash64("CBF"), hash64("Beef"));
    }
}
