//! Tiny wall-clock timing helpers used by the bench harness and metrics.

use std::time::{Duration, Instant};

/// Measure the wall-clock duration of a closure, returning (result, dt).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// A stopwatch accumulating named segments (coarse profiling in examples).
#[derive(Default)]
pub struct Stopwatch {
    segments: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` and record its duration under `name`.
    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, dt) = time_it(f);
        self.segments.push((name.to_string(), dt));
        out
    }

    pub fn segments(&self) -> &[(String, Duration)] {
        &self.segments
    }

    /// Render a small aligned report.
    pub fn report(&self) -> String {
        let total: Duration = self.segments.iter().map(|(_, d)| *d).sum();
        let mut out = String::new();
        for (name, d) in &self.segments {
            let pct = if total.as_nanos() > 0 {
                100.0 * d.as_secs_f64() / total.as_secs_f64()
            } else {
                0.0
            };
            out.push_str(&format!("{name:<28} {:>10.3} ms  {pct:>5.1}%\n", d.as_secs_f64() * 1e3));
        }
        out.push_str(&format!("{:<28} {:>10.3} ms\n", "TOTAL", total.as_secs_f64() * 1e3));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        let v = sw.measure("work", || 2 + 2);
        assert_eq!(v, 4);
        assert_eq!(sw.segments().len(), 1);
        assert!(sw.report().contains("work"));
    }
}
