//! Math & statistics helpers shared across the crate.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_pop(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation; input need not be
/// sorted.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Error function (Abramowitz & Stegun 7.1.26, |err| <= 1.5e-7) — enough
/// for Wilcoxon normal-approximation p-values.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Average ranks (1-based) with ties sharing the mean rank — the ranking
/// used by both the Wilcoxon test and the tables' "mean rank" rows.
pub fn avg_ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[order[j + 1]] == xs[order[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[order[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Smallest f64 strictly greater than `v` (NaN and +inf map to
/// themselves).  Used by the search engine's tie-exact abandon
/// threshold; in-tree because `f64::next_up` is not yet stable on the
/// pinned toolchain.
pub fn next_up_f64(v: f64) -> f64 {
    if v.is_nan() || v == f64::INFINITY {
        return v;
    }
    if v == 0.0 {
        return f64::from_bits(1); // smallest positive subnormal
    }
    let bits = v.to_bits();
    if v.is_sign_positive() {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

/// log(sum(exp(xs))) with the usual max-shift; NEG-safe.
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m <= -1.0e29 || m == f64::NEG_INFINITY {
        return -1.0e30;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_pop(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1e-6);
        assert!((norm_cdf(1.959_963_99) - 0.975).abs() < 1e-5);
    }

    #[test]
    fn ranks_with_ties() {
        let r = avg_ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn next_up_properties() {
        for v in [-2.5, -0.0, 0.0, 1.0, 1e30] {
            let up = next_up_f64(v);
            assert!(up > v, "next_up({v}) = {up} not greater");
            // nothing strictly between v and next_up(v)
            let mid = v + 0.5 * (up - v);
            assert!(mid == v || mid == up);
        }
        assert_eq!(next_up_f64(f64::INFINITY), f64::INFINITY);
        assert!(next_up_f64(f64::NAN).is_nan());
    }

    #[test]
    fn lse() {
        let v = logsumexp(&[0.0_f64.ln(), 1.0_f64.ln(), 2.0_f64.ln()]);
        assert!((v - 3.0_f64.ln()).abs() < 1e-12);
        assert!(logsumexp(&[-1e30, -1e30]) <= -1e29);
    }
}
