//! In-tree micro-benchmark harness (criterion is not in the vendored
//! crate set).  Used by every `rust/benches/*.rs` target via
//! `[[bench]] harness = false`, so `cargo bench` runs them unchanged.
//!
//! Discipline: warmup iterations, then timed samples; reports mean, σ,
//! p50/p95 and throughput.  Samples are wall-clock per *batch* of
//! `inner` iterations to keep timer overhead negligible for fast bodies.

use std::hint::black_box;
use std::time::Instant;

use crate::util::mathx::{mean, percentile, std_pop};

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }

    pub fn report_row(&self) -> String {
        format!(
            "{:<44} {:>12} {:>10} {:>10} {:>10} {:>12}",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            format!("{:.1}/s", self.per_sec()),
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with fixed sample counts (deterministic duration).
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 3,
            samples: 12,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bench {
            warmup,
            samples,
            ..Default::default()
        }
    }

    /// Time `f`, auto-choosing an inner batch size so one sample takes
    /// at least ~2 ms.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // calibrate
        let mut inner = 1usize;
        loop {
            let t0 = Instant::now();
            for _ in 0..inner {
                black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt >= 2e-3 || inner >= 1 << 20 {
                break;
            }
            inner = (inner * 2).max((inner as f64 * 2.5e-3 / dt.max(1e-9)) as usize);
        }
        for _ in 0..self.warmup {
            for _ in 0..inner {
                black_box(f());
            }
        }
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..inner {
                black_box(f());
            }
            per_iter.push(t0.elapsed().as_secs_f64() / inner as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            mean_s: mean(&per_iter),
            std_s: std_pop(&per_iter),
            p50_s: percentile(&per_iter, 50.0),
            p95_s: percentile(&per_iter, 95.0),
            samples: self.samples,
            iters_per_sample: inner,
        };
        println!("{}", res.report_row());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn header(title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>12} {:>10} {:>10} {:>10} {:>12}",
            "benchmark", "mean", "σ", "p50", "p95", "rate"
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new(1, 4);
        let r = b.run("noop-ish", || (0..100).sum::<u64>());
        assert!(r.mean_s >= 0.0);
        assert!(r.iters_per_sample >= 1);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
