//! Minimal JSON parser + serializer.
//!
//! Covers the subset this project needs (config files, the artifact
//! manifest, results/metrics dumps, the TCP wire protocol): objects,
//! arrays, strings with escapes, numbers, booleans, null.  No serde in
//! the vendored crate set — see DESIGN.md §2.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — results files diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Maximum container nesting depth accepted by [`Json::parse`].
///
/// The parser descends recursively per `[`/`{`, so an unbounded input
/// like `"[[[["…` ×100k would otherwise overflow the thread stack — a
/// remote crash for anything feeding untrusted bytes to the wire
/// protocol (found by the `fuzz_wire` fuzz target; regression-tested in
/// `parse_depth_is_bounded` below and the protocol malformed-envelope
/// matrix).  128 is far beyond any legitimate request: v2 envelopes
/// nest at most ~6 levels (`params.grid.entries[...]`).
pub const MAX_PARSE_DEPTH: usize = 128;

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
            depth: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Required-field helpers that produce useful errors.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::config(format!("missing string field '{key}'")))
    }
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::config(format!("missing integer field '{key}'")))
    }
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::config(format!("missing number field '{key}'")))
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::config(format!("missing array field '{key}'")))
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// Current container nesting depth (bounded by [`MAX_PARSE_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            c @ (b'{' | b'[') => {
                // Each container level is one stack frame of recursion;
                // cap it so adversarial inputs ("[[[["… to the wire
                // protocol) error out instead of overflowing the stack.
                if self.depth >= MAX_PARSE_DEPTH {
                    return Err(self.err("nesting too deep"));
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                _ => {
                    // copy a run of plain bytes (handles multi-byte utf8)
                    let start = self.i;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.req_arr("a").unwrap();
        assert_eq!(arr[2].req_str("b").unwrap(), "x");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"batch":32,"kernel":"dtw","length":60}],"version":1}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn accessor_errors_are_informative() {
        let v = Json::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.req_usize("n").is_err()); // fractional
        assert!(v.req_str("missing").is_err());
    }

    /// Regression (fuzz_wire finding): parsing recursed once per `[`/`{`
    /// with no bound, so a ~100k-deep input overflowed the thread stack —
    /// a remote crash through the TCP protocol.  Deep nesting must now be
    /// a typed `Error::Json` ("nesting too deep"), never an abort.
    #[test]
    fn parse_depth_is_bounded() {
        for (open, close) in [("[", "]"), (r#"{"k":"#, "}")] {
            // one past the cap: typed error
            let deep = format!(
                "{}1{}",
                open.repeat(MAX_PARSE_DEPTH + 1),
                close.repeat(MAX_PARSE_DEPTH + 1)
            );
            match Json::parse(&deep) {
                Err(Error::Json { msg, .. }) => assert!(msg.contains("nesting too deep")),
                other => panic!("expected depth error, got {other:?}"),
            }
            // grossly past the cap (the fuzz shape): still a typed error,
            // and crucially no stack overflow
            let hostile = open.repeat(100_000);
            assert!(Json::parse(&hostile).is_err());
        }
        // at the cap: still parses
        let ok = format!(
            "{}1{}",
            "[".repeat(MAX_PARSE_DEPTH),
            "]".repeat(MAX_PARSE_DEPTH)
        );
        assert!(Json::parse(&ok).is_ok());
    }
}
