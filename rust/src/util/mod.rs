//! Support substrates: seeded RNG, minimal JSON, math/stat helpers, and
//! the in-tree bench + property-testing harnesses (the vendored crate set
//! has no rand/serde/criterion/proptest — see DESIGN.md §2).

pub mod bench;
pub mod json;
pub mod mathx;
pub mod prop;
pub mod rng;
pub mod timer;
