//! Property-testing harness (proptest is not in the vendored crate set).
//!
//! Seeded random case generation with a simple halving shrinker for
//! numeric/vector inputs.  Each `forall_*` helper runs `N_CASES` cases;
//! on failure it tries to shrink the input and panics with the minimal
//! reproduction plus the seed, so failures are replayable.

use crate::util::rng::Pcg64;

pub const N_CASES: usize = 64;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Honor SPDTW_PROP_SEED for replaying failures.
        let seed = std::env::var("SPDTW_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xdead_beef);
        PropConfig {
            cases: N_CASES,
            seed,
        }
    }
}

/// Run `prop` over `cases` random f64 vectors with lengths in
/// `[min_len, max_len]` and values in `[-scale, scale]`.
pub fn forall_vec(
    cfg: &PropConfig,
    min_len: usize,
    max_len: usize,
    scale: f64,
    mut prop: impl FnMut(&[f64]) -> bool,
) {
    let mut rng = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let len = min_len + rng.below(max_len - min_len + 1);
        let xs: Vec<f64> = (0..len).map(|_| rng.range(-scale, scale)).collect();
        if !prop(&xs) {
            // shrink: halve the vector while the property still fails
            let mut cur = xs.clone();
            loop {
                if cur.len() <= min_len.max(1) {
                    break;
                }
                let half: Vec<f64> = cur[..cur.len() / 2].to_vec();
                if half.len() >= min_len && !prop(&half) {
                    cur = half;
                } else {
                    let tail: Vec<f64> = cur[cur.len() / 2..].to_vec();
                    if tail.len() >= min_len && !prop(&tail) {
                        cur = tail;
                    } else {
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {}):\n  minimal input ({} elems): {:?}",
                cfg.seed,
                cur.len(),
                &cur[..cur.len().min(32)]
            );
        }
    }
}

/// Run `prop` over `cases` random *pairs* of equal-length vectors.
pub fn forall_pairs(
    cfg: &PropConfig,
    min_len: usize,
    max_len: usize,
    scale: f64,
    mut prop: impl FnMut(&[f64], &[f64]) -> bool,
) {
    let mut rng = Pcg64::new(cfg.seed ^ 0x5bd1_e995);
    for case in 0..cfg.cases {
        let len = min_len + rng.below(max_len - min_len + 1);
        let xs: Vec<f64> = (0..len).map(|_| rng.range(-scale, scale)).collect();
        let ys: Vec<f64> = (0..len).map(|_| rng.range(-scale, scale)).collect();
        if !prop(&xs, &ys) {
            panic!(
                "pair property failed (case {case}, seed {}): len={len}\n  x={:?}\n  y={:?}",
                cfg.seed,
                &xs[..len.min(24)],
                &ys[..len.min(24)]
            );
        }
    }
}

/// Run `prop` over random usize tuples (for batching/queueing invariants).
pub fn forall_usizes(
    cfg: &PropConfig,
    ranges: &[(usize, usize)],
    mut prop: impl FnMut(&[usize]) -> bool,
) {
    let mut rng = Pcg64::new(cfg.seed ^ 0xc2b2_ae35);
    for case in 0..cfg.cases {
        let vals: Vec<usize> = ranges
            .iter()
            .map(|&(lo, hi)| lo + rng.below(hi - lo + 1))
            .collect();
        if !prop(&vals) {
            panic!(
                "usize property failed (case {case}, seed {}): {vals:?}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall_vec(&PropConfig::default(), 1, 10, 5.0, |xs| {
            count += 1;
            xs.len() <= 10
        });
        assert_eq!(count, N_CASES);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall_vec(&PropConfig::default(), 1, 16, 5.0, |xs| xs.len() < 8);
    }

    #[test]
    fn pair_lengths_match() {
        forall_pairs(&PropConfig::default(), 2, 12, 1.0, |x, y| x.len() == y.len());
    }
}
