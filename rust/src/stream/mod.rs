//! Online subsequence k-NN over unbounded streams (ROADMAP item 4).
//!
//! A [`StreamMonitor`] ingests one sample at a time into a ring buffer
//! and, once the window is full, answers "which indexed series does the
//! last `T` samples most resemble" at every step, UCR-suite style:
//! the exact LB_Kim → LB_Keogh → reversed LB_Keogh → early-abandoning
//! DP cascade of [`crate::search::SearchEngine`] runs per window, with
//! the *query-side* Lemire envelope maintained incrementally by a
//! [`SlidingEnvelope`] — monotonic deques updated per sample, never
//! rebuilt — instead of the batch path's per-query `envelope_into`.
//!
//! ## Exactness contract
//!
//! The streaming match at every step is **bit-identical**
//! (`f64::to_bits`, neighbors *and* `PruneStats`) to a batch
//! `SearchEngine::knn_values_with` call over the same window:
//!
//! * the staged window is a plain copy of the ring contents, so the DP
//!   stages see exactly the bytes a batch query would;
//! * the sliding envelope selects exactly the sample `envelope_into`'s
//!   deque front would select at every position (the same keep-latest
//!   tie rule everywhere — see [`SlidingEnvelope`]), so the staged
//!   `(upper, lower)` halves are bit-identical to a from-scratch
//!   rebuild (property: `tests/prop_stream.rs`);
//! * for a z-normalized index the window statistics change at *every*
//!   step, so no envelope can be maintained incrementally in the
//!   normalized domain — the monitor routes those windows through the
//!   engine's own normalize-then-envelope path (`knn_values_with`),
//!   which is the batch code itself.
//!
//! ## Approximate pre-filter
//!
//! With an [`RwsConfig`], windows first pass through a Random Warping
//! Series embedding ([`rws`], arXiv 1809.05259): a linear scan in R^d
//! selects a candidate subset, and the exact cascade refines only that
//! subset (`SearchEngine::knn_among_with`).  Approximate reports are
//! always flagged (`MatchReport::approx`) and periodically audited
//! against the exact path (`recall@k`); the exact path is the default.
//! When the candidate budget covers the corpus the refine step scans
//! every series and the result is bit-identical to the exact path.

pub mod rws;

use std::collections::VecDeque;

use crate::data::znormalize_in_place;
use crate::error::{Error, Result};
use crate::measures::workspace::DpWorkspace;
use crate::search::engine::Neighbor;
use crate::search::{PruneStats, SearchEngine};

pub use rws::{RwsConfig, RwsFilter};

/// Sliding-window Lemire envelope: for a stream whose last `t` samples
/// form the current window, maintains per-position `(upper, lower)`
/// envelope values under warping radius `r`, updated per sample.
///
/// Window position `i`'s envelope range is `[max(i-r, 0), min(i+r,
/// t-1)]` — exactly `envelope_into`'s.  Interior positions (`r <= i <=
/// t-1-r`) have ranges that are fixed absolute sample spans, so their
/// extrema are computed once, when the last sample of the span arrives,
/// from a pair of *global* monotonic deques over the most recent `2r+1`
/// samples and cached in a ring.  Edge positions clamp against the
/// moving window boundary and are rebuilt per step by O(r) running
/// scans.  Everywhere the tie rule is keep-latest — the sample
/// `envelope_into`'s deque front holds — so staged values are
/// bit-identical to a from-scratch rebuild even when equal values have
/// distinct bit patterns (±0.0).
#[derive(Debug)]
pub struct SlidingEnvelope {
    t: usize,
    r: usize,
    /// Absolute sample indices, values descending (max) / ascending
    /// (min) from front to back; fronts hold the latest extremum of the
    /// trailing `2r+1` samples.
    maxq: VecDeque<usize>,
    minq: VecDeque<usize>,
    /// Interior extrema, keyed by absolute center index mod `t`.
    umax: Vec<f64>,
    umin: Vec<f64>,
}

impl SlidingEnvelope {
    /// Envelope for window length `t` (>= 1) at radius `r` (clamped to
    /// `t - 1`, the widest reach any position can use).
    pub fn new(t: usize, r: usize) -> SlidingEnvelope {
        assert!(t > 0, "window length must be >= 1");
        // lint:allow(hot-alloc): constructor-time ring buffers, reused
        // on every per-sample update afterwards.
        let umax = vec![0.0; t];
        // lint:allow(hot-alloc): constructor-time ring buffer (see above).
        let umin = vec![0.0; t];
        SlidingEnvelope {
            t,
            r: r.min(t - 1),
            maxq: VecDeque::new(),
            minq: VecDeque::new(),
            umax,
            umin,
        }
    }

    /// Whether the incremental (deque + interior ring) machinery is in
    /// play.  A degenerate radius (`2r >= t`) leaves no interior
    /// positions and would need more than `t` samples of history, so
    /// [`Self::stage_into`] recomputes those windows with two O(t)
    /// running passes instead.
    #[inline]
    pub fn sliding(&self) -> bool {
        2 * self.r < self.t
    }

    /// Ingest sample `p` (0-based absolute stream index); `ring` is the
    /// stream's value ring (`ring[p % t]` already holds the sample).
    /// O(1) amortized: each index enters and leaves each deque once.
    pub fn push(&mut self, p: usize, ring: &[f64]) {
        debug_assert_eq!(ring.len(), self.t);
        if !self.sliding() {
            return;
        }
        let t = self.t;
        let r = self.r;
        let v = ring[p % t];
        // Keep-latest: an equal earlier sample is popped, so the front
        // always names the latest occurrence of the extremum.
        while self.maxq.back().map_or(false, |&b| ring[b % t] <= v) {
            self.maxq.pop_back();
        }
        self.maxq.push_back(p);
        while self.minq.back().map_or(false, |&b| ring[b % t] >= v) {
            self.minq.pop_back();
        }
        self.minq.push_back(p);
        let lo = p.saturating_sub(2 * r);
        while self.maxq.front().map_or(false, |&f| f < lo) {
            self.maxq.pop_front();
        }
        while self.minq.front().map_or(false, |&f| f < lo) {
            self.minq.pop_front();
        }
        if p >= 2 * r {
            // Sample p completes the absolute span [p-2r, p]: the
            // envelope range of interior center c = p - r, final from
            // here on.  2r < t keeps every deque index inside the ring.
            let c = p - r;
            self.umax[c % t] = ring[*self.maxq.front().expect("deque never empty") % t];
            self.umin[c % t] = ring[*self.minq.front().expect("deque never empty") % t];
        }
    }

    /// Write the envelope of the current window into `upper`/`lower`.
    /// `p` is the latest absolute sample index (window = samples
    /// `p+1-t ..= p`); `window` is the contiguously staged window.
    /// Output is bit-identical to `envelope_into(window, r, ..)`.
    pub fn stage_into(
        &self,
        p: usize,
        window: &[f64],
        upper: &mut Vec<f64>,
        lower: &mut Vec<f64>,
    ) {
        let t = self.t;
        let r = self.r;
        debug_assert_eq!(window.len(), t);
        debug_assert!(p + 1 >= t, "window not full");
        upper.clear();
        upper.resize(t, 0.0);
        lower.clear();
        lower.resize(t, 0.0);
        if !self.sliding() {
            // Degenerate radius: every position's range touches a
            // window edge, so a prefix pass (i <= r) plus a suffix pass
            // (i > r, where i >= t-1-r holds because 2r >= t) covers
            // every position.
            fill_prefix(window, r, upper, lower, r.min(t - 1) + 1);
            if r + 1 < t {
                fill_suffix(window, r, upper, lower, r + 1);
            }
            return;
        }
        fill_prefix(window, r, upper, lower, r);
        let start = p + 1 - t;
        for i in r..=(t - 1 - r) {
            let c = start + i;
            upper[i] = self.umax[c % t];
            lower[i] = self.umin[c % t];
        }
        fill_suffix(window, r, upper, lower, t - r);
    }
}

/// Envelope positions `0..i_end`: ranges `[0, min(i+r, t-1)]`, filled
/// by one forward running-extremum scan.  `>=`/`<=` updates keep the
/// latest occurrence of a tied extremum — the same sample
/// `envelope_into`'s deque front holds for these prefix ranges.
fn fill_prefix(window: &[f64], r: usize, upper: &mut [f64], lower: &mut [f64], i_end: usize) {
    if i_end == 0 {
        return;
    }
    let t = window.len();
    let mut mx = window[0];
    let mut mn = window[0];
    let mut j = 0usize; // running extrema cover window[0..=j]
    for i in 0..i_end {
        let hi = (i + r).min(t - 1);
        while j < hi {
            j += 1;
            if window[j] >= mx {
                mx = window[j];
            }
            if window[j] <= mn {
                mn = window[j];
            }
        }
        upper[i] = mx;
        lower[i] = mn;
    }
}

/// Envelope positions `i_start..t`: ranges `[i-r, t-1]`, filled by one
/// backward running-extremum scan.  Strict `>`/`<` updates keep the
/// rightmost (= latest) occurrence of a tied extremum, matching
/// `envelope_into`'s deque tie-break for these suffix ranges.
fn fill_suffix(window: &[f64], r: usize, upper: &mut [f64], lower: &mut [f64], i_start: usize) {
    let t = window.len();
    if i_start >= t {
        return;
    }
    let mut mx = window[t - 1];
    let mut mn = window[t - 1];
    let mut j = t - 1; // running extrema cover window[j..]
    for i in (i_start..t).rev() {
        let lo = i - r;
        while j > lo {
            j -= 1;
            if window[j] > mx {
                mx = window[j];
            }
            if window[j] < mn {
                mn = window[j];
            }
        }
        upper[i] = mx;
        lower[i] = mn;
    }
}

/// Rolling mean/std over the last `window` samples (sum/sum-of-squares
/// form) — the monitor's O(1) drift proxy.  *Not* bit-identical to the
/// batch two-pass [`crate::data::znormalize_in_place`] (different FP
/// operation order); agrees to ~1e-9 on sane data (property-tested),
/// which is why the exact match path re-normalizes the staged window
/// through the batch code instead of using these statistics.
#[derive(Clone, Copy, Debug)]
pub struct IncZnorm {
    window: usize,
    filled: usize,
    sum: f64,
    sumsq: f64,
}

impl IncZnorm {
    pub fn new(window: usize) -> IncZnorm {
        IncZnorm {
            window,
            filled: 0,
            sum: 0.0,
            sumsq: 0.0,
        }
    }

    /// Ingest `v`; `evicted` is the sample leaving the window (None
    /// while the window is still filling).
    pub fn push(&mut self, v: f64, evicted: Option<f64>) {
        self.sum += v;
        self.sumsq += v * v;
        match evicted {
            Some(o) => {
                self.sum -= o;
                self.sumsq -= o * o;
            }
            None => {
                debug_assert!(self.filled < self.window);
                self.filled += 1;
            }
        }
    }

    /// Samples currently covered (saturates at the window length).
    pub fn filled(&self) -> usize {
        self.filled
    }

    pub fn mean(&self) -> f64 {
        if self.filled == 0 {
            0.0
        } else {
            self.sum / self.filled as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.filled == 0 {
            return 0.0;
        }
        let m = self.mean();
        // E[x^2] - m^2 can dip below zero by rounding; clamp.
        let var = (self.sumsq / self.filled as f64 - m * m).max(0.0);
        var.sqrt()
    }
}

/// Aggregate counters over a monitor's lifetime — the streaming
/// counterpart of [`PruneStats`] (which it embeds, merged per window).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Samples ingested.
    pub samples: u64,
    /// Windows evaluated (= samples once the window is full).
    pub windows: u64,
    /// Windows answered by the exact cascade over the whole corpus.
    pub exact_windows: u64,
    /// Windows answered through the RWS candidate pre-filter.
    pub approx_windows: u64,
    /// Cascade counters merged across every served window (the serving
    /// path only — audit re-queries are excluded so prune rates reflect
    /// what the stream actually paid).
    pub prune: PruneStats,
    /// RWS recall audits run (approx path, every `audit_every` windows).
    pub rws_audits: u64,
    /// Sum of audited recall@k values (mean = the recall proxy).
    pub rws_recall_sum: f64,
    /// Rolling window mean/std at the last evaluated window
    /// ([`IncZnorm`]) — a drift signal for operators.
    pub last_mean: f64,
    pub last_std: f64,
}

impl StreamStats {
    /// Mean audited recall@k, if any audits ran.
    pub fn recall(&self) -> Option<f64> {
        if self.rws_audits == 0 {
            None
        } else {
            Some(self.rws_recall_sum / self.rws_audits as f64)
        }
    }

    pub fn report(&self) -> String {
        let recall = match self.recall() {
            Some(r) => format!("{r:.4} over {} audits", self.rws_audits),
            None => "n/a".to_string(),
        };
        format!(
            "samples: {}  windows: {} ({} exact, {} approx)\n\
             recall@k (audited): {recall}\n\
             window mean {:.4} std {:.4}\n\
             {}",
            self.samples,
            self.windows,
            self.exact_windows,
            self.approx_windows,
            self.last_mean,
            self.last_std,
            self.prune.report(),
        )
    }
}

/// One per-window match report.  `approx` is true iff the neighbor list
/// came through the RWS candidate pre-filter (never silently — exact is
/// the default and the audit reference).
#[derive(Clone, Debug, Default)]
pub struct MatchReport {
    /// Absolute stream index of the window's first sample.
    pub window_start: u64,
    /// Whether the RWS pre-filter restricted the candidate set.
    pub approx: bool,
    /// The k nearest indexed series, ascending `(dist, train_idx)`.
    pub neighbors: Vec<Neighbor>,
    /// This window's cascade counters.
    pub stats: PruneStats,
    /// recall@k against the exact path (audit windows on the approx
    /// path only).
    pub recall: Option<f64>,
}

/// Online subsequence k-NN monitor: ring-buffer ingestion, per-sample
/// envelope maintenance, per-window cascade search.  See the module
/// docs for the exactness contract.
pub struct StreamMonitor {
    engine: SearchEngine,
    k: usize,
    t: usize,
    /// Raw sample ring, absolute index mod `t`.
    ring: Vec<f64>,
    /// Total samples ingested (= next absolute index).
    pushed: usize,
    env: SlidingEnvelope,
    /// Sliding envelope only serves non-z-normalized indexes (see
    /// module docs); z-normalized windows go through the engine's own
    /// normalize-then-envelope path.
    use_sliding: bool,
    znorm: IncZnorm,
    rws: Option<RwsFilter>,
    ws: DpWorkspace,
    /// Staged query envelope halves.
    qu: Vec<f64>,
    ql: Vec<f64>,
    /// Normalized-window scratch (RWS projection of z-normalized
    /// indexes).
    nbuf: Vec<f64>,
    stats: StreamStats,
    report: MatchReport,
    have_report: bool,
}

impl StreamMonitor {
    /// Monitor `engine`'s index for the top-`k` matches of every full
    /// window.  `rws` switches the serving path to the approximate
    /// pre-filter (reports stay flagged and audited; pass `None` for
    /// the exact default).
    pub fn new(engine: SearchEngine, k: usize, rws: Option<RwsConfig>) -> Result<StreamMonitor> {
        if k == 0 {
            return Err(Error::config("stream: k must be >= 1"));
        }
        if engine.index.is_empty() {
            return Err(Error::config("stream: cannot monitor an empty index"));
        }
        let t = engine.index.t;
        let radius = engine.index.radius;
        let use_sliding = !engine.index.znormalized;
        let rws = match rws {
            Some(cfg) => Some(RwsFilter::build(&engine.index, cfg)?),
            None => None,
        };
        // lint:allow(hot-alloc): constructor-time ring, reused forever.
        let ring = vec![0.0; t];
        let mut ws = DpWorkspace::new();
        // Pre-size the per-window staging buffer: steady-state pushes
        // never reallocate it.
        ws.window.reserve(t);
        let mut mon = StreamMonitor {
            engine,
            k,
            t,
            ring,
            pushed: 0,
            env: SlidingEnvelope::new(t, radius),
            use_sliding,
            znorm: IncZnorm::new(t),
            rws,
            ws,
            qu: Vec::new(),   // lint:allow(hot-alloc): constructor
            ql: Vec::new(),   // lint:allow(hot-alloc): constructor
            nbuf: Vec::new(), // lint:allow(hot-alloc): constructor
            stats: StreamStats::default(),
            report: MatchReport::default(),
            have_report: false,
        };
        mon.qu.reserve(t);
        mon.ql.reserve(t);
        mon.nbuf.reserve(t);
        Ok(mon)
    }

    /// Window length (the indexed series length).
    pub fn window_len(&self) -> usize {
        self.t
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Whether the serving path is the RWS approximate pre-filter.
    pub fn is_approx(&self) -> bool {
        self.rws.is_some()
    }

    /// Whether enough samples arrived to evaluate windows.
    pub fn ready(&self) -> bool {
        self.pushed >= self.t
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    /// The most recent match report, if any window was evaluated.
    pub fn last(&self) -> Option<&MatchReport> {
        if self.have_report {
            Some(&self.report)
        } else {
            None
        }
    }

    pub fn engine(&self) -> &SearchEngine {
        &self.engine
    }

    /// Ingest one sample.  Returns the match report for the window this
    /// sample completes (None while the ring is still filling).
    /// Non-finite values are rejected (the wire's `bad_input` class)
    /// without perturbing monitor state.
    pub fn push(&mut self, v: f64) -> Result<Option<&MatchReport>> {
        if !v.is_finite() {
            return Err(Error::data(format!(
                "stream: non-finite sample '{v}' (NaN/inf are not valid series values)"
            )));
        }
        let p = self.pushed;
        let t = self.t;
        let evicted = if p >= t { Some(self.ring[p % t]) } else { None };
        self.ring[p % t] = v;
        if self.use_sliding {
            self.env.push(p, &self.ring);
        }
        self.znorm.push(v, evicted);
        self.pushed = p + 1;
        self.stats.samples += 1;
        if self.pushed < t {
            return Ok(None);
        }
        self.eval_window(p);
        Ok(self.last())
    }

    /// Evaluate the window ending at absolute sample `p` and refresh
    /// [`Self::last`].  Zero steady-state allocations outside the
    /// engine's own per-query result vector.
    fn eval_window(&mut self, p: usize) {
        let t = self.t;
        let start = p + 1 - t;
        let mut win = std::mem::take(&mut self.ws.window);
        win.clear();
        for i in 0..t {
            win.push(self.ring[(start + i) % t]);
        }
        let engine = &self.engine;
        let znormed_index = engine.index.znormalized;
        let (res, approx, recall) = match self.rws.as_mut() {
            None => {
                let res = if znormed_index {
                    // Per-window re-normalization: the engine's own
                    // batch path (bit-identity is by construction).
                    engine.knn_values_with(&mut self.ws, &win, self.k)
                } else {
                    self.env.stage_into(p, &win, &mut self.qu, &mut self.ql);
                    engine.knn_values_with_query_env(
                        &mut self.ws,
                        &win,
                        self.k,
                        &self.qu,
                        &self.ql,
                    )
                };
                (res, false, None)
            }
            Some(filter) => {
                // Project in the domain the corpus was embedded in:
                // the stored (possibly z-normalized) representation.
                let probe: &[f64] = if znormed_index {
                    self.nbuf.clear();
                    self.nbuf.extend_from_slice(&win);
                    znormalize_in_place(&mut self.nbuf);
                    &self.nbuf
                } else {
                    &win
                };
                filter.project(&mut self.ws, probe);
                let res = engine.knn_among_with(&mut self.ws, &win, self.k, filter.candidates());
                let audit_every = filter.cfg.audit_every;
                let recall = if audit_every > 0 && self.stats.windows % audit_every == 0 {
                    let exact = engine.knn_values_with(&mut self.ws, &win, self.k);
                    Some(recall_at_k(&res.neighbors, &exact.neighbors))
                } else {
                    None
                };
                (res, true, recall)
            }
        };
        self.ws.window = win;
        self.stats.windows += 1;
        if approx {
            self.stats.approx_windows += 1;
        } else {
            self.stats.exact_windows += 1;
        }
        self.stats.prune.merge(&res.stats);
        if let Some(rc) = recall {
            self.stats.rws_audits += 1;
            self.stats.rws_recall_sum += rc;
        }
        self.stats.last_mean = self.znorm.mean();
        self.stats.last_std = self.znorm.std();
        self.report.window_start = start as u64;
        self.report.approx = approx;
        self.report.neighbors = res.neighbors;
        self.report.stats = res.stats;
        self.report.recall = recall;
        self.have_report = true;
    }
}

/// Fraction of the exact top-k present in the approximate result
/// (matched by train index).
pub fn recall_at_k(approx: &[Neighbor], exact: &[Neighbor]) -> f64 {
    if exact.is_empty() {
        return 1.0;
    }
    let mut hit = 0usize;
    for e in exact {
        if approx.iter().any(|a| a.train_idx == e.train_idx) {
            hit += 1;
        }
    }
    hit as f64 / exact.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::data::synthetic;
    use crate::measures::lb_keogh::envelope_into;
    use crate::search::{Cascade, Index};
    use crate::util::rng::Pcg64;
    use std::sync::Arc;

    /// Drive a SlidingEnvelope over a stream and compare every staged
    /// window against a from-scratch `envelope_into`, bit for bit.
    fn check_stream(values: &[f64], t: usize, r: usize) {
        let mut env = SlidingEnvelope::new(t, r);
        let mut ring = vec![0.0; t];
        let mut win = Vec::new();
        let (mut su, mut sl) = (Vec::new(), Vec::new());
        let (mut bu, mut bl) = (Vec::new(), Vec::new());
        let (mut maxq, mut minq) = (VecDeque::new(), VecDeque::new());
        for (p, &v) in values.iter().enumerate() {
            ring[p % t] = v;
            env.push(p, &ring);
            if p + 1 < t {
                continue;
            }
            win.clear();
            let start = p + 1 - t;
            for i in 0..t {
                win.push(ring[(start + i) % t]);
            }
            env.stage_into(p, &win, &mut su, &mut sl);
            envelope_into(&win, r.min(t - 1), &mut bu, &mut bl, &mut maxq, &mut minq);
            for i in 0..t {
                assert_eq!(
                    su[i].to_bits(),
                    bu[i].to_bits(),
                    "upper p={p} i={i} t={t} r={r}"
                );
                assert_eq!(
                    sl[i].to_bits(),
                    bl[i].to_bits(),
                    "lower p={p} i={i} t={t} r={r}"
                );
            }
        }
    }

    #[test]
    fn sliding_envelope_matches_batch_rebuild() {
        let mut rng = Pcg64::new(11);
        for t in [1usize, 2, 3, 5, 8, 16] {
            for r in [0usize, 1, 2, 4, 9, 100] {
                let vals: Vec<f64> = (0..3 * t + 5).map(|_| rng.normal()).collect();
                check_stream(&vals, t, r);
            }
        }
    }

    #[test]
    fn sliding_envelope_matches_batch_with_ties() {
        // quantized values force exact ties: the keep-latest rule must
        // match envelope_into's deque everywhere, including ±0.0
        let mut rng = Pcg64::new(23);
        for t in [4usize, 7, 12] {
            for r in [1usize, 3, 6] {
                let vals: Vec<f64> = (0..4 * t)
                    .map(|_| {
                        let q = (rng.normal() * 2.0).round() / 2.0;
                        if q == 0.0 && rng.below(2) == 0 {
                            -0.0
                        } else {
                            q
                        }
                    })
                    .collect();
                check_stream(&vals, t, r);
            }
        }
    }

    #[test]
    fn degenerate_radius_uses_two_pass_rebuild() {
        let env = SlidingEnvelope::new(6, 3);
        assert!(!env.sliding());
        let env = SlidingEnvelope::new(7, 3);
        assert!(env.sliding());
    }

    #[test]
    fn inc_znorm_tracks_batch_statistics() {
        let mut rng = Pcg64::new(5);
        let t = 32;
        let mut z = IncZnorm::new(t);
        let mut ring = vec![0.0; t];
        for p in 0..200usize {
            let v = rng.normal() * 3.0 + (p as f64) * 0.01;
            let evicted = if p >= t { Some(ring[p % t]) } else { None };
            ring[p % t] = v;
            z.push(v, evicted);
            if p + 1 < t {
                continue;
            }
            let n = t as f64;
            let mean: f64 = ring.iter().sum::<f64>() / n;
            let var: f64 = ring.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
            assert!((z.mean() - mean).abs() < 1e-9, "p={p}");
            assert!((z.std() - var.sqrt()).abs() < 1e-9, "p={p}");
        }
    }

    #[test]
    fn monitor_matches_batch_engine_bitwise() {
        let ds = synthetic::generate_scaled("CBF", 3, 12, 1).unwrap();
        let t = ds.series_len();
        let idx = Arc::new(Index::build(&ds.train, t / 10, 1));
        let engine = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let mut mon = StreamMonitor::new(engine.clone(), 3, None).unwrap();
        let mut rng = Pcg64::new(9);
        let stream: Vec<f64> = (0..t + 40).map(|_| rng.normal()).collect();
        let mut seen = 0;
        for (p, &v) in stream.iter().enumerate() {
            let got = mon.push(v).unwrap();
            if p + 1 < t {
                assert!(got.is_none());
                continue;
            }
            let rep = got.expect("window full");
            assert!(!rep.approx);
            let want = engine.knn_values(&stream[p + 1 - t..=p], 3);
            assert_eq!(rep.neighbors.len(), want.neighbors.len());
            for (a, b) in rep.neighbors.iter().zip(&want.neighbors) {
                assert_eq!(a.dist.to_bits(), b.dist.to_bits());
                assert_eq!(a.train_idx, b.train_idx);
            }
            assert_eq!(rep.stats, want.stats, "stats must match bitwise too");
            seen += 1;
        }
        assert_eq!(seen, 41);
        assert_eq!(mon.stats().windows, 41);
        assert_eq!(mon.stats().exact_windows, 41);
    }

    #[test]
    fn monitor_znormalized_index_matches_batch() {
        let ds = synthetic::generate_scaled("Gun-Point", 7, 10, 1).unwrap();
        let t = ds.series_len();
        let idx = Arc::new(Index::build_znormalized(&ds.train, 6, 1));
        let engine = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let mut mon = StreamMonitor::new(engine.clone(), 2, None).unwrap();
        let mut rng = Pcg64::new(3);
        let stream: Vec<f64> = (0..t + 10).map(|_| rng.normal() + 5.0).collect();
        for (p, &v) in stream.iter().enumerate() {
            if let Some(rep) = mon.push(v).unwrap() {
                let want = engine.knn_values(&stream[p + 1 - t..=p], 2);
                for (a, b) in rep.neighbors.iter().zip(&want.neighbors) {
                    assert_eq!(a.dist.to_bits(), b.dist.to_bits());
                    assert_eq!(a.train_idx, b.train_idx);
                }
            }
        }
    }

    #[test]
    fn monitor_rejects_bad_inputs() {
        let train = from_pairs(vec![(0, vec![0.0, 1.0, 2.0]), (1, vec![2.0, 1.0, 0.0])]);
        let idx = Arc::new(Index::build(&train, 1, 1));
        let engine = SearchEngine::new(idx, Cascade::default());
        assert!(StreamMonitor::new(engine.clone(), 0, None).is_err());
        let mut mon = StreamMonitor::new(engine, 1, None).unwrap();
        assert!(mon.push(f64::NAN).is_err());
        assert!(mon.push(f64::INFINITY).is_err());
        // rejected samples must not advance the stream
        assert_eq!(mon.stats().samples, 0);
        assert!(mon.push(1.0).unwrap().is_none());
        assert_eq!(mon.stats().samples, 1);
    }

    #[test]
    fn exhaustive_candidate_budget_is_bit_exact() {
        let ds = synthetic::generate_scaled("CBF", 17, 10, 1).unwrap();
        let t = ds.series_len();
        let idx = Arc::new(Index::build(&ds.train, 5, 1));
        let engine = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let cfg = RwsConfig {
            d: 4,
            len: 0,
            candidates: idx.len(), // budget covers the corpus
            seed: 3,
            audit_every: 1,
        };
        let mut mon = StreamMonitor::new(engine.clone(), 2, Some(cfg)).unwrap();
        let mut rng = Pcg64::new(41);
        let stream: Vec<f64> = (0..t + 12).map(|_| rng.normal()).collect();
        for (p, &v) in stream.iter().enumerate() {
            if let Some(rep) = mon.push(v).unwrap() {
                assert!(rep.approx, "RWS path must stay flagged");
                assert_eq!(rep.recall, Some(1.0), "full budget must audit at 1.0");
                let want = engine.knn_values(&stream[p + 1 - t..=p], 2);
                for (a, b) in rep.neighbors.iter().zip(&want.neighbors) {
                    assert_eq!(a.dist.to_bits(), b.dist.to_bits());
                    assert_eq!(a.train_idx, b.train_idx);
                }
            }
        }
        assert_eq!(mon.stats().recall(), Some(1.0));
        assert!(mon.stats().approx_windows > 0);
    }

    #[test]
    fn stream_stats_report_mentions_sections() {
        let s = StreamStats::default();
        let r = s.report();
        assert!(r.contains("samples") && r.contains("recall@k") && r.contains("windows"));
    }
}
