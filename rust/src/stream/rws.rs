//! Random Warping Series approximate pre-filter (arXiv 1809.05259).
//!
//! The RWS idea: draw `d` short random "warping series" and represent
//! every series by its vector of (unconstrained) DTW distances to them.
//! DTW structure is approximately preserved by the embedding, so a
//! cheap squared-Euclidean scan in R^d ranks the corpus well enough to
//! shortlist candidates for exact refinement.  The streaming monitor
//! uses this as a *pre-filter only*: the shortlist goes back through
//! the exact cascade (`SearchEngine::knn_among_with`), results stay
//! flagged approximate, and a periodic audit measures recall@k against
//! the exact full-corpus path.  With `candidates >= corpus size` the
//! shortlist is the whole corpus and the refinement is bit-identical
//! to the exact path — the anchor for the recall/speed dial.
//!
//! Everything is seeded ([`crate::util::rng::Pcg64`]): the same
//! `RwsConfig` over the same index always yields the same embeddings,
//! candidates, and audits.

use crate::error::{Error, Result};
use crate::measures::dtw::dtw_banded_into;
use crate::measures::workspace::DpWorkspace;
use crate::search::Index;
use crate::util::rng::Pcg64;

/// Knobs for the RWS pre-filter.  `candidates` is the recall/speed
/// dial: small budgets scan few series per window (fast, lossy), a
/// budget covering the corpus is exact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RwsConfig {
    /// Embedding dimension: number of random warping series (>= 1).
    pub d: usize,
    /// Warping-series length; 0 = auto (`t / 4`, at least 2).
    pub len: usize,
    /// Candidate budget per window (>= 1; clamped to the corpus size).
    pub candidates: usize,
    /// Seed for the warping-series draw.
    pub seed: u64,
    /// Audit cadence: every `audit_every`-th window also runs the exact
    /// path and records recall@k.  0 disables audits.
    pub audit_every: u64,
}

impl Default for RwsConfig {
    fn default() -> RwsConfig {
        RwsConfig {
            d: 8,
            len: 0,
            candidates: 16,
            seed: 7,
            audit_every: 0,
        }
    }
}

impl RwsConfig {
    pub fn validate(&self) -> Result<()> {
        if self.d == 0 {
            return Err(Error::config("rws: embedding dimension d must be >= 1"));
        }
        if self.candidates == 0 {
            return Err(Error::config("rws: candidate budget must be >= 1"));
        }
        Ok(())
    }

    /// Effective warping-series length for window length `t`.
    pub fn warp_len(&self, t: usize) -> usize {
        if self.len == 0 {
            (t / 4).clamp(2, t.max(2))
        } else {
            self.len
        }
    }
}

/// Seeded RWS embedding of one index's corpus plus per-window scratch.
/// Build once per stream session ([`RwsFilter::build`]), then call
/// [`RwsFilter::project`] per window and refine
/// [`RwsFilter::candidates`] through the exact cascade.
pub struct RwsFilter {
    pub cfg: RwsConfig,
    /// The `d` random warping series (random walks with normal steps).
    warps: Vec<Vec<f64>>,
    /// Corpus embeddings, row-major `n x d`.
    emb: Vec<f64>,
    n: usize,
    /// Per-window scratch: query embedding, scored corpus, shortlist.
    qemb: Vec<f64>,
    scored: Vec<(f64, usize)>,
    cand: Vec<usize>,
}

impl RwsFilter {
    /// Embed `index`'s stored series (the cascade's comparison domain —
    /// z-normalized if the index is).  O(n · d · t · len) DTW work,
    /// once per session.
    pub fn build(index: &Index, cfg: RwsConfig) -> Result<RwsFilter> {
        cfg.validate()?;
        if index.is_empty() {
            return Err(Error::config("rws: cannot build over an empty index"));
        }
        let wlen = cfg.warp_len(index.t);
        let mut rng = Pcg64::new(cfg.seed);
        let mut ws = DpWorkspace::new();
        // lint:allow(hot-alloc): session-build time, not a per-step path.
        let mut warps: Vec<Vec<f64>> = Vec::with_capacity(cfg.d);
        for i in 0..cfg.d {
            let mut child = rng.fork(i as u64);
            // lint:allow(hot-alloc): session-build time (see above).
            let mut w = Vec::with_capacity(wlen);
            let mut level = 0.0;
            for _ in 0..wlen {
                level += child.normal();
                w.push(level);
            }
            warps.push(w);
        }
        let n = index.len();
        // lint:allow(hot-alloc): session-build time (see above).
        let mut emb = vec![0.0; n * cfg.d];
        for j in 0..n {
            for (c, w) in warps.iter().enumerate() {
                // Unconstrained DTW (rescaled diagonal handles the
                // unequal lengths), as in the RWS formulation.
                emb[j * cfg.d + c] = dtw_banded_into(&mut ws, &index.series[j], w, usize::MAX).value;
            }
        }
        Ok(RwsFilter {
            cfg,
            warps,
            emb,
            n,
            qemb: Vec::with_capacity(cfg.d), // lint:allow(hot-alloc): constructor
            scored: Vec::with_capacity(n),   // lint:allow(hot-alloc): constructor
            cand: Vec::with_capacity(cfg.candidates.min(n)), // lint:allow(hot-alloc): constructor
        })
    }

    /// Corpus size the filter was built over.
    pub fn corpus(&self) -> usize {
        self.n
    }

    /// Embedding dimension.
    pub fn dims(&self) -> usize {
        self.cfg.d
    }

    /// Embed `probe` (same domain as the corpus embeddings: pass the
    /// z-normalized window for a z-normalized index) and select this
    /// window's candidate shortlist — ascending embedding distance,
    /// ties by train index, distinct.  Zero steady-state allocations.
    pub fn project(&mut self, ws: &mut DpWorkspace, probe: &[f64]) {
        let d = self.cfg.d;
        self.qemb.clear();
        for w in &self.warps {
            self.qemb
                .push(dtw_banded_into(ws, probe, w, usize::MAX).value);
        }
        self.scored.clear();
        for j in 0..self.n {
            let row = &self.emb[j * d..(j + 1) * d];
            let mut s = 0.0;
            for (a, b) in row.iter().zip(&self.qemb) {
                let diff = a - b;
                s += diff * diff;
            }
            self.scored.push((s, j));
        }
        let c = self.cfg.candidates.min(self.n);
        self.scored
            .select_nth_unstable_by(c - 1, |a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let top = &mut self.scored[..c];
        top.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        self.cand.clear();
        self.cand.extend(top.iter().map(|&(_, j)| j));
    }

    /// The shortlist selected by the last [`Self::project`] call
    /// (ascending expected distance; distinct train indices).
    pub fn candidates(&self) -> &[usize] {
        &self.cand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::search::Index;

    fn small_index() -> Index {
        let ds = synthetic::generate_scaled("CBF", 19, 9, 1).unwrap();
        Index::build(&ds.train, 4, 1)
    }

    #[test]
    fn config_validation() {
        assert!(RwsConfig {
            d: 0,
            ..RwsConfig::default()
        }
        .validate()
        .is_err());
        assert!(RwsConfig {
            candidates: 0,
            ..RwsConfig::default()
        }
        .validate()
        .is_err());
        assert!(RwsConfig::default().validate().is_ok());
        assert_eq!(RwsConfig::default().warp_len(128), 32);
        assert_eq!(RwsConfig::default().warp_len(3), 2);
        assert_eq!(
            RwsConfig {
                len: 9,
                ..RwsConfig::default()
            }
            .warp_len(128),
            9
        );
    }

    #[test]
    fn same_seed_is_deterministic() {
        let idx = small_index();
        let cfg = RwsConfig {
            d: 3,
            candidates: 4,
            seed: 99,
            ..RwsConfig::default()
        };
        let mut a = RwsFilter::build(&idx, cfg).unwrap();
        let mut b = RwsFilter::build(&idx, cfg).unwrap();
        assert_eq!(a.emb.len(), idx.len() * 3);
        for (x, y) in a.emb.iter().zip(&b.emb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let mut ws = DpWorkspace::new();
        let probe: Vec<f64> = idx.series[0].clone();
        a.project(&mut ws, &probe);
        b.project(&mut ws, &probe);
        assert_eq!(a.candidates(), b.candidates());
    }

    #[test]
    fn candidates_are_sorted_distinct_and_capped() {
        let idx = small_index();
        let n = idx.len();
        let cfg = RwsConfig {
            d: 4,
            candidates: 3,
            seed: 5,
            ..RwsConfig::default()
        };
        let mut f = RwsFilter::build(&idx, cfg).unwrap();
        let mut ws = DpWorkspace::new();
        f.project(&mut ws, &idx.series[1]);
        let cand = f.candidates();
        assert_eq!(cand.len(), 3.min(n));
        for w in cand.windows(2) {
            assert_ne!(w[0], w[1], "candidates must be distinct");
        }
        for &j in cand {
            assert!(j < n);
        }
        // budget over the corpus clamps to n and covers everything
        let cfg_all = RwsConfig {
            candidates: n + 10,
            ..cfg
        };
        let mut g = RwsFilter::build(&idx, cfg_all).unwrap();
        g.project(&mut ws, &idx.series[1]);
        let mut all: Vec<usize> = g.candidates().to_vec();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn self_probe_ranks_itself_first() {
        // A corpus series' embedding distance to itself is exactly 0,
        // and ties break by index, so probing with series j (on a
        // non-z-normalized index) must shortlist j first unless another
        // series has the identical embedding.
        let idx = small_index();
        let cfg = RwsConfig {
            d: 6,
            candidates: 2,
            seed: 1,
            ..RwsConfig::default()
        };
        let mut f = RwsFilter::build(&idx, cfg).unwrap();
        let mut ws = DpWorkspace::new();
        f.project(&mut ws, &idx.series[2]);
        assert!(f.candidates().contains(&2));
    }
}
