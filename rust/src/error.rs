//! Crate-wide error type.
//!
//! Hand-rolled (`thiserror` is not in the vendored crate set); converts
//! from IO / xla / parse errors and carries enough context for the CLI to
//! print actionable messages.

use std::fmt;

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the library.
#[derive(Debug)]
pub enum Error {
    /// Filesystem / socket IO.
    Io(std::io::Error),
    /// JSON parse errors from `util::json`.
    Json { msg: String, offset: usize },
    /// Configuration / CLI validation.
    Config(String),
    /// Unknown dataset, measure or experiment name.
    Unknown { kind: &'static str, name: String },
    /// A referenced entity (registered grid / index / measure key or
    /// name) does not exist — the wire's `not_found` class, distinct
    /// from malformed requests.
    NotFound { kind: &'static str, name: String },
    /// Data format violations (UCR parsing, length mismatches...).
    Data(String),
    /// PJRT runtime errors (compile, execute, artifact lookup).
    Runtime(String),
    /// Coordinator lifecycle errors (queue closed, worker panic...).
    Coordinator(String),
    /// A shard fan-out could not get exact results from every shard
    /// (typed partial-result error: the merged answer would be silently
    /// wrong, so none is returned).  `shards_ok` counts shards that
    /// answered (or had nothing to do) out of `shards_total`.
    ShardUnavailable {
        shards_ok: usize,
        shards_total: usize,
        detail: String,
    },
    /// A request deadline expired before the work completed (typed
    /// `deadline_exceeded` on the wire).  Carries the original budget so
    /// the client sees what it asked for, not a server-side remainder.
    DeadlineExceeded { budget_ms: u64 },
    /// Numerical failure (SVM non-convergence, NaN propagation...).
    Numeric(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json { msg, offset } => write!(f, "json error at byte {offset}: {msg}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Unknown { kind, name } => write!(f, "unknown {kind}: '{name}'"),
            Error::NotFound { kind, name } => write!(f, "unknown {kind}: '{name}'"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::ShardUnavailable {
                shards_ok,
                shards_total,
                detail,
            } => write!(
                f,
                "shard fan-out degraded: {shards_ok}/{shards_total} shards answered \
                 ({detail}); partial results withheld to preserve exactness"
            ),
            Error::DeadlineExceeded { budget_ms } => write!(
                f,
                "deadline exceeded: {budget_ms} ms budget exhausted before completion"
            ),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used across the crate.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
    pub fn not_found(kind: &'static str, name: impl Into<String>) -> Self {
        Error::NotFound {
            kind,
            name: name.into(),
        }
    }
    pub fn deadline_exceeded(budget_ms: u64) -> Self {
        Error::DeadlineExceeded { budget_ms }
    }

    /// Stable machine-readable error code — the `code` field of every
    /// TCP error reply (wire protocol v2; also attached to v1 replies,
    /// additively).  The mapping is part of the protocol contract and
    /// asserted by `tests/integration_protocol.rs`:
    ///
    /// | code | class |
    /// |------|-------|
    /// | `bad_json` | the request line was not valid JSON |
    /// | `bad_request` | missing/mistyped fields, invalid parameters |
    /// | `bad_input` | data violations (non-finite series values, ragged shapes) |
    /// | `unknown_op` | unrecognized `op` |
    /// | `not_found` | referenced grid/index/measure does not exist |
    /// | `unavailable` | coordinator lifecycle failures (shut down, worker gone) and shard fan-out degradation (`ShardUnavailable`, whose error replies also carry `shards_ok`/`shards_total`) |
    /// | `deadline_exceeded` | the request's `deadline_ms` budget expired before completion |
    /// | `internal` | IO / runtime / numeric failures |
    ///
    /// One additional code exists only at the wire layer:
    /// `unsupported_proto` (a `proto` value other than 1/2) is
    /// synthesized by the server's dispatch before any `Error` is
    /// constructed, so it never flows through this method.
    pub fn code(&self) -> &'static str {
        match self {
            Error::Json { .. } => "bad_json",
            Error::Config(_) => "bad_request",
            Error::Data(_) => "bad_input",
            Error::Unknown { kind: "op", .. } => "unknown_op",
            Error::Unknown { .. } | Error::NotFound { .. } => "not_found",
            Error::Coordinator(_) | Error::ShardUnavailable { .. } => "unavailable",
            Error::DeadlineExceeded { .. } => "deadline_exceeded",
            Error::Io(_) | Error::Runtime(_) | Error::Numeric(_) => "internal",
        }
    }
}
