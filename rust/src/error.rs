//! Crate-wide error type.
//!
//! Hand-rolled (`thiserror` is not in the vendored crate set); converts
//! from IO / xla / parse errors and carries enough context for the CLI to
//! print actionable messages.

use std::fmt;

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the library.
#[derive(Debug)]
pub enum Error {
    /// Filesystem / socket IO.
    Io(std::io::Error),
    /// JSON parse errors from `util::json`.
    Json { msg: String, offset: usize },
    /// Configuration / CLI validation.
    Config(String),
    /// Unknown dataset, measure or experiment name.
    Unknown { kind: &'static str, name: String },
    /// Data format violations (UCR parsing, length mismatches...).
    Data(String),
    /// PJRT runtime errors (compile, execute, artifact lookup).
    Runtime(String),
    /// Coordinator lifecycle errors (queue closed, worker panic...).
    Coordinator(String),
    /// Numerical failure (SVM non-convergence, NaN propagation...).
    Numeric(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json { msg, offset } => write!(f, "json error at byte {offset}: {msg}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Unknown { kind, name } => write!(f, "unknown {kind}: '{name}'"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used across the crate.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
}
