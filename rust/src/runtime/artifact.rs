//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.  `artifacts/manifest.json` lists every AOT-lowered
//! HLO-text module with its kernel kind, batch size, series length and
//! dtype; the runtime picks buckets from here and never guesses shapes.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Which DP kernel an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Weighted masked DTW, f32: args (x[B,T], y[B,T], wdiag[2T-1,T]).
    Dtw,
    /// Log-domain K_rdtw, f64: args (x, y, mdiag[2T-1,T], nu[1]).
    Krdtw,
}

impl KernelKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dtw" => Ok(KernelKind::Dtw),
            "krdtw" => Ok(KernelKind::Krdtw),
            other => Err(Error::runtime(format!("unknown kernel kind '{other}'"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::Dtw => "dtw",
            KernelKind::Krdtw => "krdtw",
        }
    }
}

/// One AOT-compiled module.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub kernel: KernelKind,
    pub name: String,
    pub path: PathBuf,
    pub batch: usize,
    pub length: usize,
    pub dtype: String,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            Error::runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                mpath.display()
            ))
        })?;
        let json = Json::parse(&text)?;
        let mut entries = Vec::new();
        for e in json.req_arr("entries")? {
            let kernel = KernelKind::parse(e.req_str("kernel")?)?;
            let file = e.req_str("file")?;
            let path = dir.join(file);
            if !path.exists() {
                return Err(Error::runtime(format!(
                    "manifest entry '{file}' missing on disk"
                )));
            }
            entries.push(ArtifactEntry {
                kernel,
                name: e.req_str("name")?.to_string(),
                path,
                batch: e.req_usize("batch")?,
                length: e.req_usize("length")?,
                dtype: e.req_str("dtype")?.to_string(),
            });
        }
        Ok(Manifest { entries })
    }

    /// Find the bucket for an exact series length (same-length batching
    /// policy, DESIGN.md §7).
    pub fn find(&self, kernel: KernelKind, length: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kernel == kernel && e.length == length)
    }

    /// Supported lengths for a kernel kind.
    pub fn lengths(&self, kernel: KernelKind) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel)
            .map(|e| e.length)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake(dir: &Path, with_file: bool) {
        std::fs::create_dir_all(dir).unwrap();
        if with_file {
            std::fs::write(dir.join("dtw_T8_B4.hlo.txt"), "HloModule m\n").unwrap();
        }
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"entries":[{"kernel":"dtw","name":"dtw_T8_B4","file":"dtw_T8_B4.hlo.txt","batch":4,"length":8,"dtype":"f32","args":[]}]}"#,
        )
        .unwrap();
    }

    #[test]
    fn load_and_find() {
        let dir = std::env::temp_dir().join(format!("spdtw_man_{}", std::process::id()));
        write_fake(&dir, true);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert!(m.find(KernelKind::Dtw, 8).is_some());
        assert!(m.find(KernelKind::Dtw, 9).is_none());
        assert!(m.find(KernelKind::Krdtw, 8).is_none());
        assert_eq!(m.lengths(KernelKind::Dtw), vec![8]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!("spdtw_man2_{}", std::process::id()));
        write_fake(&dir, false);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_helpful_error() {
        let dir = std::env::temp_dir().join(format!("spdtw_man3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
