//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.  `artifacts/manifest.json` lists every AOT-lowered
//! HLO-text module with its kernel kind, batch size, series length and
//! dtype; the runtime picks buckets from here and never guesses shapes.
//!
//! The manifest also records persisted **search indexes** (an optional
//! `"indexes"` array): `.spix` files written by `search::persist` that a
//! warm-starting coordinator reloads at boot instead of rebuilding.
//! [`record_index_artifact`] rewrites only that array, preserving every
//! other manifest key byte-for-byte semantically (the Python AOT side
//! owns `"entries"` and may carry fields Rust does not model).
//!
//! Registered **measures** persist in a separate `measures.json` next to
//! the manifest ([`record_measure_spec`] / [`load_measure_specs`]): the
//! index array and the measure list are written under *different*
//! coordinator locks, so sharing one file would let their read-modify-
//! write cycles interleave and lose updates.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::measures::spec::MeasureSpec;
use crate::util::json::Json;

/// Which DP kernel an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Weighted masked DTW, f32: args (x[B,T], y[B,T], wdiag[2T-1,T]).
    Dtw,
    /// Log-domain K_rdtw, f64: args (x, y, mdiag[2T-1,T], nu[1]).
    Krdtw,
    /// LB_Keogh lane batch, f64: args (q[T], upper[T,L], lower[T,L]) —
    /// the envelope operands are candidate-major ((T, L): column j of
    /// every lane contiguous), the exact layout
    /// `search::lanes::pack_candidate_major` produces on the host.
    LbKeogh,
    /// SP-DTW lane batch, f64: args (q[T], y[T,L], plane[nnz-packed
    /// LOC]) — y is candidate-major like `LbKeogh`; the LOC plane is
    /// resolved by `plane_key` on the serving side.
    Spdtw,
}

impl KernelKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dtw" => Ok(KernelKind::Dtw),
            "krdtw" => Ok(KernelKind::Krdtw),
            "lb_keogh" => Ok(KernelKind::LbKeogh),
            "spdtw" => Ok(KernelKind::Spdtw),
            other => Err(Error::runtime(format!("unknown kernel kind '{other}'"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::Dtw => "dtw",
            KernelKind::Krdtw => "krdtw",
            KernelKind::LbKeogh => "lb_keogh",
            KernelKind::Spdtw => "spdtw",
        }
    }
}

/// One AOT-compiled module.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub kernel: KernelKind,
    pub name: String,
    pub path: PathBuf,
    pub batch: usize,
    pub length: usize,
    pub dtype: String,
}

/// One persisted search index (`search::persist` file) listed in the
/// manifest next to the AOT kernel artifacts.
#[derive(Clone, Debug)]
pub struct IndexArtifact {
    /// Registry name the coordinator re-registers it under at boot.
    pub name: String,
    /// Absolute path of the `.spix` file.
    pub path: PathBuf,
    /// Indexed series length (T).
    pub length: usize,
    /// Number of indexed train series.
    pub count: usize,
    /// LRU recency stamp: a monotone per-store counter bumped on every
    /// save ([`record_index_artifact`]) and named lookup
    /// ([`touch_index_artifact`]) — larger = more recently used.  A
    /// warm-starting coordinator replays entries in ascending order so
    /// the store's eviction order survives restarts.  0 for manifests
    /// written before this field existed.
    pub last_used: u64,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
    /// Persisted search indexes (optional `"indexes"` manifest key).
    /// Existence on disk is *not* checked here: a stale entry is caught
    /// by `search::persist::load_index`'s own validation at warm-start.
    pub indexes: Vec<IndexArtifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            Error::runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                mpath.display()
            ))
        })?;
        let json = Json::parse(&text)?;
        let mut entries = Vec::new();
        for e in json.req_arr("entries")? {
            let kernel = KernelKind::parse(e.req_str("kernel")?)?;
            let file = e.req_str("file")?;
            let path = dir.join(file);
            if !path.exists() {
                return Err(Error::runtime(format!(
                    "manifest entry '{file}' missing on disk"
                )));
            }
            entries.push(ArtifactEntry {
                kernel,
                name: e.req_str("name")?.to_string(),
                path,
                batch: e.req_usize("batch")?,
                length: e.req_usize("length")?,
                dtype: e.req_str("dtype")?.to_string(),
            });
        }
        let mut indexes = Vec::new();
        if let Some(arr) = json.get("indexes").and_then(Json::as_arr) {
            for e in arr {
                indexes.push(IndexArtifact {
                    name: e.req_str("name")?.to_string(),
                    path: dir.join(e.req_str("file")?),
                    length: e.req_usize("length")?,
                    count: e.req_usize("count")?,
                    last_used: e.get("last_used").and_then(Json::as_usize).unwrap_or(0) as u64,
                });
            }
        }
        Ok(Manifest { entries, indexes })
    }

    /// Look up a persisted index by registry name.
    pub fn find_index(&self, name: &str) -> Option<&IndexArtifact> {
        self.indexes.iter().find(|e| e.name == name)
    }

    /// Find the bucket for an exact series length (same-length batching
    /// policy, DESIGN.md §7).
    pub fn find(&self, kernel: KernelKind, length: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kernel == kernel && e.length == length)
    }

    /// Supported lengths for a kernel kind.
    pub fn lengths(&self, kernel: KernelKind) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel)
            .map(|e| e.length)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Record (or replace) a persisted-index entry in `<dir>/manifest.json`,
/// creating a minimal manifest when none exists.  Only the `"indexes"`
/// array is touched; every other key — including entry fields Rust does
/// not model — survives the rewrite.  The entry is stamped with the
/// next `last_used` recency value (max over existing entries + 1), so
/// the LRU eviction order survives a restart.  The write is temp-file +
/// rename so a crash never leaves a torn manifest.
pub fn record_index_artifact(
    dir: &Path,
    name: &str,
    file: &str,
    length: usize,
    count: usize,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    rewrite_manifest_indexes(dir, true, |indexes| {
        indexes.retain(|e| e.get("name").and_then(Json::as_str) != Some(name));
        let stamp = next_recency_stamp(indexes);
        indexes.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("file", Json::str(file)),
            ("length", Json::num(length as f64)),
            ("count", Json::num(count as f64)),
            ("last_used", Json::num(stamp as f64)),
        ]));
        true
    })
}

/// Next LRU stamp: one past the largest `last_used` among `indexes`.
fn next_recency_stamp(indexes: &[Json]) -> u64 {
    indexes
        .iter()
        .filter_map(|e| e.get("last_used").and_then(Json::as_usize))
        .max()
        .map(|m| m as u64 + 1)
        .unwrap_or(1)
}

/// Bump a persisted index's `last_used` recency stamp to most-recent
/// (the manifest half of an in-memory LRU touch; called on named
/// lookups so the eviction order survives a coordinator restart).
/// Missing manifest or unknown name is a no-op.
pub fn touch_index_artifact(dir: &Path, name: &str) -> Result<()> {
    rewrite_manifest_indexes(dir, false, |indexes| {
        let stamp = next_recency_stamp(indexes);
        let mut found = false;
        for e in indexes.iter_mut() {
            if e.get("name").and_then(Json::as_str) == Some(name) {
                if let Json::Obj(fields) = e {
                    fields.insert("last_used".to_string(), Json::num(stamp as f64));
                    found = true;
                }
            }
        }
        found
    })
}

/// Remove a persisted-index entry from `<dir>/manifest.json` (LRU
/// eviction path).  Missing manifest or missing entry is a no-op.
pub fn remove_index_artifact(dir: &Path, name: &str) -> Result<()> {
    rewrite_manifest_indexes(dir, false, |indexes| {
        let before = indexes.len();
        indexes.retain(|e| e.get("name").and_then(Json::as_str) != Some(name));
        indexes.len() != before
    })
}

/// Shared read-modify-write over the manifest's `"indexes"` array: load
/// `<dir>/manifest.json` (creating a minimal one when `create_if_missing`
/// — otherwise a missing manifest is a no-op), hand the array to
/// `mutate`, and atomically rewrite (temp-file + rename, so a crash
/// never leaves a torn manifest) when it returns true.  Every other
/// manifest key — including entry fields Rust does not model — survives
/// the rewrite.
fn rewrite_manifest_indexes(
    dir: &Path,
    create_if_missing: bool,
    mutate: impl FnOnce(&mut Vec<Json>) -> bool,
) -> Result<()> {
    let mpath = dir.join("manifest.json");
    let root = match std::fs::read_to_string(&mpath) {
        Ok(text) => Json::parse(&text)?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            if !create_if_missing {
                return Ok(());
            }
            Json::obj(vec![
                ("version", Json::num(1.0)),
                ("entries", Json::Arr(Vec::new())),
            ])
        }
        Err(e) => return Err(e.into()),
    };
    let mut obj = root
        .as_obj()
        .cloned()
        .ok_or_else(|| Error::runtime("manifest.json root is not an object"))?;
    let mut indexes: Vec<Json> = obj
        .get("indexes")
        .and_then(Json::as_arr)
        .map(|a| a.to_vec())
        .unwrap_or_default();
    if !mutate(&mut indexes) {
        return Ok(());
    }
    obj.insert("indexes".to_string(), Json::Arr(indexes));
    let tmp = dir.join("manifest.json.tmp");
    std::fs::write(&tmp, Json::Obj(obj).to_pretty())?;
    std::fs::rename(&tmp, &mpath)?;
    Ok(())
}

/// Record (or replace) a registered measure in `<dir>/measures.json`
/// (`{"version":1,"measures":[{"key":K,"spec":{...}}]}`), creating the
/// file when missing, so a warm-starting coordinator can replay
/// `register_measure` entries at their original keys.  Temp-file +
/// rename, like the manifest writes.  The caller's measure-registry
/// lock serializes the read-modify-write.
pub fn record_measure_spec(dir: &Path, key: u64, spec: &MeasureSpec) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mpath = dir.join("measures.json");
    let mut measures: Vec<Json> = match std::fs::read_to_string(&mpath) {
        Ok(text) => Json::parse(&text)?
            .get("measures")
            .and_then(Json::as_arr)
            .map(|a| a.to_vec())
            .unwrap_or_default(),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(e.into()),
    };
    measures.retain(|e| e.get("key").and_then(Json::as_usize) != Some(key as usize));
    measures.push(Json::obj(vec![
        ("key", Json::num(key as f64)),
        ("spec", spec.to_json()),
    ]));
    let root = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("measures", Json::Arr(measures)),
    ]);
    let tmp = dir.join("measures.json.tmp");
    std::fs::write(&tmp, root.to_pretty())?;
    std::fs::rename(&tmp, &mpath)?;
    Ok(())
}

/// Load every persisted measure from `<dir>/measures.json` as
/// `(key, spec)` pairs in ascending key order.  A missing file is an
/// empty store, not an error; a malformed file or entry is (a bad line
/// must never silently vanish a registered key).
pub fn load_measure_specs(dir: &Path) -> Result<Vec<(u64, MeasureSpec)>> {
    let mpath = dir.join("measures.json");
    let text = match std::fs::read_to_string(&mpath) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e.into()),
    };
    let json = Json::parse(&text)?;
    let mut out = Vec::new();
    for e in json.req_arr("measures")? {
        let key = e.req_usize("key")? as u64;
        let spec = e
            .get("spec")
            .ok_or_else(|| Error::data("measures.json entry missing 'spec'"))?;
        out.push((key, MeasureSpec::from_json(spec)?));
    }
    out.sort_by_key(|(k, _)| *k);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake(dir: &Path, with_file: bool) {
        std::fs::create_dir_all(dir).unwrap();
        if with_file {
            std::fs::write(dir.join("dtw_T8_B4.hlo.txt"), "HloModule m\n").unwrap();
        }
        std::fs::write(
            dir.join("manifest.json"),
            concat!(
                r#"{"version":1,"entries":[{"kernel":"dtw","name":"dtw_T8_B4","#,
                r#""file":"dtw_T8_B4.hlo.txt","batch":4,"length":8,"dtype":"f32","args":[]}]}"#
            ),
        )
        .unwrap();
    }

    #[test]
    fn load_and_find() {
        let dir = std::env::temp_dir().join(format!("spdtw_man_{}", std::process::id()));
        write_fake(&dir, true);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert!(m.find(KernelKind::Dtw, 8).is_some());
        assert!(m.find(KernelKind::Dtw, 9).is_none());
        assert!(m.find(KernelKind::Krdtw, 8).is_none());
        assert_eq!(m.lengths(KernelKind::Dtw), vec![8]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!("spdtw_man2_{}", std::process::id()));
        write_fake(&dir, false);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_index_creates_and_preserves_manifest() {
        let dir = std::env::temp_dir().join(format!("spdtw_man4_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        // fresh store: creates a minimal manifest with the index entry
        record_index_artifact(&dir, "cbf", "cbf.spix", 128, 30).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.is_empty());
        assert_eq!(m.indexes.len(), 1);
        assert_eq!(m.find_index("cbf").unwrap().length, 128);
        assert_eq!(m.find_index("cbf").unwrap().count, 30);
        assert!(m.find_index("nope").is_none());

        // same name again: replaced, not duplicated
        record_index_artifact(&dir, "cbf", "cbf.spix", 128, 60).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.indexes.len(), 1);
        assert_eq!(m.find_index("cbf").unwrap().count, 60);

        // foreign manifest keys (the python AOT side's) survive rewrites
        write_fake(&dir, true);
        record_index_artifact(&dir, "gun", "gun.spix", 150, 24).unwrap();
        let raw = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let json = Json::parse(&raw).unwrap();
        assert!(json.get("version").is_some());
        assert_eq!(json.req_arr("entries").unwrap().len(), 1);
        assert!(json.req_arr("entries").unwrap()[0].get("args").is_some());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.indexes.len(), 1); // write_fake reset the manifest
        assert!(m.find_index("gun").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn remove_index_entry_preserves_rest() {
        let dir = std::env::temp_dir().join(format!("spdtw_man5_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        // removing from a nonexistent manifest is a clean no-op
        remove_index_artifact(&dir, "ghost").unwrap();
        record_index_artifact(&dir, "a", "a.spix", 8, 2).unwrap();
        record_index_artifact(&dir, "b", "b.spix", 8, 2).unwrap();
        remove_index_artifact(&dir, "a").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.find_index("a").is_none());
        assert!(m.find_index("b").is_some());
        // unknown name: no-op, manifest intact
        remove_index_artifact(&dir, "nope").unwrap();
        assert_eq!(Manifest::load(&dir).unwrap().indexes.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recency_stamps_record_and_touch() {
        let dir = std::env::temp_dir().join(format!("spdtw_man6_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        // touching a nonexistent manifest / unknown name is a no-op
        touch_index_artifact(&dir, "ghost").unwrap();
        record_index_artifact(&dir, "a", "a.spix", 8, 2).unwrap();
        record_index_artifact(&dir, "b", "b.spix", 8, 2).unwrap();
        record_index_artifact(&dir, "c", "c.spix", 8, 2).unwrap();
        let stamp = |name: &str| {
            Manifest::load(&dir).unwrap().find_index(name).unwrap().last_used
        };
        assert!(stamp("a") < stamp("b") && stamp("b") < stamp("c"));

        // a touch moves the name to most-recent
        touch_index_artifact(&dir, "a").unwrap();
        assert!(stamp("a") > stamp("c"));
        touch_index_artifact(&dir, "nope").unwrap(); // unknown: no-op
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.indexes.len(), 3);

        // re-recording a name replaces the entry with a fresh stamp
        record_index_artifact(&dir, "b", "b.spix", 8, 4).unwrap();
        assert!(stamp("b") > stamp("a"));
        assert_eq!(Manifest::load(&dir).unwrap().find_index("b").unwrap().count, 4);

        // manifests without the field parse as stamp 0 (oldest)
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"entries":[],"indexes":[{"name":"old","file":"old.spix","length":8,"count":1}]}"#,
        )
        .unwrap();
        assert_eq!(stamp("old"), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn measure_specs_roundtrip_and_replace() {
        let dir = std::env::temp_dir().join(format!("spdtw_meas_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        // empty store: no file, no entries
        assert!(load_measure_specs(&dir).unwrap().is_empty());

        record_measure_spec(&dir, 0, &MeasureSpec::Euclidean).unwrap();
        record_measure_spec(&dir, 1, &MeasureSpec::Krdtw { nu: 0.5, band_cells: Some(3) })
            .unwrap();
        let got = load_measure_specs(&dir).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], (0, MeasureSpec::Euclidean));
        assert_eq!(got[1], (1, MeasureSpec::Krdtw { nu: 0.5, band_cells: Some(3) }));

        // re-recording a key replaces, not duplicates
        record_measure_spec(&dir, 0, &MeasureSpec::Dtw).unwrap();
        let got = load_measure_specs(&dir).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], (0, MeasureSpec::Dtw));

        // a torn/garbage file is an error, not a silent empty store
        std::fs::write(dir.join("measures.json"), "{not json").unwrap();
        assert!(load_measure_specs(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kernel_kind_lane_batches_roundtrip() {
        for kind in [
            KernelKind::Dtw,
            KernelKind::Krdtw,
            KernelKind::LbKeogh,
            KernelKind::Spdtw,
        ] {
            assert_eq!(KernelKind::parse(kind.as_str()).unwrap(), kind);
        }
        assert!(KernelKind::parse("lb-keogh").is_err());
    }

    #[test]
    fn missing_manifest_helpful_error() {
        let dir = std::env::temp_dir().join(format!("spdtw_man3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
