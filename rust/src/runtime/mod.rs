//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client from the Rust hot path (no Python anywhere).
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-based (not
//! `Send`), so a dedicated **executor thread** owns the client and all
//! compiled executables; the rest of the system talks to it through the
//! cloneable [`PjrtHandle`] (an mpsc request channel).  The PJRT CPU
//! client parallelizes internally, so one executor thread is not a
//! throughput limiter — see EXPERIMENTS.md §Perf.
//!
//! Device-resident weight planes: the `(2T-1, T)` weight/mask plane is
//! shared by every pair of a (dataset, measure-variant), so the engine
//! caches it as a `PjRtBuffer` keyed by a caller-provided u64 and runs
//! `execute_b` with only x/y re-uploaded per batch.
//!
//! Lane-batched entry points ([`LbKeoghBatch`], [`SpdtwBatch`]) take one
//! query against a **candidate-major** (T, L) operand block — the exact
//! buffer `search::lanes::pack_candidate_major` produces — so the host
//! lane kernels and the PJRT batch API share one marshalling layout.

pub mod artifact;
pub mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;

use crate::error::{Error, Result};
pub use artifact::{
    load_measure_specs, record_index_artifact, record_measure_spec, remove_index_artifact,
    touch_index_artifact, ArtifactEntry, IndexArtifact, KernelKind, Manifest,
};

/// A batched DTW request (f32): `b` pairs of length-`t` series.
#[derive(Clone, Debug)]
pub struct DtwBatch {
    pub t: usize,
    /// Row-major (B, T).
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    /// Cache key of the weight plane previously registered via
    /// [`PjrtHandle::register_plane_f32`].
    pub plane_key: u64,
}

/// A batched K_rdtw request (f64).
#[derive(Clone, Debug)]
pub struct KrdtwBatch {
    pub t: usize,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub plane_key: u64,
    pub nu: f64,
}

/// A lane-batched LB_Keogh request (f64): one query against L candidate
/// envelopes.  The envelope operands are **candidate-major** (T, L) —
/// column j of every lane contiguous — exactly the buffer
/// `search::lanes::pack_candidate_major` produces from the per-lane
/// envelope slices, so the host marshals once and uploads verbatim.
#[derive(Clone, Debug)]
pub struct LbKeoghBatch {
    pub t: usize,
    /// Query values (T).
    pub q: Vec<f64>,
    /// Candidate-major upper envelopes (T, L).
    pub upper: Vec<f64>,
    /// Candidate-major lower envelopes (T, L).
    pub lower: Vec<f64>,
}

/// A lane-batched SP-DTW request (f64): one query against L candidates
/// in candidate-major (T, L) layout (see [`LbKeoghBatch`]).  The packed
/// LOC plane was registered once via
/// [`PjrtHandle::register_plane_f64`] under `plane_key`.
#[derive(Clone, Debug)]
pub struct SpdtwBatch {
    pub t: usize,
    /// Query values (T).
    pub q: Vec<f64>,
    /// Candidate-major candidate values (T, L).
    pub y: Vec<f64>,
    pub plane_key: u64,
}

enum Request {
    RegisterPlaneF32 {
        key: u64,
        t: usize,
        plane: Vec<f32>,
        resp: mpsc::Sender<Result<()>>,
    },
    RegisterPlaneF64 {
        key: u64,
        t: usize,
        plane: Vec<f64>,
        resp: mpsc::Sender<Result<()>>,
    },
    Dtw {
        batch: DtwBatch,
        resp: mpsc::Sender<Result<Vec<f32>>>,
    },
    Krdtw {
        batch: KrdtwBatch,
        resp: mpsc::Sender<Result<Vec<f64>>>,
    },
    LbKeogh {
        batch: LbKeoghBatch,
        resp: mpsc::Sender<Result<Vec<f64>>>,
    },
    Spdtw {
        batch: SpdtwBatch,
        resp: mpsc::Sender<Result<Vec<f64>>>,
    },
    Info {
        resp: mpsc::Sender<EngineInfo>,
    },
}

/// Engine facts exposed for routing decisions and reports.
#[derive(Clone, Debug)]
pub struct EngineInfo {
    pub platform: String,
    pub dtw_lengths: Vec<usize>,
    pub krdtw_lengths: Vec<usize>,
    /// (kernel, T) -> batch size B of the artifact.
    pub batch_of: Vec<(String, usize, usize)>,
}

impl EngineInfo {
    /// Batch size of the (kernel, T) bucket, for any kernel kind —
    /// `batch_of` lists every manifest entry, so this is the single
    /// lookup the router needs (lane kernels included); presence of a
    /// bucket is `kernel_batch(..).is_some()`.
    pub fn kernel_batch(&self, kind: KernelKind, t: usize) -> Option<usize> {
        self.batch_of
            .iter()
            .find(|(k, tt, _)| k == kind.as_str() && *tt == t)
            .map(|&(_, _, b)| b)
    }
    pub fn dtw_batch(&self, t: usize) -> Option<usize> {
        self.kernel_batch(KernelKind::Dtw, t)
    }
    pub fn krdtw_batch(&self, t: usize) -> Option<usize> {
        self.kernel_batch(KernelKind::Krdtw, t)
    }
}

/// Send-able handle to the executor thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: mpsc::Sender<Request>,
}

/// The executor thread plus its handle; dropping joins the thread.
pub struct PjrtRuntime {
    handle: PjrtHandle,
    join: Option<thread::JoinHandle<()>>,
}

impl PjrtRuntime {
    /// Spawn the executor thread; compiles artifacts lazily on first use.
    pub fn start(artifacts_dir: &Path) -> Result<PjrtRuntime> {
        let dir = artifacts_dir.to_path_buf();
        // Validate the manifest on the caller thread for early errors.
        Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || match Engine::new(&dir) {
                Ok(mut engine) => {
                    let _ = ready_tx.send(Ok(()));
                    engine.serve(rx);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| Error::runtime("pjrt executor died during startup"))??;
        Ok(PjrtRuntime {
            handle: PjrtHandle { tx },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> PjrtHandle {
        self.handle.clone()
    }
}

impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        // Closing the channel stops `serve`.
        let (tx, _) = mpsc::channel();
        self.handle = PjrtHandle { tx };
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl PjrtHandle {
    fn call<T>(&self, build: impl FnOnce(mpsc::Sender<T>) -> Request) -> Result<T> {
        let (resp_tx, resp_rx) = mpsc::channel();
        self.tx
            .send(build(resp_tx))
            .map_err(|_| Error::runtime("pjrt executor gone"))?;
        resp_rx
            .recv()
            .map_err(|_| Error::runtime("pjrt executor dropped the request"))
    }

    /// Upload a DTW weight plane (2T-1, T) once; later batches reference
    /// it by key.
    pub fn register_plane_f32(&self, key: u64, t: usize, plane: Vec<f32>) -> Result<()> {
        self.call(|resp| Request::RegisterPlaneF32 { key, t, plane, resp })?
    }

    /// Upload a K_rdtw mask plane (2T-1, T) once.
    pub fn register_plane_f64(&self, key: u64, t: usize, plane: Vec<f64>) -> Result<()> {
        self.call(|resp| Request::RegisterPlaneF64 { key, t, plane, resp })?
    }

    /// Execute one batched DTW; returns B distances.
    pub fn run_dtw(&self, batch: DtwBatch) -> Result<Vec<f32>> {
        self.call(|resp| Request::Dtw { batch, resp })?
    }

    /// Execute one batched K_rdtw; returns B log-kernel values.
    pub fn run_krdtw(&self, batch: KrdtwBatch) -> Result<Vec<f64>> {
        self.call(|resp| Request::Krdtw { batch, resp })?
    }

    /// Execute one lane-batched LB_Keogh; returns L lower bounds.
    pub fn run_lb_keogh(&self, batch: LbKeoghBatch) -> Result<Vec<f64>> {
        self.call(|resp| Request::LbKeogh { batch, resp })?
    }

    /// Execute one lane-batched SP-DTW; returns L distances.
    pub fn run_spdtw(&self, batch: SpdtwBatch) -> Result<Vec<f64>> {
        self.call(|resp| Request::Spdtw { batch, resp })?
    }

    pub fn info(&self) -> Result<EngineInfo> {
        self.call(|resp| Request::Info { resp })
    }
}

/// The executor-thread state: PJRT client, lazily compiled executables,
/// device-resident planes.
struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    planes_f32: HashMap<u64, (usize, xla::PjRtBuffer)>,
    planes_f64: HashMap<u64, (usize, xla::PjRtBuffer)>,
}

impl Engine {
    fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        // PJRT CPU client creation is not safe to race from multiple
        // threads (observed hangs when several runtimes start at once,
        // e.g. under the parallel test harness) — serialize it globally.
        static CLIENT_INIT: std::sync::Mutex<()> = std::sync::Mutex::new(());
        let client = {
            let _guard = CLIENT_INIT.lock().unwrap();
            xla::PjRtClient::cpu().map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e}")))?
        };
        Ok(Engine {
            client,
            manifest,
            dir: dir.to_path_buf(),
            executables: HashMap::new(),
            planes_f32: HashMap::new(),
            planes_f64: HashMap::new(),
        })
    }

    fn serve(&mut self, rx: mpsc::Receiver<Request>) {
        while let Ok(req) = rx.recv() {
            match req {
                Request::RegisterPlaneF32 { key, t, plane, resp } => {
                    let r = self.upload_f32(&plane, t).map(|buf| {
                        self.planes_f32.insert(key, (t, buf));
                    });
                    let _ = resp.send(r);
                }
                Request::RegisterPlaneF64 { key, t, plane, resp } => {
                    let r = self.upload_f64(&plane, t).map(|buf| {
                        self.planes_f64.insert(key, (t, buf));
                    });
                    let _ = resp.send(r);
                }
                Request::Dtw { batch, resp } => {
                    let _ = resp.send(self.run_dtw(&batch));
                }
                Request::Krdtw { batch, resp } => {
                    let _ = resp.send(self.run_krdtw(&batch));
                }
                Request::LbKeogh { batch, resp } => {
                    let _ = resp.send(self.run_lb_keogh(&batch));
                }
                Request::Spdtw { batch, resp } => {
                    let _ = resp.send(self.run_spdtw(&batch));
                }
                Request::Info { resp } => {
                    let _ = resp.send(self.info());
                }
            }
        }
    }

    fn info(&self) -> EngineInfo {
        EngineInfo {
            platform: self.client.platform_name(),
            dtw_lengths: self.manifest.lengths(KernelKind::Dtw),
            krdtw_lengths: self.manifest.lengths(KernelKind::Krdtw),
            batch_of: self
                .manifest
                .entries
                .iter()
                .map(|e| (e.kernel.as_str().to_string(), e.length, e.batch))
                .collect(),
        }
    }

    fn upload_f32(&self, plane: &[f32], t: usize) -> Result<xla::PjRtBuffer> {
        let dims = [2 * t - 1, t];
        self.client
            .buffer_from_host_buffer(plane, &dims, None)
            .map_err(|e| Error::runtime(format!("plane upload: {e}")))
    }

    fn upload_f64(&self, plane: &[f64], t: usize) -> Result<xla::PjRtBuffer> {
        let dims = [2 * t - 1, t];
        self.client
            .buffer_from_host_buffer(plane, &dims, None)
            .map_err(|e| Error::runtime(format!("plane upload: {e}")))
    }

    /// Lazily compile the artifact for (kernel, t).
    fn executable(
        &mut self,
        kernel: KernelKind,
        t: usize,
    ) -> Result<(&xla::PjRtLoadedExecutable, usize)> {
        let entry = self
            .manifest
            .find(kernel, t)
            .ok_or_else(|| {
                Error::runtime(format!(
                    "no {} artifact for T={t} in {} (lengths: {:?})",
                    kernel.as_str(),
                    self.dir.display(),
                    self.manifest.lengths(kernel)
                ))
            })?
            .clone();
        if !self.executables.contains_key(&entry.name) {
            let proto = xla::HloModuleProto::from_text_file(&entry.path)
                .map_err(|e| Error::runtime(format!("parse {}: {e}", entry.path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::runtime(format!("compile {}: {e}", entry.name)))?;
            self.executables.insert(entry.name.clone(), exe);
        }
        Ok((self.executables.get(&entry.name).unwrap(), entry.batch))
    }

    fn run_dtw(&mut self, batch: &DtwBatch) -> Result<Vec<f32>> {
        let t = batch.t;
        let b_have = batch.x.len() / t;
        if batch.x.len() != b_have * t || batch.y.len() != batch.x.len() {
            return Err(Error::runtime("malformed dtw batch shapes"));
        }
        let (_, b_need) = self.executable(KernelKind::Dtw, t)?;
        if b_have != b_need {
            return Err(Error::runtime(format!(
                "dtw batch size {b_have} != artifact batch {b_need} (batcher must pad)"
            )));
        }
        let plane = self
            .planes_f32
            .get(&batch.plane_key)
            .ok_or_else(|| Error::runtime(format!("unregistered f32 plane {}", batch.plane_key)))?;
        if plane.0 != t {
            return Err(Error::runtime("plane length mismatch"));
        }
        let xb = self
            .client
            .buffer_from_host_buffer(&batch.x, &[b_have, t], None)
            .map_err(|e| Error::runtime(format!("x upload: {e}")))?;
        let yb = self
            .client
            .buffer_from_host_buffer(&batch.y, &[b_have, t], None)
            .map_err(|e| Error::runtime(format!("y upload: {e}")))?;
        // compile (if needed) before borrowing the plane immutably
        self.executable(KernelKind::Dtw, t)?;
        let exe = {
            let entry = self.manifest.find(KernelKind::Dtw, t).unwrap();
            self.executables.get(&entry.name).unwrap()
        };
        let plane = self.planes_f32.get(&batch.plane_key).unwrap();
        let out = exe
            .execute_b(&[&xb, &yb, &plane.1])
            .map_err(|e| Error::runtime(format!("dtw execute: {e}")))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("fetch: {e}")))?;
        let tup = lit
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("untuple: {e}")))?;
        tup.to_vec::<f32>()
            .map_err(|e| Error::runtime(format!("to_vec: {e}")))
    }

    fn run_krdtw(&mut self, batch: &KrdtwBatch) -> Result<Vec<f64>> {
        let t = batch.t;
        let b_have = batch.x.len() / t;
        if batch.x.len() != b_have * t || batch.y.len() != batch.x.len() {
            return Err(Error::runtime("malformed krdtw batch shapes"));
        }
        let (_, b_need) = self.executable(KernelKind::Krdtw, t)?;
        if b_have != b_need {
            return Err(Error::runtime(format!(
                "krdtw batch size {b_have} != artifact batch {b_need}"
            )));
        }
        if self.planes_f64.get(&batch.plane_key).map(|p| p.0) != Some(t) {
            return Err(Error::runtime(format!(
                "unregistered f64 plane {} for T={t}",
                batch.plane_key
            )));
        }
        let xb = self
            .client
            .buffer_from_host_buffer(&batch.x, &[b_have, t], None)
            .map_err(|e| Error::runtime(format!("x upload: {e}")))?;
        let yb = self
            .client
            .buffer_from_host_buffer(&batch.y, &[b_have, t], None)
            .map_err(|e| Error::runtime(format!("y upload: {e}")))?;
        let nub = self
            .client
            .buffer_from_host_buffer(&[batch.nu], &[1], None)
            .map_err(|e| Error::runtime(format!("nu upload: {e}")))?;
        self.executable(KernelKind::Krdtw, t)?;
        let exe = {
            let entry = self.manifest.find(KernelKind::Krdtw, t).unwrap();
            self.executables.get(&entry.name).unwrap()
        };
        let plane = self.planes_f64.get(&batch.plane_key).unwrap();
        let out = exe
            .execute_b(&[&xb, &yb, &plane.1, &nub])
            .map_err(|e| Error::runtime(format!("krdtw execute: {e}")))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("fetch: {e}")))?;
        let tup = lit
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("untuple: {e}")))?;
        tup.to_vec::<f64>()
            .map_err(|e| Error::runtime(format!("to_vec: {e}")))
    }

    fn run_lb_keogh(&mut self, batch: &LbKeoghBatch) -> Result<Vec<f64>> {
        let t = batch.t;
        if t == 0 || batch.q.len() != t {
            return Err(Error::runtime("malformed lb_keogh batch shapes"));
        }
        let l_have = batch.upper.len() / t;
        if batch.upper.len() != l_have * t || batch.lower.len() != batch.upper.len() {
            return Err(Error::runtime("malformed lb_keogh batch shapes"));
        }
        let (_, l_need) = self.executable(KernelKind::LbKeogh, t)?;
        if l_have != l_need {
            return Err(Error::runtime(format!(
                "lb_keogh lane count {l_have} != artifact batch {l_need} (lane group must pad)"
            )));
        }
        let qb = self
            .client
            .buffer_from_host_buffer(&batch.q, &[t], None)
            .map_err(|e| Error::runtime(format!("q upload: {e}")))?;
        let ub = self
            .client
            .buffer_from_host_buffer(&batch.upper, &[t, l_have], None)
            .map_err(|e| Error::runtime(format!("upper upload: {e}")))?;
        let lb = self
            .client
            .buffer_from_host_buffer(&batch.lower, &[t, l_have], None)
            .map_err(|e| Error::runtime(format!("lower upload: {e}")))?;
        let exe = {
            let entry = self.manifest.find(KernelKind::LbKeogh, t).unwrap();
            self.executables.get(&entry.name).unwrap()
        };
        let out = exe
            .execute_b(&[&qb, &ub, &lb])
            .map_err(|e| Error::runtime(format!("lb_keogh execute: {e}")))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("fetch: {e}")))?;
        let tup = lit
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("untuple: {e}")))?;
        tup.to_vec::<f64>()
            .map_err(|e| Error::runtime(format!("to_vec: {e}")))
    }

    fn run_spdtw(&mut self, batch: &SpdtwBatch) -> Result<Vec<f64>> {
        let t = batch.t;
        if t == 0 || batch.q.len() != t {
            return Err(Error::runtime("malformed spdtw batch shapes"));
        }
        let l_have = batch.y.len() / t;
        if batch.y.len() != l_have * t {
            return Err(Error::runtime("malformed spdtw batch shapes"));
        }
        let (_, l_need) = self.executable(KernelKind::Spdtw, t)?;
        if l_have != l_need {
            return Err(Error::runtime(format!(
                "spdtw lane count {l_have} != artifact batch {l_need} (lane group must pad)"
            )));
        }
        if self.planes_f64.get(&batch.plane_key).map(|p| p.0) != Some(t) {
            return Err(Error::runtime(format!(
                "unregistered f64 plane {} for T={t}",
                batch.plane_key
            )));
        }
        let qb = self
            .client
            .buffer_from_host_buffer(&batch.q, &[t], None)
            .map_err(|e| Error::runtime(format!("q upload: {e}")))?;
        let yb = self
            .client
            .buffer_from_host_buffer(&batch.y, &[t, l_have], None)
            .map_err(|e| Error::runtime(format!("y upload: {e}")))?;
        let exe = {
            let entry = self.manifest.find(KernelKind::Spdtw, t).unwrap();
            self.executables.get(&entry.name).unwrap()
        };
        let plane = self.planes_f64.get(&batch.plane_key).unwrap();
        let out = exe
            .execute_b(&[&qb, &yb, &plane.1])
            .map_err(|e| Error::runtime(format!("spdtw execute: {e}")))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("fetch: {e}")))?;
        let tup = lit
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("untuple: {e}")))?;
        tup.to_vec::<f64>()
            .map_err(|e| Error::runtime(format!("to_vec: {e}")))
    }
}
