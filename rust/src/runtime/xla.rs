//! In-tree stand-in for the `xla` crate's PJRT surface (the vendored
//! set ships no external crates).  Mirrors exactly the API
//! `runtime::Engine` uses so the crate builds everywhere; every entry
//! point reports the backend as unavailable, which callers already
//! handle by falling back to the native DP path (`PjrtRuntime::start`
//! errors cleanly, routers keep everything on `Backend::Native`).
//!
//! Swapping in the real bindings is a one-line change: delete this
//! module and add the `xla` crate to `Cargo.toml` — the call sites are
//! written against the genuine API.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (Display only — callers format it).
#[derive(Debug)]
pub struct XlaError(String);

impl XlaError {
    fn unavailable() -> XlaError {
        XlaError("xla/PJRT bindings not vendored in this build".into())
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "unavailable".into()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("not vendored"));
        assert!(HloModuleProto::from_text_file("/tmp/nope.hlo").is_err());
    }
}
