//! Normalized Gram matrices over a [`KernelMeasure`].
//!
//! All kernel DPs run in log domain (DESIGN.md §6); the Gram entries are
//! the cosine-normalized `K̃(x,y) = exp(lK(x,y) - (lK(x,x)+lK(y,y))/2)`,
//! which keeps long-series kernels inside f64 range, preserves positive
//! definiteness, and puts the diagonal at exactly 1.

use crate::data::LabeledSet;
use crate::measures::KernelMeasure;
use crate::pool;

/// A dense row-major matrix with visited-cell accounting.
#[derive(Clone, Debug)]
pub struct Gram {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
    pub visited_cells: u64,
}

impl Gram {
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }
}

/// Symmetric train Gram: computes the N(N-1)/2 upper triangle + diagonal
/// self-kernels, mirrors the rest.  Kernel DPs run through
/// [`KernelMeasure::log_k_with`] against per-worker workspaces on the
/// persistent pool — zero allocations per entry once warm.  The two
/// fan-outs are scheduler epochs of the caller's own, so Grams computed
/// by concurrent threads (`Coordinator::submit_train_gram` requests)
/// make progress simultaneously instead of queueing behind one global
/// submit lock.
pub fn train_gram(kernel: &dyn KernelMeasure, set: &LabeledSet, threads: usize) -> Gram {
    let n = set.len();
    let selfk = pool::par_map_ws(n, threads, 1, |i, ws| {
        kernel.log_k_with(ws, &set.series[i], &set.series[i])
    });
    let pairs: Vec<(usize, usize)> = (0..n)
        .flat_map(|i| ((i + 1)..n).map(move |j| (i, j)))
        .collect();
    let vals = pool::par_map_ws(pairs.len(), threads, 1, |k, ws| {
        let (i, j) = pairs[k];
        kernel.log_k_with(ws, &set.series[i], &set.series[j])
    });
    let mut data = vec![0.0; n * n];
    let mut visited: u64 = selfk.iter().map(|d| d.visited_cells).sum();
    for i in 0..n {
        data[i * n + i] = 1.0;
    }
    for (k, &(i, j)) in pairs.iter().enumerate() {
        let lk = vals[k].value - 0.5 * (selfk[i].value + selfk[j].value);
        let v = lk.exp();
        data[i * n + j] = v;
        data[j * n + i] = v;
        visited += vals[k].visited_cells;
    }
    Gram {
        rows: n,
        cols: n,
        data,
        visited_cells: visited,
    }
}

/// Rectangular test-vs-train Gram (rows = test, cols = train).
pub fn cross_gram(
    kernel: &dyn KernelMeasure,
    test: &LabeledSet,
    train: &LabeledSet,
    threads: usize,
) -> Gram {
    let nr = test.len();
    let nc = train.len();
    let self_test = pool::par_map_ws(nr, threads, 1, |i, ws| {
        kernel.log_k_with(ws, &test.series[i], &test.series[i])
    });
    let self_train = pool::par_map_ws(nc, threads, 1, |j, ws| {
        kernel.log_k_with(ws, &train.series[j], &train.series[j])
    });
    let vals = pool::par_map_ws(nr * nc, threads, 1, |k, ws| {
        let (i, j) = (k / nc, k % nc);
        kernel.log_k_with(ws, &test.series[i], &train.series[j])
    });
    let mut data = vec![0.0; nr * nc];
    let mut visited: u64 = self_test.iter().chain(self_train.iter()).map(|d| d.visited_cells).sum();
    for k in 0..nr * nc {
        let (i, j) = (k / nc, k % nc);
        data[k] = (vals[k].value - 0.5 * (self_test[i].value + self_train[j].value)).exp();
        visited += vals[k].visited_cells;
    }
    Gram {
        rows: nr,
        cols: nc,
        data,
        visited_cells: visited,
    }
}

/// 1-NN directly from a cross Gram (larger K̃ = closer) — the kernel
/// variant of the Table II protocol, reusing self-kernels instead of
/// recomputing them per pair as the naive `KrdtwDist` wrapper would.
pub fn gram_1nn_error(cross: &Gram, test: &LabeledSet, train: &LabeledSet) -> f64 {
    assert_eq!(cross.rows, test.len());
    assert_eq!(cross.cols, train.len());
    let mut wrong = 0usize;
    for i in 0..test.len() {
        let mut best = (f64::NEG_INFINITY, usize::MAX);
        for j in 0..train.len() {
            let v = cross.get(i, j);
            if v > best.0 {
                best = (v, train.series[j].label);
            }
        }
        if best.1 != test.series[i].label {
            wrong += 1;
        }
    }
    wrong as f64 / test.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::measures::krdtw::Krdtw;

    fn toy() -> (LabeledSet, LabeledSet) {
        let train = from_pairs(vec![
            (0, vec![0.0, 0.1, 0.0, -0.1, 0.0]),
            (0, vec![0.05, 0.12, -0.02, -0.08, 0.01]),
            (1, vec![1.0, 2.0, 3.0, 2.0, 1.0]),
            (1, vec![1.1, 2.1, 2.9, 1.9, 1.0]),
        ]);
        let test = from_pairs(vec![
            (0, vec![0.02, 0.09, 0.01, -0.12, 0.03]),
            (1, vec![0.9, 2.0, 3.1, 2.1, 0.9]),
        ]);
        (train, test)
    }

    #[test]
    fn train_gram_unit_diagonal_symmetric() {
        let (train, _) = toy();
        let g = train_gram(&Krdtw::new(1.0), &train, 2);
        for i in 0..4 {
            assert!((g.get(i, i) - 1.0).abs() < 1e-12);
            for j in 0..4 {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-12);
                assert!(g.get(i, j) <= 1.0 + 1e-9);
                assert!(g.get(i, j) >= 0.0);
            }
        }
        assert!(g.visited_cells > 0);
    }

    #[test]
    fn same_class_more_similar() {
        let (train, _) = toy();
        let g = train_gram(&Krdtw::new(1.0), &train, 1);
        assert!(g.get(0, 1) > g.get(0, 2));
        assert!(g.get(2, 3) > g.get(1, 3));
    }

    #[test]
    fn gram_1nn_classifies_toy_perfectly() {
        let (train, test) = toy();
        let cg = cross_gram(&Krdtw::new(1.0), &test, &train, 2);
        assert_eq!(gram_1nn_error(&cg, &test, &train), 0.0);
    }

    #[test]
    fn cross_gram_shape() {
        let (train, test) = toy();
        let cg = cross_gram(&Krdtw::new(0.5), &test, &train, 1);
        assert_eq!((cg.rows, cg.cols), (2, 4));
    }
}
