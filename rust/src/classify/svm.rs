//! Support Vector Machine with precomputed (normalized) Gram matrices —
//! the Table IV protocol.  Binary classifier trained by Platt's SMO
//! (simplified heuristic, Stanford CS229 variant); multiclass by
//! one-vs-one majority vote, which is the standard choice for kernel
//! SVMs on UCR-scale class counts.

use crate::classify::gram::Gram;
use crate::classify::EvalResult;
use crate::data::LabeledSet;
use crate::measures::KernelMeasure;
use crate::util::rng::Pcg64;

/// SMO hyper-parameters.
#[derive(Clone, Debug)]
pub struct SvmParams {
    pub c: f64,
    pub tol: f64,
    pub max_passes: usize,
    pub max_iters: usize,
}

impl Default for SvmParams {
    fn default() -> Self {
        SvmParams {
            c: 10.0,
            tol: 1e-3,
            max_passes: 8,
            max_iters: 20_000,
        }
    }
}

/// A trained binary SVM (in precomputed-kernel space: support indices
/// refer to the training Gram rows used at fit time).
#[derive(Clone, Debug)]
pub struct BinarySvm {
    /// alpha_i * y_i for every training point (zeros for non-SVs).
    pub coef: Vec<f64>,
    pub bias: f64,
    /// Indices of the training subset this machine was fit on.
    pub idx: Vec<usize>,
}

impl BinarySvm {
    /// Fit on the sub-problem given by `idx` (train indices) and ±1
    /// labels `y` (parallel to `idx`), over the full train Gram.
    pub fn fit(gram: &Gram, idx: &[usize], y: &[f64], p: &SvmParams, seed: u64) -> BinarySvm {
        let n = idx.len();
        assert_eq!(n, y.len());
        let k = |a: usize, b: usize| gram.get(idx[a], idx[b]);
        let mut alpha = vec![0.0f64; n];
        let mut b = 0.0f64;
        let mut rng = Pcg64::new(seed ^ 0x53_56_4d);
        let f = |alpha: &[f64], b: f64, i: usize, k: &dyn Fn(usize, usize) -> f64| -> f64 {
            let mut s = b;
            for j in 0..n {
                if alpha[j] != 0.0 {
                    s += alpha[j] * y[j] * k(j, i);
                }
            }
            s
        };
        let mut passes = 0usize;
        let mut iters = 0usize;
        while passes < p.max_passes && iters < p.max_iters {
            let mut changed = 0usize;
            for i in 0..n {
                iters += 1;
                let ei = f(&alpha, b, i, &k) - y[i];
                if (y[i] * ei < -p.tol && alpha[i] < p.c) || (y[i] * ei > p.tol && alpha[i] > 0.0) {
                    // pick j != i at random (simplified SMO heuristic)
                    let mut j = rng.below(n - 1);
                    if j >= i {
                        j += 1;
                    }
                    let ej = f(&alpha, b, j, &k) - y[j];
                    let (ai_old, aj_old) = (alpha[i], alpha[j]);
                    let (lo, hi) = if y[i] != y[j] {
                        ((aj_old - ai_old).max(0.0), (p.c + aj_old - ai_old).min(p.c))
                    } else {
                        ((ai_old + aj_old - p.c).max(0.0), (ai_old + aj_old).min(p.c))
                    };
                    if lo >= hi {
                        continue;
                    }
                    let eta = 2.0 * k(i, j) - k(i, i) - k(j, j);
                    if eta >= 0.0 {
                        continue;
                    }
                    let mut aj = aj_old - y[j] * (ei - ej) / eta;
                    aj = aj.clamp(lo, hi);
                    if (aj - aj_old).abs() < 1e-5 {
                        continue;
                    }
                    let ai = ai_old + y[i] * y[j] * (aj_old - aj);
                    alpha[i] = ai;
                    alpha[j] = aj;
                    let b1 =
                        b - ei - y[i] * (ai - ai_old) * k(i, i) - y[j] * (aj - aj_old) * k(i, j);
                    let b2 =
                        b - ej - y[i] * (ai - ai_old) * k(i, j) - y[j] * (aj - aj_old) * k(j, j);
                    b = if ai > 0.0 && ai < p.c {
                        b1
                    } else if aj > 0.0 && aj < p.c {
                        b2
                    } else {
                        (b1 + b2) / 2.0
                    };
                    changed += 1;
                }
            }
            if changed == 0 {
                passes += 1;
            } else {
                passes = 0;
            }
        }
        BinarySvm {
            coef: alpha.iter().zip(y).map(|(a, yy)| a * yy).collect(),
            bias: b,
            idx: idx.to_vec(),
        }
    }

    /// Decision value for a test point given its kernel row vs the FULL
    /// train set (`k_row[t]` = K̃(x_test, x_train_t)).
    pub fn decision(&self, k_row: &[f64]) -> f64 {
        let mut s = self.bias;
        for (pos, &train_i) in self.idx.iter().enumerate() {
            if self.coef[pos] != 0.0 {
                s += self.coef[pos] * k_row[train_i];
            }
        }
        s
    }

    /// KKT violation magnitude at convergence (diagnostic; tests assert
    /// it is small on separable data).
    pub fn max_kkt_violation(&self, gram: &Gram, y: &[f64], c: f64, tol: f64) -> f64 {
        let n = self.idx.len();
        let mut worst = 0.0f64;
        for i in 0..n {
            let mut fi = self.bias;
            for j in 0..n {
                fi += self.coef[j] * gram.get(self.idx[j], self.idx[i]);
            }
            let margin = y[i] * fi;
            let alpha = self.coef[i] * y[i];
            let viol = if alpha <= tol {
                (1.0 - margin).max(0.0) // should satisfy margin >= 1
            } else if alpha >= c - tol {
                (margin - 1.0).max(0.0) // should satisfy margin <= 1
            } else {
                (margin - 1.0).abs() // on the margin
            };
            worst = worst.max(viol);
        }
        worst
    }
}

/// One-vs-one multiclass SVM over precomputed Grams.
pub struct OvoSvm {
    pub machines: Vec<(usize, usize, BinarySvm)>,
    pub labels: Vec<usize>,
}

impl OvoSvm {
    pub fn fit(gram: &Gram, train: &LabeledSet, params: &SvmParams, seed: u64) -> OvoSvm {
        let labels = train.labels();
        let mut machines = Vec::new();
        for a in 0..labels.len() {
            for b in (a + 1)..labels.len() {
                let (la, lb) = (labels[a], labels[b]);
                let idx: Vec<usize> = (0..train.len())
                    .filter(|&i| train.series[i].label == la || train.series[i].label == lb)
                    .collect();
                let y: Vec<f64> = idx
                    .iter()
                    .map(|&i| if train.series[i].label == la { 1.0 } else { -1.0 })
                    .collect();
                let m = BinarySvm::fit(gram, &idx, &y, params, seed ^ ((la * 1009 + lb) as u64));
                machines.push((la, lb, m));
            }
        }
        OvoSvm { machines, labels }
    }

    /// Predict from a cross-Gram row (test point vs all train points).
    pub fn predict_row(&self, k_row: &[f64]) -> usize {
        let mut votes: Vec<(usize, usize)> = self.labels.iter().map(|&l| (l, 0)).collect();
        for (la, lb, m) in &self.machines {
            let winner = if m.decision(k_row) >= 0.0 { *la } else { *lb };
            votes.iter_mut().find(|(l, _)| *l == winner).unwrap().1 += 1;
        }
        votes.into_iter().max_by_key(|&(_, v)| v).unwrap().0
    }
}

/// End-to-end SVM evaluation: train Gram -> OvO fit -> cross Gram ->
/// error rate.  `c_grid` is selected by k-fold CV on the train split.
pub fn classify_svm(
    kernel: &dyn KernelMeasure,
    train: &LabeledSet,
    test: &LabeledSet,
    params: &SvmParams,
    threads: usize,
    seed: u64,
) -> EvalResult {
    let tg = super::gram::train_gram(kernel, train, threads);
    let model = OvoSvm::fit(&tg, train, params, seed);
    let cg = super::gram::cross_gram(kernel, test, train, threads);
    let pred: Vec<usize> = (0..test.len())
        .map(|i| model.predict_row(&cg.data[i * cg.cols..(i + 1) * cg.cols]))
        .collect();
    let visited = tg.visited_cells + cg.visited_cells;
    let cmp = (train.len() * (train.len() - 1) / 2 + test.len() * train.len()) as u64;
    EvalResult::from_predictions(test, &pred, visited, cmp)
}

/// Select C on the train split by stratified k-fold CV over `c_grid`.
pub fn select_c(
    kernel: &dyn KernelMeasure,
    train: &LabeledSet,
    c_grid: &[f64],
    folds: usize,
    threads: usize,
    seed: u64,
) -> f64 {
    use crate::data::splits::{kfold_indices, subset};
    let tg = super::gram::train_gram(kernel, train, threads);
    let parts = kfold_indices(train, folds, seed);
    let mut best = (f64::INFINITY, c_grid[0]);
    for &c in c_grid {
        let mut errs = 0usize;
        let mut total = 0usize;
        for (tr_idx, va_idx) in &parts {
            let tr_set = subset(train, tr_idx);
            // Fit on the fold's sub-Gram: indices into the full Gram.
            let params = SvmParams {
                c,
                ..Default::default()
            };
            let labels = tr_set.labels();
            let mut machines = Vec::new();
            for a in 0..labels.len() {
                for b in (a + 1)..labels.len() {
                    let (la, lb) = (labels[a], labels[b]);
                    let idx: Vec<usize> = tr_idx
                        .iter()
                        .copied()
                        .filter(|&i| train.series[i].label == la || train.series[i].label == lb)
                        .collect();
                    if idx.is_empty() {
                        continue;
                    }
                    let y: Vec<f64> = idx
                        .iter()
                        .map(|&i| if train.series[i].label == la { 1.0 } else { -1.0 })
                        .collect();
                    let m = BinarySvm::fit(&tg, &idx, &y, &params, seed ^ ((la * 31 + lb) as u64));
                    machines.push((la, lb, m));
                }
            }
            for &vi in va_idx {
                let k_row: Vec<f64> = (0..train.len()).map(|j| tg.get(vi, j)).collect();
                let mut votes: Vec<(usize, usize)> = labels.iter().map(|&l| (l, 0)).collect();
                for (la, lb, m) in &machines {
                    let w = if m.decision(&k_row) >= 0.0 { *la } else { *lb };
                    if let Some(v) = votes.iter_mut().find(|(l, _)| *l == w) {
                        v.1 += 1;
                    }
                }
                let pred = votes
                    .into_iter()
                    .max_by_key(|&(_, v)| v)
                    .map(|(l, _)| l)
                    .unwrap_or(usize::MAX);
                if pred != train.series[vi].label {
                    errs += 1;
                }
                total += 1;
            }
        }
        let rate = errs as f64 / total.max(1) as f64;
        if rate < best.0 {
            best = (rate, c);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::data::synthetic;
    use crate::measures::krdtw::Krdtw;

    fn separable() -> (LabeledSet, LabeledSet) {
        let mk = |base: f64, n: usize, label: usize| -> Vec<(usize, Vec<f64>)> {
            (0..n)
                .map(|i| {
                    (
                        label,
                        (0..8).map(|t| base + 0.1 * ((t + i) as f64).sin()).collect(),
                    )
                })
                .collect()
        };
        let mut tr = mk(0.0, 6, 0);
        tr.extend(mk(3.0, 6, 1));
        let mut te = mk(0.05, 3, 0);
        te.extend(mk(2.95, 3, 1));
        (from_pairs(tr), from_pairs(te))
    }

    #[test]
    fn separable_binary_zero_error() {
        let (train, test) = separable();
        let r = classify_svm(&Krdtw::new(1.0), &train, &test, &SvmParams::default(), 2, 1);
        assert_eq!(r.error_rate, 0.0);
        assert!(r.visited_cells > 0);
    }

    #[test]
    fn kkt_conditions_hold_after_fit() {
        let (train, _) = separable();
        let tg = super::super::gram::train_gram(&Krdtw::new(1.0), &train, 2);
        let idx: Vec<usize> = (0..train.len()).collect();
        let y: Vec<f64> = train
            .series
            .iter()
            .map(|s| if s.label == 0 { 1.0 } else { -1.0 })
            .collect();
        let p = SvmParams::default();
        let m = BinarySvm::fit(&tg, &idx, &y, &p, 7);
        let viol = m.max_kkt_violation(&tg, &y, p.c, 1e-6);
        assert!(viol < 0.05, "KKT violation {viol}");
    }

    #[test]
    fn multiclass_on_synthetic_control() {
        // nu must be small enough that off-diagonal Gram entries do not
        // vanish at T=60 (the experiments select nu by CV; 0.01 is the
        // scale CV picks here).
        let ds = synthetic::generate_scaled("SyntheticControl", 5, 36, 24).unwrap();
        let r = classify_svm(&Krdtw::new(0.01), &ds.train, &ds.test, &SvmParams::default(), 4, 3);
        assert!(r.error_rate < 0.35, "error {}", r.error_rate);
    }

    #[test]
    fn dual_coefficients_bounded_by_c() {
        let (train, _) = separable();
        let tg = super::super::gram::train_gram(&Krdtw::new(1.0), &train, 1);
        let idx: Vec<usize> = (0..train.len()).collect();
        let y: Vec<f64> = train
            .series
            .iter()
            .map(|s| if s.label == 0 { 1.0 } else { -1.0 })
            .collect();
        let p = SvmParams { c: 2.0, ..Default::default() };
        let m = BinarySvm::fit(&tg, &idx, &y, &p, 11);
        for (co, yy) in m.coef.iter().zip(&y) {
            let alpha = co * yy;
            assert!((-1e-9..=2.0 + 1e-9).contains(&alpha), "alpha {alpha}");
        }
        // dual feasibility: sum alpha_i y_i = 0
        let s: f64 = m.coef.iter().sum();
        assert!(s.abs() < 1e-6, "sum coef = {s}");
    }

    #[test]
    fn select_c_returns_grid_member() {
        let (train, _) = separable();
        let grid = [0.5, 5.0, 50.0];
        let c = select_c(&Krdtw::new(1.0), &train, &grid, 3, 2, 13);
        assert!(grid.contains(&c));
    }
}
