//! Classification algorithms of the paper's evaluation: 1-NN over any
//! [`Measure`] and SVM (SMO) over any [`KernelMeasure`], plus the Gram
//! matrix machinery shared by both kernel paths.

pub mod gram;
pub mod nn;
pub mod svm;

use crate::data::LabeledSet;

/// Classification outcome on a test split.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// Fraction of misclassified test series in [0, 1].
    pub error_rate: f64,
    /// Total DP cells visited across every pairwise evaluation (Table VI
    /// accounting).
    pub visited_cells: u64,
    /// Total pairwise evaluations performed.
    pub comparisons: u64,
}

impl EvalResult {
    pub fn from_predictions(truth: &LabeledSet, pred: &[usize], visited: u64, cmp: u64) -> Self {
        assert_eq!(truth.len(), pred.len());
        let wrong = truth
            .series
            .iter()
            .zip(pred)
            .filter(|(s, &p)| s.label != p)
            .count();
        EvalResult {
            error_rate: wrong as f64 / truth.len().max(1) as f64,
            visited_cells: visited,
            comparisons: cmp,
        }
    }
}
