//! Nearest-neighbor classification over an arbitrary [`Measure`]
//! (parallel across test series) — the evaluation protocol of Table II.

use std::sync::Arc;

use crate::classify::EvalResult;
use crate::data::LabeledSet;
use crate::measures::Measure;
use crate::pool;
use crate::search::{Cascade, Index, PruneStats, SearchEngine};

/// 1-NN classification of `test` against `train`.
pub fn classify_1nn(
    measure: &dyn Measure,
    train: &LabeledSet,
    test: &LabeledSet,
    threads: usize,
) -> EvalResult {
    classify_knn(measure, train, test, 1, threads)
}

/// k-NN (majority vote, ties broken by the nearer neighbor set).
///
/// Runs on the persistent pool with one long-lived workspace per
/// worker: every distance goes through [`Measure::dist_with`], and the
/// per-probe `(dist, label)` table plus the rank scratch are workspace
/// buffers — the steady-state 1-NN path allocates nothing per distance
/// call.  Each call is one scheduler epoch: classifications issued from
/// distinct threads (e.g. concurrent coordinator clients) overlap on
/// the shared worker set, with bit-identical results either way.
pub fn classify_knn(
    measure: &dyn Measure,
    train: &LabeledSet,
    test: &LabeledSet,
    k: usize,
    threads: usize,
) -> EvalResult {
    assert!(k >= 1 && !train.is_empty() && !test.is_empty());
    let rows = pool::par_map_ws(test.len(), threads, 1, |i, ws| {
        let probe = &test.series[i];
        let mut dists = std::mem::take(&mut ws.dists);
        let mut order = std::mem::take(&mut ws.order);
        let mut top = std::mem::take(&mut ws.top);
        dists.clear();
        dists.reserve(train.len());
        let mut visited = 0u64;
        for tr in &train.series {
            let d = measure.dist_with(ws, probe, tr);
            visited += d.visited_cells;
            dists.push((d.value, tr.label));
        }
        // Rank by (distance, train position): identical to the stable
        // sort over distances the brute-force protocol specifies, but
        // via a non-allocating unstable index sort — the `(dist, idx)`
        // key is a duplicate-free total order (total_cmp, not
        // partial_cmp().unwrap(): a NaN distance must not panic the
        // whole run — it sorts after every real distance instead).
        order.clear();
        order.extend(0..dists.len());
        order.sort_unstable_by(|&a, &b| dists[a].0.total_cmp(&dists[b].0).then(a.cmp(&b)));
        top.clear();
        top.extend(order.iter().take(k.min(dists.len())).map(|&j| dists[j]));
        let label = vote(&top);
        ws.dists = dists;
        ws.order = order;
        ws.top = top;
        (label, visited, train.len() as u64)
    });
    let pred: Vec<usize> = rows.iter().map(|r| r.0).collect();
    let visited: u64 = rows.iter().map(|r| r.1).sum();
    let cmp: u64 = rows.iter().map(|r| r.2).sum();
    EvalResult::from_predictions(test, &pred, visited, cmp)
}

/// Majority vote over the k nearest `(distance, label)` pairs: largest
/// count wins, count ties broken by the smaller minimum distance.
/// Public so the index-backed search path votes identically.
pub fn vote(nearest: &[(f64, usize)]) -> usize {
    let mut counts: Vec<(usize, usize, f64)> = Vec::new(); // (label, count, min_dist)
    for &(d, l) in nearest {
        match counts.iter_mut().find(|(lab, _, _)| *lab == l) {
            Some((_, c, md)) => {
                *c += 1;
                if d < *md {
                    *md = d;
                }
            }
            None => counts.push((l, 1, d)),
        }
    }
    counts
        .into_iter()
        // NaN-safe: total_cmp ranks a NaN min-dist as farthest.
        .max_by(|a, b| a.1.cmp(&b.1).then_with(|| b.2.total_cmp(&a.2)))
        .map(|(l, _, _)| l)
        .unwrap()
}

/// Index-backed k-NN: identical decisions to [`classify_knn`] over the
/// same DP measure, but served through the `search` cascade (lower
/// bounds + early abandoning) instead of exhaustive evaluation.
/// Returns the usual [`EvalResult`] plus the cascade's [`PruneStats`].
pub fn classify_knn_indexed(
    index: &Arc<Index>,
    cascade: Cascade,
    test: &LabeledSet,
    k: usize,
    threads: usize,
) -> (EvalResult, PruneStats) {
    SearchEngine::new(Arc::clone(index), cascade).classify(test, k, threads)
}

/// Leave-one-out 1-NN error on a single set — the paper's protocol for
/// tuning θ / ν / band on the train split (Fig. 4).
pub fn loo_error_1nn(measure: &dyn Measure, set: &LabeledSet, threads: usize) -> f64 {
    let n = set.len();
    assert!(n >= 2);
    let wrong = pool::par_map_ws(n, threads, 1, |i, ws| {
        let probe = &set.series[i];
        let mut best = (f64::INFINITY, usize::MAX);
        for (j, tr) in set.series.iter().enumerate() {
            if j == i {
                continue;
            }
            let d = measure.dist_with(ws, probe, tr).value;
            if d < best.0 {
                best = (d, tr.label);
            }
        }
        (best.1 != probe.label) as u64
    });
    wrong.iter().sum::<u64>() as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::data::synthetic;
    use crate::measures::euclidean::Euclidean;

    #[test]
    fn perfectly_separable_zero_error() {
        let train = from_pairs(vec![
            (0, vec![0.0, 0.0, 0.0]),
            (1, vec![10.0, 10.0, 10.0]),
        ]);
        let test = from_pairs(vec![
            (0, vec![0.1, -0.1, 0.0]),
            (1, vec![9.9, 10.1, 10.0]),
        ]);
        let r = classify_1nn(&Euclidean, &train, &test, 2);
        assert_eq!(r.error_rate, 0.0);
        assert_eq!(r.comparisons, 4);
        assert_eq!(r.visited_cells, 4 * 3);
    }

    #[test]
    fn always_wrong_is_one() {
        let train = from_pairs(vec![(1, vec![0.0]), (0, vec![10.0])]);
        let test = from_pairs(vec![(0, vec![0.0]), (1, vec![10.0])]);
        let r = classify_1nn(&Euclidean, &train, &test, 1);
        assert_eq!(r.error_rate, 1.0);
    }

    #[test]
    fn knn_majority_beats_single_outlier() {
        let train = from_pairs(vec![
            (0, vec![0.0]),
            (0, vec![0.2]),
            (0, vec![-0.2]),
            (1, vec![0.05]), // outlier of class 1 closest to probe
        ]);
        let test = from_pairs(vec![(0, vec![0.04])]);
        let r1 = classify_knn(&Euclidean, &train, &test, 1, 1);
        assert_eq!(r1.error_rate, 1.0); // 1-NN fooled
        let r3 = classify_knn(&Euclidean, &train, &test, 3, 1);
        assert_eq!(r3.error_rate, 0.0); // 3-NN majority correct
    }

    #[test]
    fn loo_error_on_separable_data_is_low() {
        let ds = synthetic::generate_scaled("CBF", 13, 18, 0).unwrap();
        let err = loo_error_1nn(&Euclidean, &ds.train, 2);
        assert!(err <= 0.5, "LOO error {err} unexpectedly high");
    }

    #[test]
    fn nan_distance_does_not_panic_and_loses() {
        use crate::data::TimeSeries;
        use crate::measures::DistResult;

        /// Returns NaN against one poisoned train series, Euclidean else.
        struct NanAgainstFirst;
        impl Measure for NanAgainstFirst {
            fn name(&self) -> String {
                "nan-probe".into()
            }
            fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
                if y.label == 9 {
                    DistResult::new(f64::NAN, 1)
                } else {
                    Euclidean.dist(x, y)
                }
            }
        }

        let train = from_pairs(vec![(9, vec![0.0]), (0, vec![0.1]), (1, vec![10.0])]);
        let test = from_pairs(vec![(0, vec![0.0])]);
        // pre-fix this panicked in sort_by(partial_cmp().unwrap());
        // post-fix the NaN candidate simply sorts last.
        let r = classify_1nn(&NanAgainstFirst, &train, &test, 1);
        assert_eq!(r.error_rate, 0.0);
    }

    #[test]
    fn indexed_path_matches_exhaustive() {
        use crate::measures::dtw::BandedDtw;

        let ds = synthetic::generate_scaled("CBF", 2, 14, 10).unwrap();
        let band = 6;
        let index = Arc::new(Index::build(&ds.train, band, 2));
        let (eval, stats) = classify_knn_indexed(&index, Cascade::default(), &ds.test, 1, 2);
        let brute = classify_1nn(&BandedDtw(band), &ds.train, &ds.test, 2);
        assert_eq!(eval.error_rate, brute.error_rate);
        assert!(stats.pruned() > 0);
        assert!(stats.dp_cells < brute.visited_cells);
    }

    #[test]
    fn threads_invariant() {
        let ds = synthetic::generate_scaled("Gun-Point", 3, 16, 10).unwrap();
        let a = classify_1nn(&Euclidean, &ds.train, &ds.test, 1);
        let b = classify_1nn(&Euclidean, &ds.train, &ds.test, 4);
        assert_eq!(a.error_rate, b.error_rate);
        assert_eq!(a.visited_cells, b.visited_cells);
    }
}
