//! LOC sparse alignment-path matrix (paper §III): the thresholded
//! occupancy grid stored as a list of (row, col, weight) coordinates
//! sorted by increasing row then column — exactly the iteration order
//! Algorithms 1 and 2 require.  Internally CSR for O(log nnz_row)
//! predecessor lookups in the sparse DP.

use crate::error::{Error, Result};
use crate::measures::BIG;

/// Sentinel for "no predecessor" in the precomputed DP dependency lists.
pub const NO_PRED: u32 = u32::MAX;

/// Sparse cell matrix in CSR layout with per-cell weights.
#[derive(Clone, Debug, PartialEq)]
pub struct LocMatrix {
    /// Grid side (T).
    pub t: usize,
    /// CSR row pointers, len = t + 1.
    pub row_ptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub cols: Vec<u32>,
    /// Row index of every entry (parallel to `cols`) — lets the DP hot
    /// loop run flat over entries without re-deriving the row.
    pub rows: Vec<u32>,
    /// Cell weights, parallel to `cols` (SP-DTW's f(p) values; all-ones
    /// for the kernel variants).
    pub weights: Vec<f64>,
    /// Precomputed DP dependency indices per entry:
    /// `[diag (r-1,c-1), up (r-1,c), left (r,c-1)]`, `NO_PRED` when the
    /// predecessor cell is not in the LOC set.  Data-independent, built
    /// once at construction — turns Algorithms 1 & 2 into flat loops
    /// with three indexed loads per cell (§Perf, EXPERIMENTS.md).
    pub preds: Vec<[u32; 3]>,
}

impl LocMatrix {
    /// Build from (row, col, weight) triples (any order; deduplicated by
    /// keeping the last weight).  Panics on out-of-range cells — use
    /// [`Self::try_from_triples`] for untrusted input.
    pub fn from_triples(t: usize, triples: Vec<(usize, usize, f64)>) -> Self {
        Self::try_from_triples(t, triples).expect("invalid LOC triples")
    }

    /// Fallible [`Self::from_triples`]: rejects out-of-range cells and
    /// non-finite weights instead of panicking — the entry point for
    /// grids read back from disk or the wire (`search::persist`, the
    /// TCP protocol).
    pub fn try_from_triples(t: usize, mut triples: Vec<(usize, usize, f64)>) -> Result<Self> {
        triples.sort_by_key(|&(r, c, _)| (r, c));
        triples.dedup_by_key(|&mut (r, c, _)| (r, c));
        let mut row_ptr = vec![0usize; t + 1];
        for &(r, c, w) in &triples {
            if r >= t || c >= t {
                return Err(Error::data(format!(
                    "LOC cell ({r}, {c}) out of range (t={t})"
                )));
            }
            if !w.is_finite() {
                return Err(Error::data(format!(
                    "LOC cell ({r}, {c}) has non-finite weight {w}"
                )));
            }
            row_ptr[r + 1] += 1;
        }
        for i in 0..t {
            row_ptr[i + 1] += row_ptr[i];
        }
        let cols: Vec<u32> = triples.iter().map(|&(_, c, _)| c as u32).collect();
        let rows: Vec<u32> = triples.iter().map(|&(r, _, _)| r as u32).collect();
        let weights = triples.iter().map(|&(_, _, w)| w).collect();
        let mut m = LocMatrix {
            t,
            row_ptr,
            cols,
            rows,
            weights,
            preds: Vec::new(),
        };
        m.preds = m.build_preds();
        Ok(m)
    }

    /// Predecessor index table (see field docs).  One binary search per
    /// (entry, predecessor) at build time; O(1) loads at eval time.
    fn build_preds(&self) -> Vec<[u32; 3]> {
        let mut preds = vec![[NO_PRED; 3]; self.cols.len()];
        for r in 0..self.t {
            let (rs, re) = (self.row_ptr[r], self.row_ptr[r + 1]);
            for k in rs..re {
                let c = self.cols[k] as usize;
                let p = &mut preds[k];
                if r > 0 {
                    if c > 0 {
                        if let Some(i) = self.index_of(r - 1, c - 1) {
                            p[0] = i as u32;
                        }
                    }
                    if let Some(i) = self.index_of(r - 1, c) {
                        p[1] = i as u32;
                    }
                }
                // left neighbor is simply the previous entry when adjacent
                if c > 0 && k > rs && self.cols[k - 1] as usize == c - 1 {
                    p[2] = (k - 1) as u32;
                }
            }
        }
        preds
    }

    /// Full grid with unit weights (SP-DTW degenerates to DTW on it).
    pub fn full(t: usize) -> Self {
        let mut triples = Vec::with_capacity(t * t);
        for r in 0..t {
            for c in 0..t {
                triples.push((r, c, 1.0));
            }
        }
        Self::from_triples(t, triples)
    }

    /// Sakoe-Chiba corridor with unit weights.
    pub fn corridor(t: usize, band: usize) -> Self {
        let mut triples = Vec::new();
        for r in 0..t {
            let lo = r.saturating_sub(band);
            let hi = (r + band).min(t - 1);
            for c in lo..=hi {
                triples.push((r, c, 1.0));
            }
        }
        Self::from_triples(t, triples)
    }

    /// Number of stored (admissible) cells = the paper's "# visited
    /// cells" for SP measures (Table VI).
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Sparsity ratio = 1 - nnz / T².
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.t * self.t) as f64
    }

    /// Paper Table VI speed-up percentage vs the full grid.
    pub fn speedup_pct(&self) -> f64 {
        100.0 * self.sparsity()
    }

    /// Weight at (r, c), or None if the cell is sparsified out.
    pub fn get(&self, r: usize, c: usize) -> Option<f64> {
        let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
        self.cols[s..e]
            .binary_search(&(c as u32))
            .ok()
            .map(|k| self.weights[s + k])
    }

    /// Position in the value arrays of cell (r, c), if present.
    #[inline]
    pub fn index_of(&self, r: usize, c: usize) -> Option<usize> {
        let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
        self.cols[s..e]
            .binary_search(&(c as u32))
            .ok()
            .map(|k| s + k)
    }

    /// Iterate cells in (row, col) order as (row, col, weight, flat_idx).
    pub fn iter_cells(&self) -> impl Iterator<Item = (usize, usize, f64, usize)> + '_ {
        (0..self.t).flat_map(move |r| {
            (self.row_ptr[r]..self.row_ptr[r + 1]).map(move |k| {
                (r, self.cols[k] as usize, self.weights[k], k)
            })
        })
    }

    /// Contains the full main diagonal? (guarantees every pair has at
    /// least one admissible path).
    pub fn has_diagonal(&self) -> bool {
        (0..self.t).all(|i| self.get(i, i).is_some())
    }

    /// Symmetric as a cell set? (paper: grids are symmetrized).
    pub fn is_symmetric_support(&self) -> bool {
        self.iter_cells().all(|(r, c, _, _)| self.get(c, r).is_some())
    }

    /// Dense weight plane packed per anti-diagonal: row k holds cells of
    /// anti-diagonal i + j = k indexed by i; missing cells get `BIG`
    /// (DTW) — the exact input layout of the AOT Pallas artifacts
    /// (`python/compile/kernels/common.py::pack_diagonals`).
    pub fn pack_weight_plane_f32(&self) -> Vec<f32> {
        let t = self.t;
        let mut plane = vec![BIG as f32; (2 * t - 1) * t];
        for (r, c, w, _) in self.iter_cells() {
            plane[(r + c) * t + r] = w as f32;
        }
        plane
    }

    /// Binary mask plane (1.0 = admissible), f64 — the K_rdtw artifact
    /// layout (weights intentionally dropped to preserve definiteness,
    /// paper §IV).
    pub fn pack_mask_plane_f64(&self) -> Vec<f64> {
        let t = self.t;
        let mut plane = vec![0.0f64; (2 * t - 1) * t];
        for (r, c, _, _) in self.iter_cells() {
            plane[(r + c) * t + r] = 1.0;
        }
        plane
    }

    /// Serialize as sorted triples (for persistence / the TCP protocol).
    pub fn to_triples(&self) -> Vec<(usize, usize, f64)> {
        self.iter_cells().map(|(r, c, w, _)| (r, c, w)).collect()
    }

    /// Widest off-diagonal reach `max |r - c|` over the retained cells —
    /// the tightest envelope radius for which LB_Keogh stays admissible
    /// for SP-DTW over this grid (`search::Index::build_spdtw`).
    pub fn max_band_offset(&self) -> usize {
        self.rows
            .iter()
            .zip(&self.cols)
            .map(|(&r, &c)| (r as i64 - c as i64).unsigned_abs() as usize)
            .max()
            .unwrap_or(0)
    }

    /// Smallest cell weight (INFINITY for an empty grid).  Lower bounds
    /// derived from unweighted costs require this to be ≥ 1.
    pub fn min_weight(&self) -> f64 {
        self.weights.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Resident heap footprint in bytes (CSR pointers + the four
    /// nnz-parallel arrays) — folded into `Index::memory_bytes` so the
    /// TCP `register_index` reply accounts for attached grids.
    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.cols.len() * std::mem::size_of::<u32>()
            + self.rows.len() * std::mem::size_of::<u32>()
            + self.weights.len() * std::mem::size_of::<f64>()
            + self.preds.len() * std::mem::size_of::<[u32; 3]>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_triples_sorted_csr() {
        let m = LocMatrix::from_triples(
            3,
            vec![(2, 1, 0.5), (0, 0, 1.0), (2, 0, 0.25), (1, 1, 2.0)],
        );
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.get(0, 0), Some(1.0));
        assert_eq!(m.get(2, 0), Some(0.25));
        assert_eq!(m.get(2, 1), Some(0.5));
        assert_eq!(m.get(0, 1), None);
        // row-major sorted iteration
        let order: Vec<(usize, usize)> = m.iter_cells().map(|(r, c, _, _)| (r, c)).collect();
        assert_eq!(order, vec![(0, 0), (1, 1), (2, 0), (2, 1)]);
    }

    #[test]
    fn dedup_keeps_one_entry() {
        let m = LocMatrix::from_triples(2, vec![(0, 0, 1.0), (0, 0, 3.0), (1, 1, 1.0)]);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn full_and_corridor_counts() {
        assert_eq!(LocMatrix::full(5).nnz(), 25);
        assert_eq!(LocMatrix::corridor(5, 0).nnz(), 5);
        assert_eq!(LocMatrix::corridor(5, 1).nnz(), 13);
        assert!(LocMatrix::corridor(5, 1).has_diagonal());
        assert!(LocMatrix::corridor(5, 1).is_symmetric_support());
    }

    #[test]
    fn sparsity_and_speedup() {
        let m = LocMatrix::corridor(10, 0);
        assert!((m.sparsity() - 0.9).abs() < 1e-12);
        assert!((m.speedup_pct() - 90.0).abs() < 1e-9);
    }

    #[test]
    fn weight_plane_layout_matches_python() {
        // mirror of python pack_diagonals: plane[k][i] = w[i, k-i]
        let m = LocMatrix::from_triples(3, vec![(0, 0, 1.0), (1, 1, 2.0), (2, 1, 4.0)]);
        let plane = m.pack_weight_plane_f32();
        let t = 3;
        let get = |k: usize, i: usize| plane[k * t + i];
        assert_eq!(get(0, 0), 1.0); // (0,0) on diag 0
        assert_eq!(get(2, 1), 2.0); // (1,1) on diag 2
        assert_eq!(get(3, 2), 4.0); // (2,1) on diag 3
        // everything else BIG
        let big = BIG as f32;
        assert_eq!(get(1, 0), big);
        assert_eq!(get(4, 2), big);
    }

    #[test]
    fn mask_plane_counts() {
        let m = LocMatrix::corridor(4, 1);
        let plane = m.pack_mask_plane_f64();
        let ones = plane.iter().filter(|&&v| v == 1.0).count();
        assert_eq!(ones, m.nnz());
    }

    #[test]
    fn band_offset_and_min_weight() {
        assert_eq!(LocMatrix::corridor(8, 0).max_band_offset(), 0);
        assert_eq!(LocMatrix::corridor(8, 3).max_band_offset(), 3);
        assert_eq!(LocMatrix::full(5).max_band_offset(), 4);
        let m = LocMatrix::from_triples(4, vec![(0, 0, 2.0), (3, 0, 0.5), (3, 3, 1.0)]);
        assert_eq!(m.max_band_offset(), 3);
        assert_eq!(m.min_weight(), 0.5);
    }

    #[test]
    fn triples_roundtrip() {
        let m = LocMatrix::corridor(6, 2);
        let back = LocMatrix::from_triples(6, m.to_triples());
        assert_eq!(m, back);
    }

    #[test]
    fn try_from_triples_rejects_bad_cells() {
        assert!(LocMatrix::try_from_triples(3, vec![(3, 0, 1.0)]).is_err());
        assert!(LocMatrix::try_from_triples(3, vec![(0, 5, 1.0)]).is_err());
        assert!(LocMatrix::try_from_triples(3, vec![(0, 0, f64::NAN)]).is_err());
        assert!(LocMatrix::try_from_triples(3, vec![(0, 0, f64::INFINITY)]).is_err());
        let ok = LocMatrix::try_from_triples(3, vec![(0, 0, 1.0), (2, 2, 2.0)]).unwrap();
        assert_eq!(ok.nnz(), 2);
    }

    #[test]
    fn memory_bytes_scales_with_nnz() {
        let small = LocMatrix::corridor(8, 0);
        let big = LocMatrix::corridor(8, 3);
        assert!(big.memory_bytes() > small.memory_bytes());
        // 4 (cols) + 4 (rows) + 8 (weights) + 12 (preds) bytes per entry
        assert_eq!(
            big.memory_bytes(),
            9 * std::mem::size_of::<usize>() + big.nnz() * 28
        );
    }
}
