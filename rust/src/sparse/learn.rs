//! Occupancy-grid learning (Fig. 3-a/b/c): compute the optimal DTW
//! alignment path for every unordered training pair and accumulate the
//! symmetrized occupancy counts.  The N(N-1)/2 pairwise DPs are
//! embarrassingly parallel (`pool::par_map`).

use crate::data::LabeledSet;
use crate::measures::dtw::{dtw_path_into, Path};
use crate::pool;
use crate::sparse::OccupancyGrid;

/// Learn the occupancy grid from a training set.
pub fn learn_occupancy_grid(train: &LabeledSet, threads: usize) -> OccupancyGrid {
    let n = train.len();
    let t = train.series_len();
    assert!(t > 0, "empty series");
    let mut grid = OccupancyGrid::new(t);
    if n < 2 {
        return grid;
    }
    // Enumerate unordered pairs (i < j).
    let pairs: Vec<(usize, usize)> = (0..n)
        .flat_map(|i| ((i + 1)..n).map(move |j| (i, j)))
        .collect();
    // The O(T²) backtracking matrix comes from each worker's long-lived
    // workspace, so the N(N-1)/2 pairwise DPs allocate only their
    // returned paths.
    let paths = pool::par_map_ws(pairs.len(), threads, 1, |k, ws| {
        let (i, j) = pairs[k];
        let mut path = Path::new();
        dtw_path_into(
            ws,
            &train.series[i].values,
            &train.series[j].values,
            &mut path,
        );
        path
    });
    for path in &paths {
        grid.add_path(path);
    }
    // The learn pass is the only consumer of the O(T²) workspace
    // matrix; release it so long-lived workers keep only their
    // steady-state serving buffers warm.
    pool::trim_workspaces();
    grid
}

/// Learning-phase cost in DP cells (N(N-1)/2 full grids) — reported by
/// the experiments so the one-off sparsification cost is visible next to
/// the per-query savings it buys.
pub fn learning_cost_cells(n: usize, t: usize) -> u64 {
    (n as u64) * (n as u64 - 1) / 2 * (t as u64) * (t as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::data::synthetic;

    #[test]
    fn grid_accumulates_all_pairs() {
        let set = from_pairs(vec![
            (0, vec![0.0, 1.0, 2.0, 3.0]),
            (0, vec![0.0, 1.0, 2.0, 3.0]),
            (1, vec![3.0, 2.0, 1.0, 0.0]),
        ]);
        let grid = learn_occupancy_grid(&set, 2);
        assert_eq!(grid.pairs, 3); // C(3,2)
        assert_eq!(grid.t, 4);
        // identical series pair aligns on the diagonal
        assert!(grid.count(0, 0) >= 1);
        assert!(grid.count(3, 3) >= 1);
    }

    #[test]
    fn corners_always_occupied() {
        // boundary condition: every path contains (0,0) and (T-1,T-1)
        let ds = synthetic::generate_scaled("CBF", 3, 10, 0).unwrap();
        let grid = learn_occupancy_grid(&ds.train, 4);
        let n_pairs = grid.pairs as u32;
        assert_eq!(grid.count(0, 0), n_pairs);
        assert_eq!(grid.count(grid.t - 1, grid.t - 1), n_pairs);
    }

    #[test]
    fn grid_concentrates_near_diagonal_for_warped_classes() {
        // The paper's premise: optimal paths of structured data occupy a
        // narrow region; off-corner cells far from the diagonal stay 0.
        let ds = synthetic::generate_scaled("CBF", 7, 14, 0).unwrap();
        let grid = learn_occupancy_grid(&ds.train, 4);
        let t = grid.t;
        // a far-off-diagonal cell like (5, T-5) should be unvisited
        assert_eq!(grid.count(5, t - 5), 0);
        // support far below T^2
        assert!(grid.support() < t * t / 2, "support={} t2={}", grid.support(), t * t);
    }

    #[test]
    fn single_series_empty_grid() {
        let set = from_pairs(vec![(0, vec![1.0, 2.0])]);
        let grid = learn_occupancy_grid(&set, 2);
        assert_eq!(grid.pairs, 0);
        assert_eq!(grid.support(), 0);
    }

    #[test]
    fn threads_do_not_change_result() {
        let ds = synthetic::generate_scaled("Gun-Point", 5, 10, 0).unwrap();
        let g1 = learn_occupancy_grid(&ds.train, 1);
        let g4 = learn_occupancy_grid(&ds.train, 4);
        assert_eq!(g1.counts, g4.counts);
    }

    #[test]
    fn cost_formula() {
        assert_eq!(learning_cost_cells(10, 100), 45 * 10_000);
    }
}
