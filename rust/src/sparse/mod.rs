//! Occupancy-grid sparsification (paper §III, Fig. 3): accumulate the
//! optimal alignment paths of all training pairs into a T×T frequency
//! grid, threshold it, and export the sparse LOC matrix that SP-DTW /
//! SP-K_rdtw iterate over.

pub mod learn;
pub mod loc;

pub use loc::LocMatrix;

/// Absolute path-occupancy counts over a T×T grid (Fig. 3-c).
#[derive(Clone, Debug)]
pub struct OccupancyGrid {
    pub t: usize,
    /// Row-major absolute frequencies (symmetrized).
    pub counts: Vec<u32>,
    /// Number of (unordered) training pairs accumulated.
    pub pairs: usize,
}

impl OccupancyGrid {
    pub fn new(t: usize) -> Self {
        OccupancyGrid {
            t,
            counts: vec![0; t * t],
            pairs: 0,
        }
    }

    /// Accumulate one optimal path, symmetrized: cell (i, j) and its
    /// mirror (j, i) both count (the paper computes N(N-1)/2 pairs and
    /// symmetrizes instead of running all N² orderings).
    pub fn add_path(&mut self, path: &[(usize, usize)]) {
        for &(i, j) in path {
            debug_assert!(i < self.t && j < self.t);
            self.counts[i * self.t + j] += 1;
            if i != j {
                self.counts[j * self.t + i] += 1;
            }
        }
        self.pairs += 1;
    }

    pub fn count(&self, i: usize, j: usize) -> u32 {
        self.counts[i * self.t + j]
    }

    pub fn max_count(&self) -> u32 {
        self.counts.iter().copied().max().unwrap_or(0)
    }

    /// Cells with non-zero occupancy (Fig. 3-d support).
    pub fn support(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    /// Normalized frequency p(m_tt') ∈ [0, 1] (Fig. 3-d): counts scaled
    /// by the maximum cell count.
    pub fn normalized(&self, i: usize, j: usize) -> f64 {
        let m = self.max_count();
        if m == 0 {
            0.0
        } else {
            self.count(i, j) as f64 / m as f64
        }
    }

    /// Apply the occupancy threshold θ (Fig. 3-e).  θ is expressed as a
    /// *percentage of the maximum cell count* — the paper's grid search
    /// sweeps θ over [0, 15] (Fig. 4), and a relative threshold keeps
    /// that range meaningful for any train-set size: a cell survives iff
    /// `count > θ/100 · max_count`.  θ = 0 keeps every visited cell.
    pub fn threshold(&self, theta: f64) -> ThresholdedGrid {
        ThresholdedGrid {
            grid: self.clone(),
            theta,
        }
    }

    /// Absolute count a cell must exceed to survive threshold θ.
    pub fn cutoff(&self, theta: f64) -> f64 {
        if theta <= 0.0 {
            0.0
        } else {
            theta / 100.0 * self.max_count() as f64
        }
    }
}

/// An occupancy grid with a threshold applied (Fig. 3-e) — convertible
/// into the final LOC sparse matrix (Fig. 3-f).
#[derive(Clone, Debug)]
pub struct ThresholdedGrid {
    pub grid: OccupancyGrid,
    pub theta: f64,
}

impl ThresholdedGrid {
    /// Retained-cell count.
    pub fn nnz(&self) -> usize {
        let cut = self.grid.cutoff(self.theta);
        self.grid
            .counts
            .iter()
            .filter(|&&c| c as f64 > cut)
            .count()
    }

    /// Export the LOC matrix with SP-DTW weights `f(p) = p^-gamma`
    /// (paper Eq. 9; gamma = 0 gives unit weights = plain DTW costs on
    /// the retained cells).  The main diagonal is always retained so
    /// every pair keeps at least one admissible path — without it, test
    /// pairs whose optimal path strays from the training distribution
    /// would become unreachable (Algorithm 1 returns Max_Float).
    pub fn to_loc(&self, gamma: f64) -> LocMatrix {
        let t = self.grid.t;
        let max = self.grid.max_count().max(1) as f64;
        let cut = self.grid.cutoff(self.theta);
        let mut triples = Vec::new();
        for i in 0..t {
            for j in 0..t {
                let c = self.grid.count(i, j) as f64;
                let keep = c > cut || i == j;
                if keep {
                    let p = (c / max).max(1.0 / max); // avoid p = 0 on forced diagonal
                    let w = if gamma == 0.0 { 1.0 } else { p.powf(-gamma) };
                    triples.push((i, j, w));
                }
            }
        }
        LocMatrix::from_triples(t, triples)
    }

    /// Export with unit weights (the kernel variants drop weights to
    /// preserve definiteness, paper §IV).
    pub fn to_loc_mask(&self) -> LocMatrix {
        self.to_loc(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_path_symmetrizes() {
        let mut g = OccupancyGrid::new(4);
        g.add_path(&[(0, 0), (1, 2), (3, 3)]);
        assert_eq!(g.count(1, 2), 1);
        assert_eq!(g.count(2, 1), 1);
        assert_eq!(g.count(0, 0), 1); // diagonal not double-counted
        assert_eq!(g.pairs, 1);
    }

    #[test]
    fn threshold_monotone() {
        let mut g = OccupancyGrid::new(3);
        g.add_path(&[(0, 0), (1, 1), (2, 2)]);
        g.add_path(&[(0, 0), (0, 1), (1, 2), (2, 2)]);
        let n0 = g.threshold(0.0).nnz();
        let n1 = g.threshold(1.0).nnz();
        assert!(n1 <= n0);
        assert!(n0 <= 9);
    }

    #[test]
    fn loc_always_has_diagonal() {
        let g = OccupancyGrid::new(5); // empty grid
        let loc = g.threshold(0.0).to_loc(1.0);
        assert!(loc.has_diagonal());
        assert_eq!(loc.nnz(), 5);
    }

    #[test]
    fn weights_follow_negative_power_law() {
        let mut g = OccupancyGrid::new(2);
        // (0,0) visited twice, (1,1) once, (0,1)+(1,0) once
        g.add_path(&[(0, 0), (1, 1)]);
        g.add_path(&[(0, 0), (0, 1), (1, 1)]);
        let loc = g.threshold(0.0).to_loc(1.0);
        let w00 = loc.get(0, 0).unwrap(); // p = 1.0 -> w = 1.0
        let w01 = loc.get(0, 1).unwrap(); // p = 0.5 -> w = 2.0
        assert!((w00 - 1.0).abs() < 1e-12);
        assert!((w01 - 2.0).abs() < 1e-12);
        // higher-frequency cells get SMALLER weights (privileged)
        assert!(w00 < w01);
    }

    #[test]
    fn gamma_zero_unit_weights() {
        let mut g = OccupancyGrid::new(3);
        g.add_path(&[(0, 0), (1, 1), (2, 2)]);
        let loc = g.threshold(0.0).to_loc(0.0);
        assert!(loc.iter_cells().all(|(_, _, w, _)| w == 1.0));
    }

    #[test]
    fn support_counts_nonzero_cells() {
        let mut g = OccupancyGrid::new(3);
        g.add_path(&[(0, 0), (1, 1), (2, 2)]);
        assert_eq!(g.support(), 3);
    }
}
