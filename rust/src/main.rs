//! spdtw CLI — the leader entrypoint.
//!
//! ```text
//! spdtw experiment <id|all> [opts]   regenerate paper tables/figures
//! spdtw classify <dataset> [opts]    quick 1-NN run with one measure
//! spdtw dist [opts]                  one pairwise distance/kernel under any measure
//! spdtw search <dataset> [opts]      cascade k-NN search vs brute force
//! spdtw index save <dataset> [opts]  build a search index and persist it
//! spdtw index load <file>            reload + validate a persisted index
//! spdtw index inspect <file>         header/checksum summary of an index file
//! spdtw gen-data <dataset> [opts]    write the synthetic dataset as UCR files
//! spdtw monitor <dataset> [opts]     online subsequence k-NN over stdin or a file
//! spdtw serve [opts]                 start the TCP coordinator service
//! spdtw serve --shards a:p,b:p       start a fan-out front over shard servers
//! spdtw shard-serve [opts]           start one shard server of a fleet
//! spdtw info [opts]                  show artifact manifest + platform
//! spdtw bench-backend [opts]         native vs PJRT parity + throughput
//! ```
//!
//! Every command that takes a measure accepts either `--measure <name>`
//! (the paper's names, parameterized by `--band/--nu/--theta/--gamma/
//! --lags`) or `--measure-json '<spec>'` — the serializable
//! `MeasureSpec` object shared with config files and TCP protocol v2
//! (see `config` module docs for the shape).

use std::path::PathBuf;
use std::sync::Arc;

use spdtw::classify::gram::{cross_gram, gram_1nn_error};
use spdtw::classify::nn::{classify_1nn, classify_knn, classify_knn_indexed};
use spdtw::config::cli::{usage, Args, OptSpec};
use spdtw::config::{CoordinatorConfig, ExperimentConfig, SearchConfig, ShardRole};
use spdtw::coordinator::server::Server;
use spdtw::coordinator::Coordinator;
use spdtw::data::registry;
use spdtw::data::synthetic;
use spdtw::data::TimeSeries;
use spdtw::error::{Error, Result};
use spdtw::experiments;
use spdtw::measures::dtw::BandedDtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spec::{
    FixedGrid, GridResolver, GridSpec, InlineGrids, MeasureSpec, TrainGridResolver,
};
use spdtw::measures::{KernelMeasure, Measure};
use spdtw::runtime::PjrtRuntime;
use spdtw::search::{persist, Index, SearchEngine};
use spdtw::shard::{ActiveFaults, FaultPlan, FrontServer, ShardClientConfig, ShardCoordinator};
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::stream::{MatchReport, RwsConfig, StreamMonitor};

fn opt_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "seed", takes_value: true, help: "master RNG seed (default 42)" },
        OptSpec { name: "max-train", takes_value: true, help: "train-split cap for scaled runs" },
        OptSpec { name: "max-test", takes_value: true, help: "test-split cap for scaled runs" },
        OptSpec { name: "full", takes_value: false, help: "use the full Table-I sizes" },
        OptSpec { name: "threads", takes_value: true, help: "worker threads" },
        OptSpec { name: "datasets", takes_value: true, help: "comma-separated dataset names" },
        OptSpec { name: "out", takes_value: true, help: "output directory (default out/)" },
        OptSpec {
            name: "artifacts",
            takes_value: true,
            help: "artifacts dir (default artifacts/)",
        },
        OptSpec {
            name: "measure",
            takes_value: true,
            help: "measure name: Ed|CORR|DACO|DTW|DTW_sc|DTW_it|SP-DTW|Krdtw|SP-Krdtw|Kga",
        },
        OptSpec {
            name: "measure-json",
            takes_value: true,
            help: "measure as a MeasureSpec JSON object (overrides --measure)",
        },
        OptSpec { name: "band", takes_value: true, help: "Sakoe-Chiba band %% for DTW_sc" },
        OptSpec { name: "theta", takes_value: true, help: "SP-DTW threshold override" },
        OptSpec { name: "gamma", takes_value: true, help: "SP-DTW weight exponent (default 1)" },
        OptSpec { name: "nu", takes_value: true, help: "kernel bandwidth nu (default 1)" },
        OptSpec { name: "lags", takes_value: true, help: "DACO auto-correlation lags (default 10)" },
        OptSpec { name: "x", takes_value: true, help: "dist: first series, comma-separated" },
        OptSpec { name: "y", takes_value: true, help: "dist: second series, comma-separated" },
        OptSpec {
            name: "addr",
            takes_value: true,
            help: "serve: bind address (default 127.0.0.1:7878)",
        },
        OptSpec { name: "prefer-pjrt", takes_value: false, help: "route matching jobs to PJRT" },
        OptSpec { name: "config", takes_value: true, help: "JSON config file" },
        OptSpec { name: "k", takes_value: true, help: "search: neighbors per query (default 1)" },
        OptSpec {
            name: "band-cells",
            takes_value: true,
            help: "search: DP band in cells (default 10% of T)",
        },
        OptSpec {
            name: "spdtw-index",
            takes_value: false,
            help: "search: learn a LOC grid and search under SP-DTW",
        },
        OptSpec {
            name: "no-kim",
            takes_value: false,
            help: "search: disable the O(1) LB_Kim stage",
        },
        OptSpec {
            name: "no-keogh",
            takes_value: false,
            help: "search: disable the LB_Keogh stage",
        },
        OptSpec {
            name: "no-rev",
            takes_value: false,
            help: "search: disable the reversed LB_Keogh stage",
        },
        OptSpec {
            name: "no-abandon",
            takes_value: false,
            help: "search: disable DP early abandoning",
        },
        OptSpec {
            name: "no-order",
            takes_value: false,
            help: "search: scan candidates in train order",
        },
        OptSpec {
            name: "znorm",
            takes_value: false,
            help: "search: z-normalize index + queries (banded mode)",
        },
        OptSpec {
            name: "verify",
            takes_value: false,
            help: "search: cross-check against brute-force k-NN",
        },
        OptSpec {
            name: "index-file",
            takes_value: true,
            help: "search/index: persisted .spix index file to load (search) or write (index save)",
        },
        OptSpec {
            name: "index-store",
            takes_value: true,
            help: "serve: directory for persisted indexes (save-on-register + warm start)",
        },
        OptSpec {
            name: "no-warm-start",
            takes_value: false,
            help: "serve: do not reload persisted indexes at boot",
        },
        OptSpec {
            name: "index-store-max-bytes",
            takes_value: true,
            help: "serve: LRU-evict store files past this byte budget",
        },
        OptSpec {
            name: "shards",
            takes_value: true,
            help: "serve: comma-separated shard addresses — run as a fan-out front",
        },
        OptSpec {
            name: "shard-id",
            takes_value: true,
            help: "shard-serve: this server's shard id (0-based)",
        },
        OptSpec {
            name: "shards-total",
            takes_value: true,
            help: "shard-serve: number of shards in the fleet",
        },
        OptSpec {
            name: "fault-plan",
            takes_value: true,
            help: "shard-serve: JSON fault plan for deterministic chaos testing",
        },
        OptSpec {
            name: "input",
            takes_value: true,
            help: "monitor: file of samples to tail (default: stdin)",
        },
        OptSpec {
            name: "rws",
            takes_value: false,
            help: "monitor: opt into the approximate RWS pre-filter (exact is the default)",
        },
        OptSpec {
            name: "rws-d",
            takes_value: true,
            help: "monitor: RWS embedding dimension (default 8)",
        },
        OptSpec {
            name: "rws-len",
            takes_value: true,
            help: "monitor: RWS warp series length (default T/4)",
        },
        OptSpec {
            name: "rws-candidates",
            takes_value: true,
            help: "monitor: RWS candidate budget per window (default 16)",
        },
        OptSpec {
            name: "rws-seed",
            takes_value: true,
            help: "monitor: RWS series seed (default 7)",
        },
        OptSpec {
            name: "audit-every",
            takes_value: true,
            help: "monitor: exact-audit every Nth window for recall@k (0 = off)",
        },
        OptSpec {
            name: "report-every",
            takes_value: true,
            help: "monitor: print a match line every Nth window (0 = summary only)",
        },
        OptSpec {
            name: "max-windows",
            takes_value: true,
            help: "monitor: stop after N evaluated windows",
        },
        OptSpec {
            name: "breaker-threshold",
            takes_value: true,
            help: "serve --shards: consecutive failures before a link's breaker opens (default 3)",
        },
        OptSpec {
            name: "probe-interval-ms",
            takes_value: true,
            help: "serve --shards: health-probe cadence for open breakers (default 500, 0 = off)",
        },
    ]
}

fn build_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    if let Some(v) = args.get_usize("max-train")? {
        cfg.max_train = v;
    }
    if let Some(v) = args.get_usize("max-test")? {
        cfg.max_test = v;
    }
    if args.flag("full") {
        cfg.full = true;
    }
    if let Some(v) = args.get_usize("threads")? {
        cfg.threads = v;
    }
    if let Some(v) = args.get("datasets") {
        cfg.datasets = v.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(v) = args.get("out") {
        cfg.out_dir = PathBuf::from(v);
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(v);
    }
    cfg.validate()?;
    Ok(cfg)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let spec = opt_spec();
    let args = Args::parse(argv, &spec)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "experiment" => cmd_experiment(&args),
        "classify" => cmd_classify(&args),
        "dist" => cmd_dist(&args),
        "search" => cmd_search(&args),
        "index" => cmd_index(&args),
        "gen-data" => cmd_gen_data(&args),
        "monitor" => cmd_monitor(&args),
        "serve" => cmd_serve(&args),
        "shard-serve" => cmd_shard_serve(&args),
        "info" => cmd_info(&args),
        "bench-backend" => cmd_bench_backend(&args),
        "help" | "--help" => {
            println!(
                "spdtw — Sparsified-Paths search space DTW (paper reproduction)\n\n\
                 commands: experiment <id|all> | classify <dataset> | dist |\n\
                 \x20         search <dataset> | index save|load|inspect |\n\
                 \x20         gen-data <dataset> | monitor <dataset> | serve | shard-serve |\n\
                 \x20         info | bench-backend\n\n{}",
                usage(&spec)
            );
            println!("experiments: {}", experiments::EXPERIMENTS.join(", "));
            println!("datasets: {}", registry::names().join(", "));
            Ok(())
        }
        other => Err(Error::Unknown {
            kind: "command",
            name: other.to_string(),
        }),
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| Error::config("usage: spdtw experiment <id|all>"))?;
    let cfg = build_cfg(args)?;
    std::fs::create_dir_all(&cfg.out_dir)?;
    std::fs::write(cfg.out_dir.join("config.json"), cfg.to_json().to_pretty())?;
    experiments::run(id, &cfg)
}

/// Resolve the measure a command asked for into a [`MeasureSpec`]:
/// `--measure-json` takes a raw spec object; `--measure <name>` maps
/// the paper's names plus the per-measure flags (`--band`, `--nu`,
/// `--theta`, `--gamma`, `--lags`) onto the same typed spec.
fn measure_spec_from_args(args: &Args, default: &str) -> Result<MeasureSpec> {
    if let Some(text) = args.get("measure-json") {
        if args.get("measure").is_some() {
            return Err(Error::config(
                "--measure and --measure-json are mutually exclusive",
            ));
        }
        return MeasureSpec::from_json(&spdtw::util::json::Json::parse(text)?);
    }
    let name = args.get("measure").unwrap_or(default);
    let nu = args.get_f64("nu")?.unwrap_or(1.0);
    let theta = args.get_f64("theta")?.unwrap_or(0.0);
    let gamma = args.get_f64("gamma")?.unwrap_or(1.0);
    let spec = match name {
        "Ed" => MeasureSpec::Euclidean,
        "CORR" => MeasureSpec::Corr,
        "DACO" => MeasureSpec::Daco { lags: args.get_usize("lags")?.unwrap_or(10) },
        "DTW" => MeasureSpec::Dtw,
        "DTW_sc" => MeasureSpec::SakoeChiba { band_pct: args.get_f64("band")?.unwrap_or(10.0) },
        "DTW_it" => MeasureSpec::Itakura,
        "SP-DTW" => MeasureSpec::SpDtw { grid: GridSpec::Learned { theta, gamma } },
        "Krdtw" => MeasureSpec::Krdtw { nu, band_cells: None },
        // kernel grids drop weights (mask semantics): gamma = 0
        "SP-Krdtw" => MeasureSpec::SpKrdtw { nu, grid: GridSpec::Learned { theta, gamma: 0.0 } },
        "Kga" => MeasureSpec::Kga { nu, band_cells: None },
        other => {
            return Err(Error::Unknown {
                kind: "measure",
                name: other.to_string(),
            })
        }
    };
    spec.validate()?;
    Ok(spec)
}

fn cmd_classify(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| Error::config("usage: spdtw classify <dataset> --measure <m>"))?;
    let cfg = build_cfg(args)?;
    let (cap_tr, cap_te) = cfg.caps();
    let ds = synthetic::generate_scaled(name, cfg.seed, cap_tr, cap_te)?;
    let spec = measure_spec_from_args(args, "DTW")?;
    let resolver = TrainGridResolver {
        train: Some(&ds.train),
        grid: None,
        threads: cfg.threads,
    };
    let t0 = std::time::Instant::now();
    let (error_rate, comparisons, cells) = if spec.is_kernel() {
        // kernel measures rank by the normalized Gram: self-kernels are
        // computed once per series (the experiments-runner protocol),
        // not re-derived inside every pairwise distance
        let kernel = spec.build_kernel(&resolver)?;
        let cg = cross_gram(&*kernel, &ds.test, &ds.train, cfg.threads);
        let err = gram_1nn_error(&cg, &ds.test, &ds.train);
        (err, (ds.test.len() * ds.train.len()) as u64, cg.visited_cells)
    } else {
        let m = spec.build_measure(&resolver)?;
        let r = classify_1nn(&*m, &ds.train, &ds.test, cfg.threads);
        (r.error_rate, r.comparisons, r.visited_cells)
    };
    println!(
        "{name} [{}] error={:.3} comparisons={} cells={} wall={:.2}s",
        spec.name(),
        error_rate,
        comparisons,
        cells,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Comma-separated f64 list from `--x` / `--y`, rejecting NaN/±inf at
/// the boundary (the CLI counterpart of the wire's `bad_input` class).
fn parse_value_list(args: &Args, name: &'static str) -> Result<Vec<f64>> {
    let raw = args.get(name).ok_or_else(|| {
        Error::config(format!("--{name} is required (comma-separated numbers)"))
    })?;
    let mut values = Vec::new();
    for tok in raw.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let v: f64 = tok
            .parse()
            .map_err(|_| Error::config(format!("--{name}: '{tok}' is not a number")))?;
        if !v.is_finite() {
            return Err(Error::data(format!(
                "--{name}: non-finite value '{tok}' (NaN/inf are not valid series values)"
            )));
        }
        values.push(v);
    }
    if values.is_empty() {
        return Err(Error::config(format!("--{name} must contain at least one number")));
    }
    Ok(values)
}

/// One pairwise evaluation under any measure spec — the CLI twin of the
/// TCP v2 `dist`/`kernel` ops (no dataset context, so `learned` grids
/// are rejected; use an inline `full`/`corridor` grid for SP measures).
fn cmd_dist(args: &Args) -> Result<()> {
    let spec = measure_spec_from_args(args, "DTW")?;
    let x = TimeSeries::new(0, parse_value_list(args, "x")?);
    let y = TimeSeries::new(0, parse_value_list(args, "y")?);
    spec.check_operands(x.len(), y.len())?;
    // resolve any grid exactly once: length-check it, then hand the
    // same materialized LOC to the factory via a fixed resolver
    let resolver: Box<dyn GridResolver> = match spec.grid() {
        Some(g) => {
            let loc = InlineGrids.resolve(g)?;
            if loc.t != x.len() {
                return Err(Error::config(format!(
                    "series length {} != grid T={}",
                    x.len(),
                    loc.t
                )));
            }
            Box::new(FixedGrid(loc))
        }
        None => Box::new(InlineGrids),
    };
    if spec.is_kernel() {
        let kernel = spec.build_kernel(&*resolver)?;
        // normalized-kernel distance from the three log-kernels — same
        // formula as spec::KernelDist, without re-evaluating log_k(x,y)
        let kxy = kernel.log_k(&x, &y);
        let kxx = kernel.log_k(&x, &x);
        let kyy = kernel.log_k(&y, &y);
        let dist = -(kxy.value - 0.5 * (kxx.value + kyy.value));
        let cells = kxy.visited_cells + kxx.visited_cells + kyy.visited_cells;
        println!(
            "{} log_k={} dist={} cells={}",
            spec.name(),
            kxy.value,
            dist,
            cells
        );
    } else {
        let m = spec.build_measure(&*resolver)?;
        let d = m.dist(&x, &y);
        println!("{} dist={} cells={}", spec.name(), d.value, d.visited_cells);
    }
    Ok(())
}

/// Settings precedence: defaults < `search` section of --config JSON
/// < explicit CLI flags.  The 10%-of-T band default applies only
/// when no config section exists: a config that omits `band_cells`
/// means unconstrained DTW (SearchConfig::from_json's contract).
fn resolve_search_config(args: &Args, t: usize) -> Result<SearchConfig> {
    let cfg_section = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            spdtw::util::json::Json::parse(&text)?.get("search").cloned()
        }
        None => None,
    };
    let had_cfg_section = cfg_section.is_some();
    let mut scfg = match &cfg_section {
        Some(section) => SearchConfig::from_json(section)?,
        None => SearchConfig::default(),
    };
    if let Some(k) = args.get_usize("k")? {
        scfg.k = k;
    }
    if let Some(b) = args.get_usize("band-cells")? {
        scfg.band_cells = b;
    } else if !had_cfg_section && scfg.band_cells == usize::MAX {
        scfg.band_cells = ((0.1 * t as f64).round() as usize).max(1);
    }
    if args.flag("no-kim") {
        scfg.kim = false;
    }
    if args.flag("no-keogh") {
        scfg.keogh = false;
    }
    if args.flag("no-rev") {
        scfg.keogh_rev = false;
    }
    if args.flag("no-abandon") {
        scfg.early_abandon = false;
    }
    if args.flag("no-order") {
        scfg.order_by_lb = false;
    }
    if args.flag("znorm") {
        scfg.znormalize = true;
    }
    if let Some(p) = args.get("index-file") {
        scfg.index_file = Some(PathBuf::from(p));
    }
    if let Some(text) = args.get("measure-json") {
        scfg.measure =
            Some(MeasureSpec::from_json(&spdtw::util::json::Json::parse(text)?)?);
    }
    scfg.validate()?;
    if scfg.znormalize && args.flag("spdtw-index") {
        return Err(Error::config(
            "--znorm is only supported for banded-DTW indexes (not --spdtw-index)",
        ));
    }
    Ok(scfg)
}

/// Build the index a `spdtw search` / `spdtw index save` run asked for:
/// the CLI flags resolve to a [`MeasureSpec`] and the shared
/// spec-driven builder does the rest (`--spdtw-index` is shorthand for
/// an spdtw spec over a `learned` grid).
fn build_search_index(
    args: &Args,
    cfg: &ExperimentConfig,
    ds: &spdtw::data::Dataset,
    scfg: &SearchConfig,
) -> Result<Index> {
    let spec = if args.flag("spdtw-index") {
        // both name an index measure: silently preferring one would
        // report results for a config the user didn't get
        if scfg.measure.is_some() {
            return Err(Error::config(
                "--spdtw-index conflicts with an explicit measure \
                 (--measure-json or the config file's search.measure); pick one",
            ));
        }
        let theta = args.get_f64("theta")?.unwrap_or(0.0);
        let gamma = args.get_f64("gamma")?.unwrap_or(1.0);
        MeasureSpec::SpDtw { grid: GridSpec::Learned { theta, gamma } }
    } else {
        scfg.index_spec()
    };
    let resolver = TrainGridResolver {
        train: Some(&ds.train),
        grid: None,
        threads: cfg.threads,
    };
    let index = Index::build_from_spec(&ds.train, &spec, scfg.znormalize, &resolver, cfg.threads)?;
    if let Some(loc) = &index.loc {
        println!(
            "LOC grid: nnz={} ({:.1}% sparsity), envelope radius {}",
            loc.nnz(),
            100.0 * loc.sparsity(),
            loc.max_band_offset()
        );
    }
    Ok(index)
}

fn cmd_search(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| Error::config("usage: spdtw search <dataset> [--k N] [--band-cells N]"))?;
    let cfg = build_cfg(args)?;
    let (cap_tr, cap_te) = cfg.caps();
    let ds = synthetic::generate_scaled(name, cfg.seed, cap_tr, cap_te)?;
    let t = ds.series_len();
    let scfg = resolve_search_config(args, t)?;

    let index = match &scfg.index_file {
        Some(path) => {
            // A prebuilt index fixes the build-time choices; accepting
            // contradictory build flags and silently ignoring them
            // would report results for a config the user didn't get.
            if args.flag("znorm")
                || args.flag("spdtw-index")
                || args.get("band-cells").is_some()
                || args.get("measure-json").is_some()
            {
                return Err(Error::config(
                    "--index-file loads a prebuilt index; --znorm/--spdtw-index/--band-cells/\
                     --measure-json are build-time flags and do not apply (rebuild with \
                     `spdtw index save`)",
                ));
            }
            let t0 = std::time::Instant::now();
            let loaded = persist::load_index(path)?;
            if loaded.t != t {
                return Err(Error::config(format!(
                    "index file {} holds T={} series but {name} has T={t}",
                    path.display(),
                    loaded.t
                )));
            }
            println!(
                "warm-loaded index from {} ({} series, znorm {}) in {:.1} ms",
                path.display(),
                loaded.len(),
                loaded.znormalized,
                t0.elapsed().as_secs_f64() * 1e3
            );
            loaded
        }
        None => build_search_index(args, &cfg, &ds, &scfg)?,
    };
    let index = Arc::new(index);

    let t0 = std::time::Instant::now();
    let (eval, stats) = classify_knn_indexed(&index, scfg.cascade(), &ds.test, scfg.k, cfg.threads);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{name} [search k={} band={}] error={:.3} wall={:.2}s",
        scfg.k,
        if index.loc.is_some() {
            "sp-dtw".to_string()
        } else if index.band == usize::MAX {
            "unbounded".to_string()
        } else {
            index.band.to_string()
        },
        eval.error_rate,
        wall
    );
    println!("{}", stats.report());
    let brute_cells = index.full_eval_cells() * stats.candidates;
    println!(
        "DP cells: {} vs {} brute force ({:.1}% saved)",
        stats.dp_cells,
        brute_cells,
        100.0 * (1.0 - stats.dp_cells as f64 / brute_cells.max(1) as f64)
    );

    if args.flag("verify") {
        let t1 = std::time::Instant::now();
        // The brute-force pass must see the exact series the engine
        // compared: z-normalize both splits when the index did.
        let (vtrain, vtest) = if index.znormalized {
            let mut tr = ds.train.clone();
            let mut te = ds.test.clone();
            tr.znormalize();
            te.znormalize();
            (tr, te)
        } else {
            (ds.train.clone(), ds.test.clone())
        };
        let brute = match &index.loc {
            Some(loc) => {
                let sp = SpDtw::from_arc(Arc::clone(loc));
                classify_knn(&sp, &vtrain, &vtest, scfg.k, cfg.threads)
            }
            None => classify_knn(
                &BandedDtw(index.band),
                &vtrain,
                &vtest,
                scfg.k,
                cfg.threads,
            ),
        };
        let ok = brute.error_rate == eval.error_rate;
        println!(
            "verify: brute error={:.3} in {:.2}s -> {}",
            brute.error_rate,
            t1.elapsed().as_secs_f64(),
            if ok { "MATCH" } else { "MISMATCH" }
        );
        if !ok {
            return Err(Error::config(format!(
                "search results diverge from brute force ({} vs {})",
                eval.error_rate, brute.error_rate
            )));
        }
    }
    Ok(())
}

fn cmd_index(args: &Args) -> Result<()> {
    let usage_err =
        || {
            Error::config(
                "usage: spdtw index save <dataset> [--index-file F] | load <F> | inspect <F>",
            )
        };
    let action = args.positional.get(1).map(String::as_str).ok_or_else(usage_err)?;
    match action {
        "save" => {
            let name = args.positional.get(2).ok_or_else(usage_err)?;
            let cfg = build_cfg(args)?;
            let (cap_tr, cap_te) = cfg.caps();
            let ds = synthetic::generate_scaled(name, cfg.seed, cap_tr, cap_te)?;
            let scfg = resolve_search_config(args, ds.series_len())?;
            let path = scfg
                .index_file
                .clone()
                .unwrap_or_else(|| cfg.out_dir.join(format!("{name}.spix")));
            let t0 = std::time::Instant::now();
            let index = build_search_index(args, &cfg, &ds, &scfg)?;
            let build_ms = t0.elapsed().as_secs_f64() * 1e3;
            persist::save_index(&index, &path)?;
            println!(
                "{name}: built index (T={}, {} series, radius {}) in {:.1} ms",
                index.t,
                index.len(),
                index.radius,
                build_ms
            );
            println!(
                "saved {} ({} bytes on disk, ~{} bytes resident)",
                path.display(),
                std::fs::metadata(&path)?.len(),
                index.memory_bytes()
            );
            Ok(())
        }
        "load" => {
            let path = PathBuf::from(args.positional.get(2).ok_or_else(usage_err)?);
            let t0 = std::time::Instant::now();
            let index = persist::load_index(&path)?;
            println!(
                "loaded {} in {:.1} ms: T={}, {} series, radius {}, band {}, \
                 grid nnz {}, znorm {}, lb_valid {}, ~{} bytes resident",
                path.display(),
                t0.elapsed().as_secs_f64() * 1e3,
                index.t,
                index.len(),
                index.radius,
                if index.band == usize::MAX {
                    "unbounded".to_string()
                } else {
                    index.band.to_string()
                },
                index.loc.as_ref().map(|l| l.nnz()).unwrap_or(0),
                index.znormalized,
                index.lb_valid,
                index.memory_bytes()
            );
            Ok(())
        }
        "inspect" => {
            let path = PathBuf::from(args.positional.get(2).ok_or_else(usage_err)?);
            let info = persist::inspect(&path)?;
            println!(
                "{}: format v{}, {} bytes, checksum {}",
                path.display(),
                info.version,
                info.file_bytes,
                if info.checksum_ok { "OK" } else { "MISMATCH (corrupt)" }
            );
            println!(
                "  T={}, {} series, radius {}, band {}, znorm {}, lb_valid {}, grid nnz {}",
                info.t,
                info.n,
                info.radius,
                if info.band == usize::MAX {
                    "unbounded".to_string()
                } else {
                    info.band.to_string()
                },
                info.znormalized,
                info.lb_valid,
                info.grid_nnz.map(|n| n.to_string()).unwrap_or_else(|| "-".to_string())
            );
            Ok(())
        }
        other => Err(Error::Unknown {
            kind: "index action",
            name: other.to_string(),
        }),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| Error::config("usage: spdtw gen-data <dataset|all> [--out DIR]"))?;
    let cfg = build_cfg(args)?;
    let dir = cfg.out_dir.join("data");
    let names: Vec<&str> = if name == "all" {
        registry::names()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        let (cap_tr, cap_te) = cfg.caps();
        let ds = synthetic::generate_scaled(n, cfg.seed, cap_tr, cap_te)?;
        spdtw::data::ucr::write_dataset(&dir, &ds)?;
        println!(
            "wrote {n}: train={} test={} T={} -> {}",
            ds.train.len(),
            ds.test.len(),
            ds.series_len(),
            dir.display()
        );
    }
    Ok(())
}

/// One printed match line per reported window — the parseable shape
/// `ci/stream_smoke.py` asserts on (`path=exact` vs `path=approx`,
/// `recall=` only on audited windows).
fn format_match_line(windows: usize, rep: &MatchReport) -> String {
    let mut s = format!(
        "window {windows} start={} path={}",
        rep.window_start,
        if rep.approx { "approx" } else { "exact" }
    );
    for n in &rep.neighbors {
        s.push_str(&format!(
            " idx={} label={} dist={:.6}",
            n.train_idx, n.label, n.dist
        ));
    }
    if let Some(r) = rep.recall {
        s.push_str(&format!(" recall={r:.3}"));
    }
    s
}

/// `spdtw monitor <dataset>`: online subsequence k-NN.  The dataset's
/// train split becomes the registered index (same flags as `spdtw
/// search`); samples are then tailed from `--input FILE` or stdin (any
/// mix of comma/whitespace separation, `#` comments) and every
/// completed sliding window is searched — exactly by default,
/// approximately (and flagged) under `--rws`.
fn cmd_monitor(args: &Args) -> Result<()> {
    use std::io::BufRead;
    let name = args.positional.get(1).ok_or_else(|| {
        Error::config("usage: spdtw monitor <dataset> [--input FILE] [--k N] [--rws]")
    })?;
    let cfg = build_cfg(args)?;
    let (cap_tr, cap_te) = cfg.caps();
    let ds = synthetic::generate_scaled(name, cfg.seed, cap_tr, cap_te)?;
    let scfg = resolve_search_config(args, ds.series_len())?;
    let index = build_search_index(args, &cfg, &ds, &scfg)?;
    let engine = SearchEngine::new(Arc::new(index), scfg.cascade());

    let rws_flags_given = ["rws-d", "rws-len", "rws-candidates", "rws-seed", "audit-every"]
        .iter()
        .any(|&f| args.get(f).is_some());
    let rws = if args.flag("rws") {
        let mut rc = RwsConfig::default();
        if let Some(v) = args.get_usize("rws-d")? {
            rc.d = v;
        }
        if let Some(v) = args.get_usize("rws-len")? {
            rc.len = v;
        }
        if let Some(v) = args.get_usize("rws-candidates")? {
            rc.candidates = v;
        }
        if let Some(v) = args.get_usize("rws-seed")? {
            rc.seed = v as u64;
        }
        if let Some(v) = args.get_usize("audit-every")? {
            rc.audit_every = v as u64;
        }
        Some(rc)
    } else if rws_flags_given {
        // silently ignoring tuning flags would run a different path
        // than the one the user configured
        return Err(Error::config(
            "--rws-*/--audit-every tune the approximate pre-filter; add --rws to enable it",
        ));
    } else {
        None
    };
    let mut monitor = StreamMonitor::new(engine, scfg.k, rws)?;
    println!(
        "monitor {name}: T={} k={} path={}",
        monitor.window_len(),
        monitor.k(),
        if monitor.is_approx() { "approx(rws)" } else { "exact" }
    );

    let report_every = args.get_usize("report-every")?.unwrap_or(0);
    let max_windows = args.get_usize("max-windows")?.unwrap_or(usize::MAX);
    let reader: Box<dyn BufRead> = match args.get("input") {
        Some(path) => Box::new(std::io::BufReader::new(std::fs::File::open(path)?)),
        None => Box::new(std::io::BufReader::new(std::io::stdin())),
    };

    let mut windows = 0usize;
    'tail: for line in reader.lines() {
        let line = line?;
        let text = line.split('#').next().unwrap_or("");
        for tok in text.split(|c: char| c == ',' || c.is_whitespace()) {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let v: f64 = tok
                .parse()
                .map_err(|_| Error::config(format!("monitor: '{tok}' is not a number")))?;
            if let Some(rep) = monitor.push(v)? {
                windows += 1;
                if report_every > 0 && windows % report_every == 0 {
                    println!("{}", format_match_line(windows, rep));
                }
                if windows >= max_windows {
                    break 'tail;
                }
            }
        }
    }
    println!("{}", monitor.stats().report());
    Ok(())
}

/// The `serve`/`shard-serve` flags shared by both roles, folded into a
/// [`CoordinatorConfig`].
fn coordinator_config_from_args(args: &Args, cfg: &ExperimentConfig) -> Result<CoordinatorConfig> {
    let mut ccfg = CoordinatorConfig {
        workers: cfg.threads,
        prefer_pjrt: args.flag("prefer-pjrt"),
        warm_start: !args.flag("no-warm-start"),
        ..CoordinatorConfig::default()
    };
    if let Some(dir) = args.get("index-store") {
        ccfg.index_store = Some(PathBuf::from(dir));
    }
    if let Some(v) = args.get("index-store-max-bytes") {
        let bytes: u64 = v
            .parse()
            .map_err(|_| Error::config("--index-store-max-bytes must be an integer"))?;
        ccfg.index_store_max_bytes = Some(bytes);
    }
    Ok(ccfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(list) = args.get("shards") {
        return serve_front(args, list);
    }
    let cfg = build_cfg(args)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878");
    let ccfg = coordinator_config_from_args(args, &cfg)?;
    let runtime = if ccfg.prefer_pjrt {
        match PjrtRuntime::start(&cfg.artifacts_dir) {
            Ok(rt) => {
                println!("pjrt engine up (artifacts: {})", cfg.artifacts_dir.display());
                Some(rt)
            }
            Err(e) => {
                eprintln!("warning: pjrt unavailable ({e}); native backend only");
                None
            }
        }
    } else {
        None
    };
    let coord = Arc::new(Coordinator::start(ccfg, runtime.as_ref().map(|r| r.handle()))?);
    let boot = coord.metrics();
    if let Some(dir) = &coord.config().index_store {
        println!(
            "index store: {} ({} warm-loaded, {} rejected)",
            dir.display(),
            boot.indexes_loaded,
            boot.index_load_failures
        );
    }
    let server = Server::start(Arc::clone(&coord), addr)?;
    println!("spdtw coordinator listening on {}", server.addr);
    println!(
        "protocol: one JSON object per line; v1 ops: ping, info, register_grid, spdtw, \
         spkrdtw, register_index, search, batch_search, metrics, shutdown"
    );
    println!(
        "protocol v2 ({{\"proto\":2, ...}}): generic dist / kernel / register_measure over \
         any MeasureSpec, id echo, typed error codes"
    );
    // Serve until the TCP `shutdown` op fires (or the process is killed).
    while !server.is_stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    Ok(())
}

/// `spdtw serve --shards a:p,b:p,...`: no local engine — a fan-out
/// front that merges exact per-shard answers (see [`spdtw::shard`]).
fn serve_front(args: &Args, list: &str) -> Result<()> {
    if args.get("shard-id").is_some() || args.get("shards-total").is_some() {
        return Err(Error::config(
            "--shard-id/--shards-total configure a shard server (spdtw shard-serve), \
             not a fan-out front",
        ));
    }
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878");
    let addrs: Vec<String> = list
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let mut scfg = ShardClientConfig::for_addrs(addrs);
    if let Some(dir) = args.get("index-store") {
        scfg.store = Some(PathBuf::from(dir));
    }
    if let Some(v) = args.get_usize("breaker-threshold")? {
        if v == 0 {
            return Err(Error::config("--breaker-threshold must be >= 1"));
        }
        scfg.breaker_threshold = v as u32;
    }
    if let Some(v) = args.get_usize("probe-interval-ms")? {
        scfg.probe_interval_ms = v as u64;
    }
    let sc = ShardCoordinator::connect(scfg)?;
    let server = FrontServer::start(Arc::clone(&sc), addr)?;
    println!(
        "spdtw shard front listening on {} ({} shards: {})",
        server.addr,
        sc.shards_total(),
        sc.addrs().join(", ")
    );
    println!(
        "protocol: v1/v2 front ops: ping, info, register_index, search, batch_search, \
         metrics, shutdown — k-NN answers merged exactly across shards \
         (opt-in: allow_partial, deadline_ms)"
    );
    while !server.is_stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    Ok(())
}

/// `spdtw shard-serve --shard-id I --shards-total N`: one shard server
/// of a fleet — the standard coordinator + TCP server with a
/// [`ShardRole`], accepting sharded registrations and `shard_search`.
fn cmd_shard_serve(args: &Args) -> Result<()> {
    let cfg = build_cfg(args)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7879");
    let shard_id = args
        .get_usize("shard-id")?
        .ok_or_else(|| Error::config("shard-serve needs --shard-id <I>"))?;
    let shards_total = args
        .get_usize("shards-total")?
        .ok_or_else(|| Error::config("shard-serve needs --shards-total <N>"))?;
    let mut ccfg = coordinator_config_from_args(args, &cfg)?;
    ccfg.shard = Some(ShardRole {
        shard_id,
        shards_total,
    });
    let coord = Arc::new(Coordinator::start(ccfg, None)?);
    let server = match args.get("fault-plan") {
        Some(path) => {
            let plan = FaultPlan::load(std::path::Path::new(path))?;
            eprintln!(
                "WARNING: FAULT INJECTION ACTIVE — serving through fault plan {path} \
                 ({} rules, seed {}); this server WILL misbehave by design",
                plan.rules.len(),
                plan.seed
            );
            let faults = Arc::new(ActiveFaults::new(plan));
            Server::start_with_faults(Arc::clone(&coord), addr, faults)?
        }
        None => Server::start(Arc::clone(&coord), addr)?,
    };
    println!(
        "spdtw shard {shard_id}/{shards_total} listening on {}",
        server.addr
    );
    println!(
        "protocol: v1/v2 plus shard ops — register_index takes shard/global_ids, \
         shard_search returns exact local top-k in global index space"
    );
    while !server.is_stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = build_cfg(args)?;
    match PjrtRuntime::start(&cfg.artifacts_dir) {
        Ok(rt) => {
            let info = rt.handle().info()?;
            println!("platform: {}", info.platform);
            println!("dtw buckets (T): {:?}", info.dtw_lengths);
            println!("krdtw buckets (T): {:?}", info.krdtw_lengths);
            for (k, t, b) in &info.batch_of {
                println!("  {k} T={t} B={b}");
            }
        }
        Err(e) => println!("pjrt unavailable: {e}"),
    }
    Ok(())
}

fn cmd_bench_backend(args: &Args) -> Result<()> {
    let cfg = build_cfg(args)?;
    let name = args.positional.get(1).map(String::as_str).unwrap_or("SyntheticControl");
    let ds = synthetic::generate_scaled(name, cfg.seed, 32, 32)?;
    let t = ds.series_len();
    let grid = learn_occupancy_grid(&ds.train, cfg.threads);
    let loc = grid.threshold(1.0).to_loc(1.0);
    println!("{name}: T={t} loc nnz={} ({:.1}% sparsity)", loc.nnz(), 100.0 * loc.sparsity());

    let runtime = PjrtRuntime::start(&cfg.artifacts_dir).ok();
    let mut ccfg = CoordinatorConfig::default();
    ccfg.prefer_pjrt = runtime.is_some();
    let coord = Coordinator::start(ccfg, runtime.as_ref().map(|r| r.handle()))?;
    let key = coord.register_grid(loc)?;
    let rows = &ds.train.series[..ds.train.len().min(16)];
    let t0 = std::time::Instant::now();
    let m = coord.spdtw_matrix(key, rows, rows)?;
    let dt = t0.elapsed();
    let snap = coord.metrics();
    println!(
        "matrix {}x{} in {:.1} ms ({:.0} pairs/s)",
        rows.len(),
        rows.len(),
        dt.as_secs_f64() * 1e3,
        m.len() as f64 / dt.as_secs_f64()
    );
    println!("{}", snap.report());
    Ok(())
}
