//! Meta-parameter selection (paper §V-B): every tunable knob (θ, γ, ν,
//! the Sakoe-Chiba band, DACO's lag count) is selected on the TRAIN split
//! only, by leave-one-out 1-NN error through a grid/line search — the
//! protocol behind Fig. 4.

use crate::classify::nn::loo_error_1nn;
use crate::data::LabeledSet;
use crate::measures::daco::Daco;
use crate::measures::krdtw::{Krdtw, KrdtwDist};
use crate::measures::sakoe_chiba::SakoeChibaDtw;
use crate::measures::spdtw::SpDtw;
use crate::measures::spkrdtw::{SpKrdtw, SpKrdtwDist};
use crate::sparse::OccupancyGrid;

/// One grid-search curve: (parameter value, LOO error) — Fig. 4's data.
pub type Curve = Vec<(f64, f64)>;

/// Default grids (paper: θ ∈ [0, 15]; ν and band by convention).
pub fn theta_grid() -> Vec<f64> {
    (0..=15).map(|v| v as f64).collect()
}

pub fn nu_grid() -> Vec<f64> {
    vec![0.001, 0.01, 0.1, 0.5, 1.0, 5.0]
}

pub fn band_pct_grid() -> Vec<f64> {
    vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 10.0, 12.0, 14.0, 17.0, 20.0]
}

pub fn gamma_grid() -> Vec<f64> {
    vec![0.0, 0.25, 0.5, 1.0, 2.0]
}

pub fn lag_grid() -> Vec<usize> {
    vec![2, 5, 10, 20, 40]
}

/// Argmin over a curve (first minimum wins, matching a left-to-right
/// line search).
pub fn argmin(curve: &Curve) -> (f64, f64) {
    let mut best = (curve[0].0, curve[0].1);
    for &(x, e) in curve {
        if e < best.1 {
            best = (x, e);
        }
    }
    best
}

/// Argmin preferring the LAST minimum: used for θ, where ties should
/// resolve toward the sparsest search space ("important speed-up without
/// loss of accuracy" — larger θ at equal LOO error costs nothing and
/// maximizes the Table-VI saving).
pub fn argmin_prefer_last(curve: &Curve) -> (f64, f64) {
    let mut best = (curve[0].0, curve[0].1);
    for &(x, e) in curve {
        if e <= best.1 {
            best = (x, e);
        }
    }
    best
}

/// θ selection for SP-DTW (Fig. 4): LOO 1-NN error on the train split
/// for each threshold.  Returns (best θ, curve).
pub fn tune_theta(
    grid_counts: &OccupancyGrid,
    train: &LabeledSet,
    gamma: f64,
    thetas: &[f64],
    threads: usize,
) -> (f64, Curve) {
    let curve: Curve = thetas
        .iter()
        .map(|&theta| {
            let loc = grid_counts.threshold(theta).to_loc(gamma);
            let sp = SpDtw::new(loc);
            (theta, loo_error_1nn(&sp, train, threads))
        })
        .collect();
    let (best, _) = argmin_prefer_last(&curve);
    (best, curve)
}

/// γ selection for SP-DTW at a fixed θ.
pub fn tune_gamma(
    grid_counts: &OccupancyGrid,
    train: &LabeledSet,
    theta: f64,
    gammas: &[f64],
    threads: usize,
) -> (f64, Curve) {
    let curve: Curve = gammas
        .iter()
        .map(|&g| {
            let loc = grid_counts.threshold(theta).to_loc(g);
            let sp = SpDtw::new(loc);
            (g, loo_error_1nn(&sp, train, threads))
        })
        .collect();
    let (best, _) = argmin(&curve);
    (best, curve)
}

/// Sakoe-Chiba band width (percent of T) by LOO — the "adjusted corridor"
/// the paper compares against (parenthesized values of Table II).
pub fn tune_band_pct(train: &LabeledSet, pcts: &[f64], threads: usize) -> (f64, Curve) {
    let curve: Curve = pcts
        .iter()
        .map(|&p| {
            let sc = SakoeChibaDtw::new(p);
            (p, loo_error_1nn(&sc, train, threads))
        })
        .collect();
    let (best, _) = argmin(&curve);
    (best, curve)
}

/// ν selection for K_rdtw by LOO over the normalized-kernel distance.
pub fn tune_nu(
    train: &LabeledSet,
    nus: &[f64],
    band: Option<usize>,
    threads: usize,
) -> (f64, Curve) {
    let curve: Curve = nus
        .iter()
        .map(|&nu| {
            let k = match band {
                None => Krdtw::new(nu),
                Some(b) => Krdtw::with_band(nu, b),
            };
            let d = KrdtwDist::new(k);
            (nu, loo_error_1nn(&d, train, threads))
        })
        .collect();
    let (best, _) = argmin(&curve);
    (best, curve)
}

/// ν selection for SP-K_rdtw over a fixed LOC mask.
pub fn tune_nu_sparse(
    grid_counts: &OccupancyGrid,
    train: &LabeledSet,
    theta: f64,
    nus: &[f64],
    threads: usize,
) -> (f64, Curve) {
    let loc = grid_counts.threshold(theta).to_loc_mask();
    let loc = std::sync::Arc::new(loc);
    let curve: Curve = nus
        .iter()
        .map(|&nu| {
            let d = SpKrdtwDist::new(SpKrdtw::from_arc(loc.clone(), nu));
            (nu, loo_error_1nn(&d, train, threads))
        })
        .collect();
    let (best, _) = argmin(&curve);
    (best, curve)
}

/// DACO lag-count selection by LOO.
pub fn tune_daco_lags(train: &LabeledSet, lags: &[usize], threads: usize) -> (usize, Curve) {
    let curve: Curve = lags
        .iter()
        .map(|&l| (l as f64, loo_error_1nn(&Daco::new(l), train, threads)))
        .collect();
    let (best, _) = argmin(&curve);
    (best as usize, curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::sparse::learn::learn_occupancy_grid;

    #[test]
    fn argmin_first_minimum() {
        let c = vec![(0.0, 0.5), (1.0, 0.2), (2.0, 0.2), (3.0, 0.4)];
        assert_eq!(argmin(&c), (1.0, 0.2));
    }

    #[test]
    fn argmin_prefer_last_takes_sparsest_tie() {
        let c = vec![(0.0, 0.2), (1.0, 0.2), (2.0, 0.2), (3.0, 0.4)];
        assert_eq!(argmin_prefer_last(&c), (2.0, 0.2));
    }

    #[test]
    fn tune_theta_returns_grid_member_and_full_curve() {
        let ds = synthetic::generate_scaled("CBF", 21, 12, 0).unwrap();
        let grid = learn_occupancy_grid(&ds.train, 4);
        let thetas = [0.0, 2.0, 5.0];
        let (best, curve) = tune_theta(&grid, &ds.train, 1.0, &thetas, 4);
        assert!(thetas.contains(&best));
        assert_eq!(curve.len(), 3);
        assert!(curve.iter().all(|&(_, e)| (0.0..=1.0).contains(&e)));
    }

    #[test]
    fn tune_band_prefers_some_elasticity_on_warped_data() {
        let ds = synthetic::generate_scaled("CBF", 23, 15, 0).unwrap();
        let (best, curve) = tune_band_pct(&ds.train, &[0.0, 10.0], 4);
        assert!(curve.len() == 2);
        // CBF is the canonical warped dataset: some band should not hurt
        let e0 = curve[0].1;
        let e10 = curve[1].1;
        assert!(e10 <= e0 + 1e-9 || best == 0.0);
    }

    #[test]
    fn tune_nu_small_grid_runs() {
        let ds = synthetic::generate_scaled("Gun-Point", 25, 10, 0).unwrap();
        let (best, curve) = tune_nu(&ds.train, &[0.1, 1.0], Some(10), 4);
        assert!([0.1, 1.0].contains(&best));
        assert_eq!(curve.len(), 2);
    }

    #[test]
    fn tune_daco_lags_runs() {
        let ds = synthetic::generate_scaled("SyntheticControl", 27, 12, 0).unwrap();
        let (best, _) = tune_daco_lags(&ds.train, &[2, 5], 4);
        assert!([2usize, 5].contains(&best));
    }
}
