//! LB_Keogh lower bounding — the classic *indexing*-family DTW speed-up
//! (paper §II-B.2 category 2, ref [27]): a cheap O(T) lower bound on the
//! banded DTW that lets a 1-NN search skip most full DP evaluations.
//! Included so the learned sparsification can be compared against the
//! pruning approach on the same workloads.

use std::collections::VecDeque;

use crate::data::{LabeledSet, TimeSeries};
use crate::measures::dtw::dtw_banded;

/// Upper/lower envelope of a series under warping radius `r`.
///
/// O(T) monotonic-deque sliding min/max (Lemire's streaming algorithm):
/// each index enters and leaves each deque at most once, independent of
/// `r` — the seed's per-window rescan was O(T·r), which dominated index
/// builds at realistic radii.  `search::Index` builds all train
/// envelopes through this path.
pub fn envelope(y: &[f64], r: usize) -> (Vec<f64>, Vec<f64>) {
    // lint:allow(hot-alloc): owning wrapper; hot paths use `envelope_into`.
    let mut upper = Vec::new();
    let mut lower = Vec::new();
    envelope_into(
        y,
        r,
        &mut upper,
        &mut lower,
        &mut VecDeque::new(),
        &mut VecDeque::new(),
    );
    (upper, lower)
}

/// [`envelope`] into caller-provided buffers — the search engine reuses
/// the envelope halves and both deques from its
/// [`crate::measures::workspace::DpWorkspace`] so per-query envelope
/// construction allocates nothing once warm.
pub fn envelope_into(
    y: &[f64],
    r: usize,
    upper: &mut Vec<f64>,
    lower: &mut Vec<f64>,
    maxq: &mut VecDeque<usize>,
    minq: &mut VecDeque<usize>,
) {
    let t = y.len();
    upper.clear();
    upper.resize(t, 0.0);
    lower.clear();
    lower.resize(t, 0.0);
    // Deque fronts hold the argmax/argmin of the current window
    // [i - r, min(i + r, t-1)]; backs stay monotone.
    maxq.clear();
    minq.clear();
    let mut next = 0usize; // first index not yet pushed
    for i in 0..t {
        let lo = i.saturating_sub(r);
        let hi = (i + r).min(t - 1);
        while next <= hi {
            while maxq.back().map_or(false, |&b| y[b] <= y[next]) {
                maxq.pop_back();
            }
            maxq.push_back(next);
            while minq.back().map_or(false, |&b| y[b] >= y[next]) {
                minq.pop_back();
            }
            minq.push_back(next);
            next += 1;
        }
        while *maxq.front().expect("window never empty") < lo {
            maxq.pop_front();
        }
        while *minq.front().expect("window never empty") < lo {
            minq.pop_front();
        }
        upper[i] = y[*maxq.front().unwrap()];
        lower[i] = y[*minq.front().unwrap()];
    }
}

/// LB_Keogh(x, y): squared-cost lower bound on banded DTW(x, y, r).
pub fn lb_keogh(x: &[f64], upper: &[f64], lower: &[f64]) -> f64 {
    let mut s = 0.0;
    for ((&xi, &u), &l) in x.iter().zip(upper).zip(lower) {
        if xi > u {
            s += (xi - u) * (xi - u);
        } else if xi < l {
            s += (l - xi) * (l - xi);
        }
    }
    s
}

/// 1-NN with the LB_Keogh cascade: candidates are scanned in ascending
/// lower-bound order; the full banded DP runs only while the bound beats
/// the best-so-far.  Returns (error rate, full DTW evaluations skipped,
/// total candidates).
pub fn classify_1nn_lb(
    train: &LabeledSet,
    test: &LabeledSet,
    band: usize,
) -> (f64, u64, u64) {
    let envs: Vec<(Vec<f64>, Vec<f64>)> = train
        .series
        .iter()
        .map(|s| envelope(&s.values, band))
        .collect();
    let mut wrong = 0usize;
    let mut skipped = 0u64;
    let mut total = 0u64;
    for probe in &test.series {
        // ascending-LB candidate order maximizes pruning
        let mut order: Vec<(f64, usize)> = envs
            .iter()
            .enumerate()
            .map(|(j, (u, l))| (lb_keogh(&probe.values, u, l), j))
            .collect();
        // total_cmp: NaN-safe (a NaN bound sorts last instead of
        // panicking mid-classification).
        order.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut best = (f64::INFINITY, usize::MAX);
        for (lb, j) in order {
            total += 1;
            if lb >= best.0 {
                skipped += 1; // bound proves this candidate cannot win
                continue;
            }
            let d = dtw_banded(&probe.values, &train.series[j].values, band).value;
            if d < best.0 {
                best = (d, train.series[j].label);
            }
        }
        if best.1 != probe.label {
            wrong += 1;
        }
    }
    (wrong as f64 / test.len().max(1) as f64, skipped, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classify::nn::classify_1nn;
    use crate::data::synthetic;
    use crate::measures::sakoe_chiba::SakoeChibaDtw;
    use crate::util::rng::Pcg64;

    #[test]
    fn envelope_bounds_the_series() {
        let mut rng = Pcg64::new(1);
        let y: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        for r in [0usize, 2, 5] {
            let (u, l) = envelope(&y, r);
            for i in 0..y.len() {
                assert!(l[i] <= y[i] && y[i] <= u[i]);
            }
        }
    }

    #[test]
    fn lemire_envelope_matches_naive_rescan() {
        // the O(T) deque must reproduce the per-window rescan exactly
        let naive = |y: &[f64], r: usize| -> (Vec<f64>, Vec<f64>) {
            let t = y.len();
            let mut u = vec![0.0; t];
            let mut l = vec![0.0; t];
            for i in 0..t {
                let lo = i.saturating_sub(r);
                let hi = (i + r).min(t - 1);
                u[i] = y[lo..=hi].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                l[i] = y[lo..=hi].iter().cloned().fold(f64::INFINITY, f64::min);
            }
            (u, l)
        };
        let mut rng = Pcg64::new(19);
        for _ in 0..30 {
            let t = 1 + rng.below(60);
            let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            for r in [0usize, 1, 3, 7, 100] {
                let (u1, l1) = envelope(&y, r);
                let (u2, l2) = naive(&y, r);
                assert_eq!(u1, u2, "upper t={t} r={r}");
                assert_eq!(l1, l2, "lower t={t} r={r}");
            }
        }
    }

    #[test]
    fn lb_is_a_true_lower_bound() {
        // THE correctness property: LB_Keogh <= banded DTW, always.
        let mut rng = Pcg64::new(2);
        for _ in 0..50 {
            let t = 4 + rng.below(40);
            let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            for r in [1usize, 3, 8] {
                let (u, l) = envelope(&y, r);
                let lb = lb_keogh(&x, &u, &l);
                let d = dtw_banded(&x, &y, r).value;
                assert!(lb <= d + 1e-9, "LB {lb} > DTW {d} (r={r})");
            }
        }
    }

    #[test]
    fn zero_radius_envelope_gives_euclidean_bound() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 2.0, 2.0];
        let (u, l) = envelope(&y, 0);
        let lb = lb_keogh(&x, &u, &l);
        assert!((lb - 2.0).abs() < 1e-12); // (1-2)^2 + 0 + (3-2)^2
    }

    #[test]
    fn cascade_matches_plain_1nn_and_prunes() {
        let ds = synthetic::generate_scaled("CBF", 9, 20, 40).unwrap();
        let t = ds.series_len();
        let band = (0.1 * t as f64) as usize;
        let (err_lb, skipped, total) = classify_1nn_lb(&ds.train, &ds.test, band);
        let plain = classify_1nn(
            &SakoeChibaDtw::new(100.0 * band as f64 / t as f64),
            &ds.train,
            &ds.test,
            2,
        );
        assert_eq!(err_lb, plain.error_rate, "cascade must be exact");
        assert!(skipped > 0, "no pruning happened");
        assert!(skipped < total);
    }
}
