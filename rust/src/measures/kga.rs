//! Global Alignment kernel K_ga (Cuturi et al., paper Eq. 5) — included
//! as the additional kernel baseline the paper discusses: it sums the
//! product of local kernels over *all* admissible paths, but unlike
//! K_rdtw its sparsified restrictions are not guaranteed p.d. (§IV).
//! Log-domain DP, same recurrence structure as soft-DTW's partition
//! function.

use crate::data::TimeSeries;
use crate::measures::krdtw::lse3;
use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, DistResult, KernelMeasure, NEG};

/// K_ga with local kernel `kappa(a,b) = exp(-nu (a-b)^2) / (1 + something)`
/// — we use the plain Gaussian local kernel; Cuturi's 1/(2-k) correction
/// is unnecessary for our comparison purposes and keeps the measure
/// aligned with the K_rdtw local kernel.
#[derive(Clone, Debug)]
pub struct Kga {
    pub nu: f64,
    pub band: Option<usize>,
}

impl Kga {
    pub fn new(nu: f64) -> Self {
        assert!(nu > 0.0);
        Kga { nu, band: None }
    }

    pub fn with_band(nu: f64, band: usize) -> Self {
        Kga {
            nu,
            band: Some(band),
        }
    }

    /// Routes through the calling thread's TLS workspace; see
    /// [`Self::log_kernel_with`].
    pub fn log_kernel(&self, x: &[f64], y: &[f64]) -> DistResult {
        workspace::with_tls(|ws| self.log_kernel_with(ws, x, y))
    }

    /// [`Self::log_kernel`] against caller-provided scratch (the two
    /// rolling log-domain rows) — zero allocations once warm,
    /// bit-identical results.
    pub fn log_kernel_with(&self, ws: &mut DpWorkspace, x: &[f64], y: &[f64]) -> DistResult {
        let tx = x.len();
        let ty = y.len();
        assert!(tx > 0 && ty > 0);
        let nu = self.nu;
        let (mut prev, mut cur) = ws.rows(ty, NEG);
        let mut visited = 0u64;
        for i in 0..tx {
            let (lo, hi) = match self.band {
                Some(b) => (i.saturating_sub(b), (i + b).min(ty - 1)),
                None => (0, ty - 1),
            };
            for c in cur.iter_mut() {
                *c = NEG;
            }
            for j in lo..=hi {
                visited += 1;
                let lk = -nu * phi(x[i], y[j]);
                if i == 0 && j == 0 {
                    cur[0] = lk;
                    continue;
                }
                let p11 = if i > 0 && j > 0 { prev[j - 1] } else { NEG };
                let p10 = if i > 0 { prev[j] } else { NEG };
                let p01 = if j > 0 { cur[j - 1] } else { NEG };
                cur[j] = lk + lse3(p11, p10, p01);
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        DistResult::new(prev[ty - 1], visited)
    }
}

impl KernelMeasure for Kga {
    fn name(&self) -> String {
        match self.band {
            None => "Kga".into(),
            Some(b) => format!("Kga_sc({b})"),
        }
    }

    fn log_k(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.log_kernel(&x.values, &y.values)
    }

    fn log_k_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.log_kernel_with(ws, &x.values, &y.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Plain-domain K_ga for tiny T.
    fn kga_plain(x: &[f64], y: &[f64], nu: f64) -> f64 {
        let tx = x.len();
        let ty = y.len();
        let kap = |a: f64, b: f64| (-nu * (a - b) * (a - b)).exp();
        let mut g = vec![vec![0.0f64; ty]; tx];
        for i in 0..tx {
            for j in 0..ty {
                let base = if i == 0 && j == 0 {
                    1.0
                } else {
                    let p11 = if i > 0 && j > 0 { g[i - 1][j - 1] } else { 0.0 };
                    let p10 = if i > 0 { g[i - 1][j] } else { 0.0 };
                    let p01 = if j > 0 { g[i][j - 1] } else { 0.0 };
                    p11 + p10 + p01
                };
                g[i][j] = kap(x[i], y[j]) * base;
            }
        }
        g[tx - 1][ty - 1]
    }

    #[test]
    fn log_matches_plain() {
        let mut rng = Pcg64::new(1);
        for _ in 0..10 {
            let t = 3 + rng.below(8);
            let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let plain = kga_plain(&x, &y, 1.0);
            let log = Kga::new(1.0).log_kernel(&x, &y).value;
            assert!((log - plain.ln()).abs() < 1e-9);
        }
    }

    #[test]
    fn symmetry_and_finiteness() {
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let k = Kga::new(0.5);
        let a = k.log_kernel(&x, &y).value;
        let b = k.log_kernel(&y, &x).value;
        assert!(a.is_finite());
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn kga_sums_over_more_paths_than_best() {
        // log K_ga >= log of the single-best-path product
        let x = [0.0, 1.0, 2.0];
        let y = [0.0, 1.0, 2.0];
        let lk = Kga::new(1.0).log_kernel(&x, &y).value;
        // best path = diagonal, product = exp(0) = 1, log = 0
        assert!(lk >= 0.0);
    }
}
