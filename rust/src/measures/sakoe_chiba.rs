//! DTW with a Sakoe-Chiba corridor (paper refs [25], [26]) — the classic
//! symmetric band constraint the paper's sparsified search space is
//! benchmarked against.  The band is expressed as a *percentage of T*
//! (the convention of the UCR baselines and of the paper's Table II
//! parenthesized values, e.g. `0.242(6)` = 6% band).

use crate::data::TimeSeries;
use crate::measures::dtw::{dtw_banded, dtw_banded_into};
use crate::measures::workspace::DpWorkspace;
use crate::measures::{DistResult, Measure};

/// Sakoe-Chiba DTW with band = `pct`% of the series length.
#[derive(Clone, Debug)]
pub struct SakoeChibaDtw {
    /// Corridor half-width as a percentage of T (0 = diagonal only).
    pub band_pct: f64,
}

impl SakoeChibaDtw {
    pub fn new(band_pct: f64) -> Self {
        assert!((0.0..=100.0).contains(&band_pct));
        SakoeChibaDtw { band_pct }
    }

    /// Absolute band width for series of length `t`.
    pub fn band_for(&self, t: usize) -> usize {
        ((self.band_pct / 100.0) * t as f64).round() as usize
    }
}

impl Measure for SakoeChibaDtw {
    fn name(&self) -> String {
        format!("DTW_sc({}%)", self.band_pct)
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let t = x.len().max(y.len());
        dtw_banded(&x.values, &y.values, self.band_for(t))
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let t = x.len().max(y.len());
        dtw_banded_into(ws, &x.values, &y.values, self.band_for(t))
    }
}

/// Number of cells inside a Sakoe-Chiba band for a T×T grid — the
/// denominator bookkeeping of Table VI.
pub fn band_cells(t: usize, band: usize) -> u64 {
    let mut n = 0u64;
    for i in 0..t {
        let lo = i.saturating_sub(band);
        let hi = (i + band).min(t - 1);
        n += (hi - lo + 1) as u64;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TimeSeries;
    use crate::measures::dtw::Dtw;
    use crate::util::rng::Pcg64;

    fn rand_ts(rng: &mut Pcg64, t: usize) -> TimeSeries {
        TimeSeries::new(0, (0..t).map(|_| rng.normal()).collect())
    }

    #[test]
    fn full_band_equals_dtw() {
        let mut rng = Pcg64::new(1);
        let x = rand_ts(&mut rng, 40);
        let y = rand_ts(&mut rng, 40);
        let sc = SakoeChibaDtw::new(100.0);
        assert!((sc.dist(&x, &y).value - Dtw.dist(&x, &y).value).abs() < 1e-12);
    }

    #[test]
    fn narrower_band_visits_fewer_cells() {
        let mut rng = Pcg64::new(2);
        let x = rand_ts(&mut rng, 64);
        let y = rand_ts(&mut rng, 64);
        let wide = SakoeChibaDtw::new(20.0).dist(&x, &y).visited_cells;
        let narrow = SakoeChibaDtw::new(5.0).dist(&x, &y).visited_cells;
        assert!(narrow < wide);
        assert!(wide < 64 * 64);
    }

    #[test]
    fn visited_matches_band_cells_formula() {
        let mut rng = Pcg64::new(3);
        let t = 50;
        let x = rand_ts(&mut rng, t);
        let y = rand_ts(&mut rng, t);
        let sc = SakoeChibaDtw::new(10.0);
        let d = sc.dist(&x, &y);
        assert_eq!(d.visited_cells, band_cells(t, sc.band_for(t)));
    }

    #[test]
    fn band_cells_extremes() {
        assert_eq!(band_cells(10, 0), 10);
        assert_eq!(band_cells(10, 9), 100);
        // band=1: 10 diag + 2*9 off-diag
        assert_eq!(band_cells(10, 1), 28);
    }

    #[test]
    fn sc_upper_bounds_dtw() {
        // Constraining the search space can only increase the cost.
        let mut rng = Pcg64::new(4);
        for _ in 0..10 {
            let x = rand_ts(&mut rng, 32);
            let y = rand_ts(&mut rng, 32);
            let full = Dtw.dist(&x, &y).value;
            let banded = SakoeChibaDtw::new(5.0).dist(&x, &y).value;
            assert!(banded >= full - 1e-12);
        }
    }
}
