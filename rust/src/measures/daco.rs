//! Difference of Auto-Correlation Operators (paper Eq. 2): compares the
//! *dynamics* of two series through their auto-correlation vectors.

use crate::data::TimeSeries;
use crate::measures::{DistResult, Measure};

/// Auto-correlation vector ρ_1..ρ_k of a series.
pub fn autocorr(x: &[f64], lags: usize) -> Vec<f64> {
    let t = x.len();
    assert!(lags >= 1 && lags < t, "lags must be in [1, T)");
    let mean = x.iter().sum::<f64>() / t as f64;
    let denom: f64 = x.iter().map(|v| (v - mean) * (v - mean)).sum();
    (1..=lags)
        .map(|tau| {
            if denom <= 1e-300 {
                return 0.0;
            }
            let num: f64 = (0..t - tau)
                .map(|i| (x[i] - mean) * (x[i + tau] - mean))
                .sum();
            num / denom
        })
        .collect()
}

/// DACO(x, y) = || ρ(x) - ρ(y) ||² over `lags` auto-correlation lags.
#[derive(Clone, Debug)]
pub struct Daco {
    pub lags: usize,
}

impl Daco {
    pub fn new(lags: usize) -> Self {
        assert!(lags >= 1);
        Daco { lags }
    }
}

impl Default for Daco {
    /// The lag count is a meta-parameter selected by CV in the paper's
    /// protocol; 10 is the grid midpoint used as default.
    fn default() -> Self {
        Daco { lags: 10 }
    }
}

impl Measure for Daco {
    fn name(&self) -> String {
        "DACO".into()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let lags = self.lags.min(x.len() - 1).min(y.len() - 1).max(1);
        let rx = autocorr(&x.values, lags);
        let ry = autocorr(&y.values, lags);
        let d: f64 = rx.iter().zip(&ry).map(|(a, b)| (a - b) * (a - b)).sum();
        // Cost model: one pass per lag over each series.
        DistResult::new(d, (lags * (x.len() + y.len())) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TimeSeries;

    fn ts(v: Vec<f64>) -> TimeSeries {
        TimeSeries::new(0, v)
    }

    #[test]
    fn identical_series_zero() {
        let x = ts(vec![0.1, 0.9, 0.2, 0.8, 0.3, 0.7, 0.4, 0.6]);
        assert!(Daco::new(4).dist(&x, &x).value.abs() < 1e-15);
    }

    #[test]
    fn autocorr_lag1_of_alternating_is_negative() {
        let x: Vec<f64> = (0..64).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let r = autocorr(&x, 2);
        assert!(r[0] < -0.9, "lag-1 of alternating ~ -1, got {}", r[0]);
        assert!(r[1] > 0.9, "lag-2 of alternating ~ +1, got {}", r[1]);
    }

    #[test]
    fn shift_invariance_of_dynamics() {
        // DACO compares dynamics: adding a constant changes nothing.
        let x = ts(vec![0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
        let y = ts(x.values.iter().map(|v| v + 100.0).collect());
        assert!(Daco::new(3).dist(&x, &y).value.abs() < 1e-12);
    }

    #[test]
    fn different_dynamics_nonzero() {
        let fast = ts((0..64).map(|i| ((i as f64) * 1.5).sin()).collect());
        let slow = ts((0..64).map(|i| ((i as f64) * 0.1).sin()).collect());
        assert!(Daco::new(8).dist(&fast, &slow).value > 0.1);
    }

    #[test]
    fn lags_clamped_to_series_length() {
        let x = ts(vec![1.0, 2.0, 3.0]);
        let y = ts(vec![3.0, 2.0, 1.0]);
        // lags=10 > T-1=2 — must not panic
        let d = Daco::new(10).dist(&x, &y);
        assert!(d.value.is_finite());
    }
}
