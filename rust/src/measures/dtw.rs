//! Dynamic Time Warping (paper Eq. 4) with optimal-path backtracking.
//!
//! The banded core (`dtw_banded`) implements both plain DTW (band = T)
//! and the Sakoe-Chiba corridor in O(T·band) time and O(band) memory
//! (two rolling rows).  `dtw_with_path` keeps the full DP matrix to
//! backtrack the optimal alignment path — this is the building block of
//! the occupancy-grid learning phase (Fig. 3-b).

use crate::data::TimeSeries;
use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, DistResult, Measure, BIG};

/// Plain DTW over the full T×T grid.
#[derive(Clone, Debug, Default)]
pub struct Dtw;

impl Measure for Dtw {
    fn name(&self) -> String {
        "DTW".into()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        dtw_banded(&x.values, &y.values, usize::MAX)
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        dtw_banded_into(ws, &x.values, &y.values, usize::MAX)
    }
}

/// Banded DTW as a [`Measure`] with the band in *cells* — the
/// cell-exact counterpart of `sakoe_chiba::SakoeChibaDtw`'s percentage
/// band, and the brute-force baseline the `search` engine is verified
/// against (both must agree on the band to the cell).
#[derive(Clone, Debug)]
pub struct BandedDtw(pub usize);

impl Measure for BandedDtw {
    fn name(&self) -> String {
        format!("DTW_band({})", self.0)
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        dtw_banded(&x.values, &y.values, self.0)
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        dtw_banded_into(ws, &x.values, &y.values, self.0)
    }
}

/// Banded DTW: cells with |i - j| > band are inadmissible.
/// `band = usize::MAX` (or >= T) degenerates to plain DTW.
/// Works for unequal lengths; the band is applied around the rescaled
/// diagonal j ≈ i·Ty/Tx (the standard generalization).
///
/// Hot path (§Perf): two rolling rows with the three DP neighbors
/// carried in registers — one load of `prev[j]` per cell instead of
/// three row reads (see `dtw_banded_ref`, the straightforward version
/// kept for before/after measurement and cross-checking).  Routes
/// through the calling thread's TLS workspace; use
/// [`dtw_banded_into`] to thread an explicit one.
pub fn dtw_banded(x: &[f64], y: &[f64], band: usize) -> DistResult {
    workspace::with_tls(|ws| dtw_banded_into(ws, x, y, band))
}

/// [`dtw_banded`] against caller-provided scratch: zero allocations
/// once `ws` has warmed up, bit-identical to the allocating path for
/// any prior workspace contents.
pub fn dtw_banded_into(ws: &mut DpWorkspace, x: &[f64], y: &[f64], band: usize) -> DistResult {
    let tx = x.len();
    let ty = y.len();
    assert!(tx > 0 && ty > 0, "empty series");
    let slope = ty as f64 / tx as f64;
    let unbounded = band == usize::MAX || band >= tx.max(ty);
    let (mut prev, mut cur) = ws.rows(ty, BIG);
    let mut visited: u64 = 0;

    for (i, &xi) in x.iter().enumerate() {
        let center = (i as f64 * slope) as usize;
        let (lo, hi) = if unbounded {
            (0, ty - 1)
        } else {
            (center.saturating_sub(band), (center + band).min(ty - 1))
        };
        visited += (hi - lo + 1) as u64;
        if i == 0 {
            // row 0: only left-to-right accumulation
            let mut acc = 0.0f64;
            for j in lo..=hi {
                acc += phi(xi, y[j]);
                cur[j] = acc;
                // cells right of (0,0) accumulate the full prefix; but a
                // fresh start beyond j=0 is inadmissible, so prefix sum
                // is exactly D(0,j).
            }
        } else {
            let mut prev_jm1 = if lo > 0 { prev[lo - 1] } else { BIG };
            let mut cur_jm1 = BIG;
            let yrow = &y[lo..=hi];
            let prow = &prev[lo..=hi];
            let crow = &mut cur[lo..=hi];
            for ((&yj, &pj), cj) in yrow.iter().zip(prow).zip(crow.iter_mut()) {
                let mut b = pj;
                if prev_jm1 < b {
                    b = prev_jm1;
                }
                if cur_jm1 < b {
                    b = cur_jm1;
                }
                let v = phi(xi, yj) + b;
                *cj = v;
                cur_jm1 = v;
                prev_jm1 = pj;
            }
        }
        std::mem::swap(&mut prev, &mut cur);
        if !unbounded {
            for c in cur.iter_mut() {
                *c = BIG;
            }
        }
    }
    DistResult::new(prev[ty - 1], visited)
}

/// Reference implementation of [`dtw_banded`] (kept for §Perf and tests).
pub fn dtw_banded_ref(x: &[f64], y: &[f64], band: usize) -> DistResult {
    let tx = x.len();
    let ty = y.len();
    assert!(tx > 0 && ty > 0, "empty series");
    let slope = ty as f64 / tx as f64;
    // lint:allow(hot-alloc): reference implementation, not a serving path.
    let mut prev = vec![BIG; ty];
    let mut cur = vec![BIG; ty];
    let mut visited: u64 = 0;

    for (i, &xi) in x.iter().enumerate() {
        // Admissible column range for this row.
        let center = (i as f64 * slope) as usize;
        let (lo, hi) = if band == usize::MAX || band >= tx.max(ty) {
            (0, ty - 1)
        } else {
            (center.saturating_sub(band), (center + band).min(ty - 1))
        };
        for c in cur[lo..=hi].iter_mut() {
            *c = BIG;
        }
        for j in lo..=hi {
            let local = phi(xi, y[j]);
            visited += 1;
            let best = if i == 0 && j == 0 {
                0.0
            } else {
                let mut b = BIG;
                if i > 0 {
                    b = b.min(prev[j]); // (i-1, j)
                    if j > 0 {
                        b = b.min(prev[j - 1]); // (i-1, j-1)
                    }
                }
                if j > 0 {
                    b = b.min(cur[j - 1]); // (i, j-1)
                }
                b
            };
            cur[j] = local + best;
        }
        // Clear cells outside the band in `prev` for the next row reuse.
        std::mem::swap(&mut prev, &mut cur);
        if band != usize::MAX && band < tx.max(ty) {
            // reset scratch row fully — cheap relative to band loop
            for c in cur.iter_mut() {
                *c = BIG;
            }
        }
    }
    DistResult::new(prev[ty - 1], visited)
}

/// An alignment path as (i, j) pairs from (0,0) to (Tx-1, Ty-1).
pub type Path = Vec<(usize, usize)>;

/// Full DTW with optimal-path backtracking. O(Tx·Ty) memory.
pub fn dtw_with_path(x: &[f64], y: &[f64]) -> (DistResult, Path) {
    let mut path = Path::new();
    let d = workspace::with_tls(|ws| dtw_path_into(ws, x, y, &mut path));
    (d, path)
}

/// [`dtw_with_path`] with the DP matrix taken from `ws` and the path
/// written into `path` — the occupancy-grid learner reuses the O(T²)
/// matrix across all N(N-1)/2 pairwise DPs this way.
pub fn dtw_path_into(
    ws: &mut DpWorkspace,
    x: &[f64],
    y: &[f64],
    path: &mut Path,
) -> DistResult {
    let tx = x.len();
    let ty = y.len();
    assert!(tx > 0 && ty > 0);
    let d = &mut ws.matrix;
    d.clear();
    d.resize(tx * ty, 0.0);
    for i in 0..tx {
        for j in 0..ty {
            let local = phi(x[i], y[j]);
            let best = if i == 0 && j == 0 {
                0.0
            } else {
                let mut b = BIG;
                if i > 0 {
                    b = b.min(d[(i - 1) * ty + j]);
                    if j > 0 {
                        b = b.min(d[(i - 1) * ty + (j - 1)]);
                    }
                }
                if j > 0 {
                    b = b.min(d[i * ty + (j - 1)]);
                }
                b
            };
            d[i * ty + j] = local + best;
        }
    }
    // Backtrack (diagonal preferred on ties — shortest path convention).
    path.clear();
    path.reserve(tx + ty);
    let (mut i, mut j) = (tx - 1, ty - 1);
    path.push((i, j));
    while i > 0 || j > 0 {
        if i == 0 {
            j -= 1;
        } else if j == 0 {
            i -= 1;
        } else {
            let diag = d[(i - 1) * ty + (j - 1)];
            let up = d[(i - 1) * ty + j];
            let left = d[i * ty + (j - 1)];
            if diag <= up && diag <= left {
                i -= 1;
                j -= 1;
            } else if up <= left {
                i -= 1;
            } else {
                j -= 1;
            }
        }
        path.push((i, j));
    }
    path.reverse();
    DistResult::new(d[tx * ty - 1], (tx * ty) as u64)
}

/// Validate the alignment-path invariants of §II-B.2 (boundary,
/// monotonicity, continuity). Used in tests and debug assertions.
pub fn is_valid_path(path: &[(usize, usize)], tx: usize, ty: usize) -> bool {
    if path.is_empty() || path[0] != (0, 0) || *path.last().unwrap() != (tx - 1, ty - 1) {
        return false;
    }
    for w in path.windows(2) {
        let (i0, j0) = w[0];
        let (i1, j1) = w[1];
        let di = i1 as i64 - i0 as i64;
        let dj = j1 as i64 - j0 as i64;
        // monotone, unit steps, at least one axis advances
        if !(0..=1).contains(&di) || !(0..=1).contains(&dj) || di + dj < 1 {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TimeSeries;
    use crate::measures::euclidean::Euclidean;
    use crate::util::rng::Pcg64;

    fn ts(v: &[f64]) -> TimeSeries {
        TimeSeries::new(0, v.to_vec())
    }

    #[test]
    fn identity_zero_and_visited_count() {
        let x = ts(&[1.0, 2.0, 3.0, 2.0]);
        let d = Dtw.dist(&x, &x);
        assert_eq!(d.value, 0.0);
        assert_eq!(d.visited_cells, 16);
    }

    #[test]
    fn fast_dtw_matches_reference() {
        // §Perf invariant: register-carried loop == straightforward loop.
        let mut rng = Pcg64::new(91);
        for _ in 0..30 {
            let tx = 2 + rng.below(40);
            let ty = 2 + rng.below(40);
            let x: Vec<f64> = (0..tx).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..ty).map(|_| rng.normal()).collect();
            for band in [0usize, 1, 3, 10, usize::MAX] {
                let a = dtw_banded(&x, &y, band);
                let b = dtw_banded_ref(&x, &y, band);
                assert_eq!(a.visited_cells, b.visited_cells, "band={band}");
                if b.value < BIG {
                    assert!((a.value - b.value).abs() < 1e-9, "band={band}");
                } else {
                    assert!(a.value >= BIG);
                }
            }
        }
    }

    #[test]
    fn symmetry() {
        let mut rng = Pcg64::new(2);
        for _ in 0..10 {
            let x: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
            let a = dtw_banded(&x, &y, usize::MAX).value;
            let b = dtw_banded(&y, &x, usize::MAX).value;
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn dtw_leq_squared_euclidean() {
        // The diagonal path is admissible -> DTW <= sum of squared diffs.
        let mut rng = Pcg64::new(3);
        for _ in 0..20 {
            let x = ts((0..16).map(|_| rng.normal()).collect::<Vec<_>>().as_slice());
            let y = ts((0..16).map(|_| rng.normal()).collect::<Vec<_>>().as_slice());
            let d = Dtw.dist(&x, &y).value;
            let e = Euclidean.dist(&x, &y).value;
            assert!(d <= e * e + 1e-9);
        }
    }

    #[test]
    fn warp_invariance_shines_over_euclid() {
        // A shifted bump: DTW nearly 0, Euclid large.
        let bump = |c: usize| -> Vec<f64> {
            (0..64)
                .map(|i| (-(0.02 * (i as f64 - c as f64).powi(2))).exp())
                .collect()
        };
        let x = ts(&bump(20));
        let y = ts(&bump(30));
        let d = Dtw.dist(&x, &y).value;
        let e = Euclidean.dist(&x, &y).value;
        assert!(d < 0.05 * e * e, "dtw={d} ed2={}", e * e);
    }

    #[test]
    fn paper_footnote_counterexample_shape() {
        // The paper's footnote uses |.| costs; with φ = (.)² the same
        // series still violate the triangle inequality.
        let xi = ts(&[0.0]);
        let xj = ts(&[1.0, 2.0]);
        let xk = ts(&[2.0, 3.0, 3.0]);
        let ab = Dtw.dist(&xi, &xj).value; // 1 + 4 = 5
        let bc = Dtw.dist(&xj, &xk).value; // 1 + 1 + 1 = 3
        let ac = Dtw.dist(&xi, &xk).value; // 4 + 9 + 9 = 22
        assert!((ab - 5.0).abs() < 1e-12);
        assert!((bc - 3.0).abs() < 1e-12);
        assert!((ac - 22.0).abs() < 1e-12);
        assert!(ab + bc < ac, "DTW is not a metric");
    }

    #[test]
    fn unequal_lengths_supported() {
        let x = ts(&[0.0, 1.0, 2.0]);
        let y = ts(&[0.0, 0.5, 1.0, 1.5, 2.0]);
        let d = Dtw.dist(&x, &y);
        assert!(d.value.is_finite());
        assert_eq!(d.visited_cells, 15);
    }

    #[test]
    fn band_zero_is_diagonal_cost() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [1.5, 2.5, 3.5, 4.5];
        let d = dtw_banded(&x, &y, 0);
        let diag: f64 = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!((d.value - diag).abs() < 1e-12);
        assert_eq!(d.visited_cells, 4);
    }

    #[test]
    fn band_wide_equals_full() {
        let mut rng = Pcg64::new(7);
        let x: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let full = dtw_banded(&x, &y, usize::MAX).value;
        let wide = dtw_banded(&x, &y, 24).value;
        assert!((full - wide).abs() < 1e-12);
    }

    #[test]
    fn band_cost_monotone_nonincreasing_in_width() {
        let mut rng = Pcg64::new(11);
        let x: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        let mut last = f64::INFINITY;
        for band in [0, 1, 2, 4, 8, 16, 32] {
            let v = dtw_banded(&x, &y, band).value;
            assert!(v <= last + 1e-12, "band={band}: {v} > {last}");
            last = v;
        }
    }

    #[test]
    fn path_is_valid_and_costs_match() {
        let mut rng = Pcg64::new(13);
        for _ in 0..10 {
            let x: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
            let (d, path) = dtw_with_path(&x, &y);
            assert!(is_valid_path(&path, 17, 17));
            // path cost recomputed = DP value
            let cost: f64 = path.iter().map(|&(i, j)| phi(x[i], y[j])).sum();
            assert!((cost - d.value).abs() < 1e-9);
            // banded core agrees
            let b = dtw_banded(&x, &y, usize::MAX);
            assert!((b.value - d.value).abs() < 1e-9);
        }
    }

    #[test]
    fn path_length_bounds() {
        // T <= |path| <= 2T - 1 (paper §II-B.2)
        let mut rng = Pcg64::new(17);
        let t = 25;
        for _ in 0..10 {
            let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let (_, path) = dtw_with_path(&x, &y);
            assert!(path.len() >= t && path.len() <= 2 * t - 1);
        }
    }
}
