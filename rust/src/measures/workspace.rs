//! Reusable DP scratch arena — the zero-allocation substrate under
//! every distance kernel's `*_into` / `dist_with` variant.
//!
//! ## Contract
//!
//! A [`DpWorkspace`] owns every buffer a DP kernel needs (rolling f64
//! rows, `(lK1, lK2)` pair rows, flat entry-parallel arrays, the full
//! path-backtracking matrix, the search engine's candidate scratch).
//! Kernels borrow what they need, reset it to the exact initial state
//! the allocating path would have produced, and run the *same*
//! floating-point operation sequence — so a workspace call is
//! bit-identical (`f64::to_bits`) to its allocating counterpart no
//! matter what ran in the workspace before.  That invariant is what
//! makes per-worker workspace reuse in [`crate::pool`] safe: results
//! cannot depend on which worker (with whatever dirty scratch) picked
//! up an item.  Enforced by `tests/prop_workspace.rs`, which
//! deliberately dirties the workspace between interleaved calls of
//! different lengths, bands and grids.
//!
//! ## Steady state
//!
//! Buffers only ever grow (`clear` + `resize` keeps capacity), so after
//! the first call at the largest (T, nnz) in play, a reused workspace
//! performs **zero heap allocations per distance call** — the property
//! `bench_measures` reports as the allocating-vs-workspace throughput
//! split (EXPERIMENTS.md §Perf).

use std::cell::RefCell;
use std::collections::VecDeque;

/// Scratch arena for the DP kernels.  All fields are public scratch:
/// contents are unspecified between calls; any kernel may clobber any
/// field.  Never read a field you did not just reset.
#[derive(Debug, Default)]
pub struct DpWorkspace {
    /// Rolling DP row pair (banded DTW, K_ga, Itakura).
    pub row_a: Vec<f64>,
    pub row_b: Vec<f64>,
    /// Rolling `(lK1, lK2)` row pair (K_rdtw).
    pub pair_row_a: Vec<(f64, f64)>,
    pub pair_row_b: Vec<(f64, f64)>,
    /// Same-index local log-kernel values `ls[i]` (K_rdtw, SP-K_rdtw).
    pub local_ls: Vec<f64>,
    /// Flat entry-parallel DP values over LOC entries (SP-DTW).
    pub entries: Vec<f64>,
    /// Flat entry-parallel `(lK1, lK2)` values (SP-K_rdtw).
    pub pair_entries: Vec<(f64, f64)>,
    /// Full row-major DP matrix (path backtracking).
    pub matrix: Vec<f64>,
    /// Query copy (the engine's z-normalization buffer).
    pub query: Vec<f64>,
    /// Query envelope halves (reversed LB_Keogh).
    pub env_upper: Vec<f64>,
    pub env_lower: Vec<f64>,
    /// Per-candidate lower bounds (LB_Kim stage / visit ordering).
    pub lbs: Vec<f64>,
    /// Candidate visit order / sort-by-index scratch.
    pub order: Vec<usize>,
    /// The engine's ascending `(dist, idx)` top-k candidate heap.
    pub top: Vec<(f64, usize)>,
    /// k-NN per-probe `(dist, label)` scratch.
    pub dists: Vec<(f64, usize)>,
    /// Monotonic deques for Lemire envelope construction.
    pub maxq: VecDeque<usize>,
    pub minq: VecDeque<usize>,
    /// Lane-major rolling DP row blocks (`ty * L`, lane-contiguous per
    /// column) for the lane-batched banded-DTW kernel
    /// ([`crate::search::lanes`]).
    pub lane_row_a: Vec<f64>,
    pub lane_row_b: Vec<f64>,
    /// Candidate-major transposed candidate values (`t * L`): column j
    /// of every lane packed contiguously so a vertical lane update is
    /// one cache line.
    pub lane_vals: Vec<f64>,
    /// Lane-major entry-parallel SP-DTW DP values over LOC entries
    /// (`nnz * L`).
    pub lane_entries: Vec<f64>,
    /// Contiguously staged sliding window (the streaming monitor's
    /// per-step query copy, [`crate::stream`]).
    pub window: Vec<f64>,
}

/// Reset `v` to exactly `n` copies of `fill`, reusing capacity.
/// Produces the same contents as `vec![fill; n]` without allocating
/// once capacity has grown to `n`.
#[inline]
pub fn reset<T: Copy>(v: &mut Vec<T>, n: usize, fill: T) {
    v.clear();
    v.resize(n, fill);
}

impl DpWorkspace {
    pub fn new() -> DpWorkspace {
        DpWorkspace::default()
    }

    /// The two rolling f64 rows, reset to `fill` at length `t`.
    #[inline]
    pub fn rows(&mut self, t: usize, fill: f64) -> (&mut Vec<f64>, &mut Vec<f64>) {
        reset(&mut self.row_a, t, fill);
        reset(&mut self.row_b, t, fill);
        // Kernels index these rows unchecked-by-reasoning up to `t`;
        // the postcondition keeps `reset` honest under refactoring.
        debug_assert!(self.row_a.len() == t && self.row_b.len() == t);
        (&mut self.row_a, &mut self.row_b)
    }

    /// The two rolling pair rows, reset to `fill` at length `t`.
    #[inline]
    pub fn pair_rows(
        &mut self,
        t: usize,
        fill: (f64, f64),
    ) -> (&mut Vec<(f64, f64)>, &mut Vec<(f64, f64)>) {
        reset(&mut self.pair_row_a, t, fill);
        reset(&mut self.pair_row_b, t, fill);
        debug_assert!(self.pair_row_a.len() == t && self.pair_row_b.len() == t);
        (&mut self.pair_row_a, &mut self.pair_row_b)
    }

    /// Drop the O(T²) path-backtracking matrix allocation — the one
    /// buffer only the occupancy-grid learning pass needs.  Long-lived
    /// workers call this (via [`crate::pool::trim_workspaces`]) after a
    /// learn pass so serving processes don't pin T²-sized heap forever;
    /// every other buffer stays warm.
    pub fn trim(&mut self) {
        self.matrix = Vec::new();
    }

    /// Bytes currently resident across all scratch buffers (capacity,
    /// not length) — a capacity-planning signal for long-lived workers.
    pub fn memory_bytes(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let p = std::mem::size_of::<(f64, f64)>();
        let u = std::mem::size_of::<usize>();
        (self.row_a.capacity() + self.row_b.capacity()) * f
            + (self.pair_row_a.capacity() + self.pair_row_b.capacity()) * p
            + self.local_ls.capacity() * f
            + self.entries.capacity() * f
            + self.pair_entries.capacity() * p
            + self.matrix.capacity() * f
            + self.query.capacity() * f
            + (self.env_upper.capacity() + self.env_lower.capacity()) * f
            + self.lbs.capacity() * f
            + self.order.capacity() * u
            + (self.top.capacity() + self.dists.capacity()) * std::mem::size_of::<(f64, usize)>()
            + (self.maxq.capacity() + self.minq.capacity()) * u
            + (self.lane_row_a.capacity()
                + self.lane_row_b.capacity()
                + self.lane_vals.capacity()
                + self.lane_entries.capacity())
                * f
            + self.window.capacity() * f
    }
}

thread_local! {
    static TLS_WS: RefCell<DpWorkspace> = RefCell::new(DpWorkspace::new());
}

/// Run `f` with this thread's long-lived workspace.  The allocating
/// kernel wrappers (`dtw_banded`, `SpDtw::eval`, …) route through this,
/// so even legacy call sites stop allocating per call after warm-up.
/// Re-entrant calls (a kernel invoked while the workspace is already
/// borrowed higher up the stack) fall back to a fresh workspace instead
/// of panicking.
pub fn with_tls<R>(f: impl FnOnce(&mut DpWorkspace) -> R) -> R {
    TLS_WS.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ws) => f(&mut ws),
        Err(_) => f(&mut DpWorkspace::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_reset_length_and_fill() {
        let mut ws = DpWorkspace::new();
        {
            let (a, b) = ws.rows(4, 7.0);
            assert_eq!(a.as_slice(), &[7.0; 4]);
            assert_eq!(b.as_slice(), &[7.0; 4]);
            a[2] = -1.0;
        }
        // shrink after dirtying: old contents must not leak through
        let (a, _b) = ws.rows(2, 0.0);
        assert_eq!(a.as_slice(), &[0.0, 0.0]);
    }

    #[test]
    fn reset_matches_fresh_vec() {
        let mut v = vec![1.0f64, 2.0, 3.0];
        reset(&mut v, 5, 9.5);
        assert_eq!(v, vec![9.5; 5]);
        let cap = v.capacity();
        reset(&mut v, 5, 0.5);
        assert_eq!(v.capacity(), cap, "reset must not reallocate");
    }

    #[test]
    fn with_tls_is_reentrant() {
        let outer = with_tls(|ws| {
            ws.rows(8, 1.0);
            // nested borrow must not panic — it gets a fresh arena
            with_tls(|inner| {
                let (a, _) = inner.rows(3, 2.0);
                a[0]
            })
        });
        assert_eq!(outer, 2.0);
    }

    #[test]
    fn trim_releases_only_the_matrix() {
        let mut ws = DpWorkspace::new();
        ws.matrix.resize(4096, 0.0);
        ws.rows(64, 0.0);
        ws.trim();
        assert_eq!(ws.matrix.capacity(), 0);
        assert!(ws.row_a.capacity() >= 64, "serving buffers must stay warm");
    }

    #[test]
    fn memory_bytes_tracks_growth() {
        let mut ws = DpWorkspace::new();
        let before = ws.memory_bytes();
        ws.rows(128, 0.0);
        assert!(ws.memory_bytes() >= before + 2 * 128 * 8);
    }

    #[test]
    fn memory_bytes_counts_stream_window_scratch() {
        let mut ws = DpWorkspace::new();
        let before = ws.memory_bytes();
        reset(&mut ws.window, 128, 0.0);
        assert!(ws.memory_bytes() >= before + 128 * 8);
    }

    #[test]
    fn memory_bytes_counts_lane_scratch() {
        let mut ws = DpWorkspace::new();
        let before = ws.memory_bytes();
        reset(&mut ws.lane_row_a, 64 * 8, 0.0);
        reset(&mut ws.lane_row_b, 64 * 8, 0.0);
        reset(&mut ws.lane_vals, 64 * 8, 0.0);
        reset(&mut ws.lane_entries, 256 * 8, 0.0);
        assert!(ws.memory_bytes() >= before + (3 * 64 * 8 + 256 * 8) * 8);
    }
}
