//! SP-K_rdtw — the sparsified-paths K_rdtw kernel (paper §IV,
//! Algorithm 2): the K_rdtw recursion evaluated only on the cells of the
//! learned LOC matrix.  Cell weights are deliberately IGNORED (mask
//! semantics only) — restricting the summation of Eq. 6 to any subset
//! P ⊂ A preserves positive definiteness, weighting the terms would not.
//!
//! Log-domain like `krdtw.rs`; cells outside LOC contribute the
//! log-domain zero `NEG`.

use crate::data::TimeSeries;
use crate::measures::krdtw::{lse2, lse3};
use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, DistResult, KernelMeasure, Measure, NEG};
use crate::sparse::LocMatrix;
use std::sync::Arc;

/// SP-K_rdtw over a learned sparse alignment-path matrix.
#[derive(Clone)]
pub struct SpKrdtw {
    pub loc: Arc<LocMatrix>,
    pub nu: f64,
}

impl SpKrdtw {
    pub fn new(loc: LocMatrix, nu: f64) -> Self {
        assert!(nu > 0.0);
        SpKrdtw {
            loc: Arc::new(loc),
            nu,
        }
    }

    pub fn from_arc(loc: Arc<LocMatrix>, nu: f64) -> Self {
        SpKrdtw { loc, nu }
    }

    /// Algorithm 2 restricted to LOC cells; returns log(K1 + K2).
    /// Flat loop over LOC entries via the precomputed predecessor table
    /// (§Perf; `log_kernel_scan` is the row-cursor reference).  Routes
    /// through the calling thread's TLS workspace; see
    /// [`Self::log_kernel_with`].
    pub fn log_kernel(&self, x: &[f64], y: &[f64]) -> DistResult {
        workspace::with_tls(|ws| self.log_kernel_with(ws, x, y))
    }

    /// [`Self::log_kernel`] against caller-provided scratch: the
    /// entry-parallel `(lK1, lK2)` array and the `ls` vector come from
    /// `ws` — zero allocations once warm, bit-identical results.
    pub fn log_kernel_with(&self, ws: &mut DpWorkspace, x: &[f64], y: &[f64]) -> DistResult {
        let loc = &*self.loc;
        let t = loc.t;
        assert_eq!(x.len(), t);
        assert_eq!(y.len(), t);
        let nu = self.nu;
        let log3 = 3.0f64.ln();
        let DpWorkspace {
            local_ls,
            pair_entries,
            ..
        } = ws;
        local_ls.clear();
        local_ls.extend((0..t).map(|i| -nu * phi(x[i], y[i])));
        let ls: &[f64] = local_ls;
        let n = loc.nnz();
        let vals = pair_entries;
        crate::measures::workspace::reset(vals, n, (NEG, NEG));
        for k in 0..n {
            let r = loc.rows[k] as usize;
            let c = loc.cols[k] as usize;
            let lk = -nu * phi(x[r], y[c]);
            if r == 0 && c == 0 {
                vals[k] = (lk, ls[0]);
                continue;
            }
            let p = loc.preds[k];
            let no = crate::sparse::loc::NO_PRED;
            let (p11, q11) = if p[0] != no { vals[p[0] as usize] } else { (NEG, NEG) };
            let (p10, q10) = if p[1] != no { vals[p[1] as usize] } else { (NEG, NEG) };
            let (p01, q01) = if p[2] != no { vals[p[2] as usize] } else { (NEG, NEG) };
            let l1 = lk - log3 + lse3(p11, p10, p01);
            let ls_i = ls[r];
            let ls_j = ls[c];
            let avg = (((ls_i.exp() + ls_j.exp()) * 0.5).max(1e-300)).ln();
            let l2 = -log3 + lse3(avg + q11, ls_i + q10, ls_j + q01);
            vals[k] = (l1, l2);
        }
        let corner = loc
            .index_of(t - 1, t - 1)
            .map(|k| lse2(vals[k].0, vals[k].1))
            .unwrap_or(NEG);
        DistResult::new(corner, n as u64)
    }

    /// Row-cursor reference implementation (kept for §Perf before/after
    /// and cross-checking).
    pub fn log_kernel_scan(&self, x: &[f64], y: &[f64]) -> DistResult {
        let loc = &*self.loc;
        let t = loc.t;
        assert_eq!(x.len(), t);
        assert_eq!(y.len(), t);
        let nu = self.nu;
        let log3 = 3.0f64.ln();
        let ls: Vec<f64> = (0..t).map(|i| -nu * phi(x[i], y[i])).collect();

        // (lK1, lK2) per LOC entry.
        // lint:allow(hot-alloc): reference scan kept as a cross-check oracle.
        let mut vals = vec![(NEG, NEG); loc.nnz()];
        for r in 0..t {
            let (rs, re) = (loc.row_ptr[r], loc.row_ptr[r + 1]);
            let (ps, pe) = if r > 0 {
                (loc.row_ptr[r - 1], loc.row_ptr[r])
            } else {
                (0, 0)
            };
            let mut p_cursor = ps;
            for k in rs..re {
                let c = loc.cols[k] as usize;
                let lk = -nu * phi(x[r], y[c]);
                if r == 0 && c == 0 {
                    vals[0] = (lk, ls[0]);
                    continue;
                }
                while p_cursor < pe && (loc.cols[p_cursor] as usize) < c.saturating_sub(1) {
                    p_cursor += 1;
                }
                let (mut p11, mut p10) = (NEG, NEG);
                let (mut q11, mut q10) = (NEG, NEG);
                if r > 0 {
                    let mut q = p_cursor;
                    while q < pe && (loc.cols[q] as usize) <= c {
                        let pc = loc.cols[q] as usize;
                        if c > 0 && pc == c - 1 {
                            p11 = vals[q].0;
                            q11 = vals[q].1;
                        } else if pc == c {
                            p10 = vals[q].0;
                            q10 = vals[q].1;
                        }
                        q += 1;
                    }
                }
                let (mut p01, mut q01) = (NEG, NEG);
                if c > 0 && k > rs && loc.cols[k - 1] as usize == c - 1 {
                    p01 = vals[k - 1].0;
                    q01 = vals[k - 1].1;
                }
                let l1 = lk - log3 + lse3(p11, p10, p01);
                let ls_i = ls[r];
                let ls_j = ls[c];
                let avg = (((ls_i.exp() + ls_j.exp()) * 0.5).max(1e-300)).ln();
                let l2 = -log3 + lse3(avg + q11, ls_i + q10, ls_j + q01);
                vals[k] = (l1, l2);
            }
        }
        let corner = loc
            .index_of(t - 1, t - 1)
            .map(|k| lse2(vals[k].0, vals[k].1))
            .unwrap_or(NEG);
        DistResult::new(corner, loc.nnz() as u64)
    }
}

impl KernelMeasure for SpKrdtw {
    fn name(&self) -> String {
        "SP-Krdtw".into()
    }

    fn log_k(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.log_kernel(&x.values, &y.values)
    }

    fn log_k_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.log_kernel_with(ws, &x.values, &y.values)
    }
}

/// Distance wrapper for 1-NN (normalized-kernel ranking, cf.
/// `krdtw::KrdtwDist`).
pub struct SpKrdtwDist {
    pub kernel: SpKrdtw,
}

impl SpKrdtwDist {
    pub fn new(kernel: SpKrdtw) -> Self {
        SpKrdtwDist { kernel }
    }
}

impl Measure for SpKrdtwDist {
    fn name(&self) -> String {
        "SP-Krdtw".into()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let kxy = self.kernel.log_kernel(&x.values, &y.values);
        let kxx = self.kernel.log_kernel(&x.values, &x.values);
        let kyy = self.kernel.log_kernel(&y.values, &y.values);
        let norm = kxy.value - 0.5 * (kxx.value + kyy.value);
        DistResult::new(
            -norm,
            kxy.visited_cells + kxx.visited_cells + kyy.visited_cells,
        )
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let kxy = self.kernel.log_kernel_with(ws, &x.values, &y.values);
        let kxx = self.kernel.log_kernel_with(ws, &x.values, &x.values);
        let kyy = self.kernel.log_kernel_with(ws, &y.values, &y.values);
        let norm = kxy.value - 0.5 * (kxx.value + kyy.value);
        DistResult::new(
            -norm,
            kxy.visited_cells + kxx.visited_cells + kyy.visited_cells,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::krdtw::Krdtw;
    use crate::measures::NEG_THRESH;
    use crate::util::rng::Pcg64;

    fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
        (0..t).map(|_| rng.normal()).collect()
    }

    #[test]
    fn fast_log_kernel_matches_scan_reference() {
        let mut rng = Pcg64::new(77);
        for t in [3usize, 10, 22] {
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            let mut triples = vec![(0usize, 0usize, 1.0f64), (t - 1, t - 1, 1.0)];
            for i in 0..t {
                for j in 0..t {
                    if rng.f64() < 0.5 {
                        triples.push((i, j, 1.0));
                    }
                }
            }
            let sp = SpKrdtw::new(LocMatrix::from_triples(t, triples), 0.8);
            let a = sp.log_kernel(&x, &y);
            let b = sp.log_kernel_scan(&x, &y);
            assert_eq!(a.visited_cells, b.visited_cells);
            if a.value > NEG_THRESH {
                assert!((a.value - b.value).abs() < 1e-10, "t={t}");
            } else {
                assert!(b.value <= NEG_THRESH);
            }
        }
    }

    #[test]
    fn full_grid_equals_krdtw() {
        let mut rng = Pcg64::new(1);
        for t in [2usize, 7, 20] {
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            let sp = SpKrdtw::new(LocMatrix::full(t), 0.7);
            let kr = Krdtw::new(0.7);
            let a = sp.log_kernel(&x, &y).value;
            let b = kr.log_kernel(&x, &y).value;
            assert!((a - b).abs() < 1e-9, "t={t}: {a} vs {b}");
        }
    }

    #[test]
    fn corridor_grid_equals_banded_krdtw() {
        let mut rng = Pcg64::new(2);
        let t = 24;
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        for band in [1usize, 3, 6] {
            let sp = SpKrdtw::new(LocMatrix::corridor(t, band), 1.0);
            let kr = Krdtw::with_band(1.0, band);
            let a = sp.log_kernel(&x, &y);
            let b = kr.log_kernel(&x, &y);
            assert!((a.value - b.value).abs() < 1e-9);
            assert_eq!(a.visited_cells, b.visited_cells);
        }
    }

    #[test]
    fn weights_are_ignored() {
        // scaling LOC weights must not change the kernel value
        let mut rng = Pcg64::new(3);
        let t = 12;
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        let base = LocMatrix::corridor(t, 3);
        let reweighted = LocMatrix::from_triples(
            t,
            base.to_triples()
                .into_iter()
                .map(|(r, c, _)| (r, c, 17.5))
                .collect(),
        );
        let a = SpKrdtw::new(base, 0.5).log_kernel(&x, &y).value;
        let b = SpKrdtw::new(reweighted, 0.5).log_kernel(&x, &y).value;
        assert_eq!(a, b);
    }

    #[test]
    fn symmetry_on_symmetric_support() {
        let mut rng = Pcg64::new(4);
        let t = 15;
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        let sp = SpKrdtw::new(LocMatrix::corridor(t, 4), 1.0);
        let a = sp.log_kernel(&x, &y).value;
        let b = sp.log_kernel(&y, &x).value;
        assert!((a - b).abs() < 1e-10);
    }

    #[test]
    fn empty_corner_returns_neg() {
        let loc = LocMatrix::from_triples(3, vec![(0, 0, 1.0), (1, 1, 1.0)]);
        let sp = SpKrdtw::new(loc, 1.0);
        let v = sp.log_kernel(&[0.0; 3], &[0.0; 3]).value;
        assert!(v <= NEG_THRESH);
    }

    #[test]
    fn dist_wrapper_self_zero_and_nonnegative() {
        use crate::data::TimeSeries;
        let mut rng = Pcg64::new(5);
        let x = TimeSeries::new(0, rand_vec(&mut rng, 18));
        let y = TimeSeries::new(0, rand_vec(&mut rng, 18));
        let d = SpKrdtwDist::new(SpKrdtw::new(LocMatrix::corridor(18, 5), 1.0));
        assert!(d.dist(&x, &x).value.abs() < 1e-9);
        assert!(d.dist(&x, &y).value >= -1e-9);
    }

    #[test]
    fn sparse_gram_positive_definite() {
        // the headline §IV property: restriction to any P ⊂ A stays p.d.
        let mut rng = Pcg64::new(6);
        let n = 6;
        let t = 12;
        let series: Vec<Vec<f64>> = (0..n).map(|_| rand_vec(&mut rng, t)).collect();
        // random symmetric sparse support + diagonal
        let mut triples = vec![];
        for i in 0..t {
            for j in i..t {
                if i == j || rng.f64() < 0.4 {
                    triples.push((i, j, 1.0));
                    triples.push((j, i, 1.0));
                }
            }
        }
        let sp = SpKrdtw::new(LocMatrix::from_triples(t, triples), 0.8);
        let mut lk = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in 0..n {
                lk[i][j] = sp.log_kernel(&series[i], &series[j]).value;
            }
        }
        let mut g = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in 0..n {
                g[i][j] = (lk[i][j] - 0.5 * (lk[i][i] + lk[j][j])).exp();
            }
        }
        // Cholesky with jitter
        let mut a = g.clone();
        for i in 0..n {
            a[i][i] += 1e-10;
        }
        for c in 0..n {
            for r in c..n {
                let mut sum = a[r][c];
                for k in 0..c {
                    sum -= a[r][k] * a[c][k];
                }
                if r == c {
                    assert!(sum > 0.0, "not p.d.");
                    a[r][c] = sum.sqrt();
                } else {
                    a[r][c] = sum / a[c][c];
                }
            }
        }
    }
}
