//! `MeasureSpec` — the single typed, serializable entrypoint to the
//! whole measure family.
//!
//! The paper defines a *family* of DTW-like measures (DTW, corridor
//! DTW, Itakura DTW, SP-DTW, K_rdtw, SP-K_rdtw, K_ga, plus the linear
//! baselines).  Every public surface of this crate — the CLI, the
//! coordinator's TCP protocol v2 and the `search` engine — describes
//! the measure it wants with one [`MeasureSpec`] value instead of
//! ad-hoc strings and per-measure plumbing, and the factory here
//! validates parameters **once at the boundary** before any DP runs.
//!
//! A spec is plain data: it round-trips JSON ⇄ typed bit-exactly
//! (f64 parameters serialize via Rust's shortest-roundtrip formatting),
//! so the same value can live in a config file, travel over the wire
//! and be rebuilt into a boxed [`Measure`] / [`KernelMeasure`] on the
//! other side.
//!
//! ## JSON shape
//!
//! ```json
//! {"kind":"euclidean"}
//! {"kind":"minkowski","p":3}
//! {"kind":"corr"}
//! {"kind":"daco","lags":10}
//! {"kind":"dtw"}
//! {"kind":"banded_dtw","band_cells":12}
//! {"kind":"sakoe_chiba","band_pct":10}
//! {"kind":"itakura"}
//! {"kind":"spdtw","grid":{"kind":"corridor","t":60,"band":5}}
//! {"kind":"krdtw","nu":0.5}
//! {"kind":"krdtw","nu":0.5,"band_cells":8}
//! {"kind":"spkrdtw","nu":0.5,"grid":{"kind":"registered","key":0}}
//! {"kind":"kga","nu":0.5}
//! ```
//!
//! Grid references (`"grid"`) come in four kinds:
//!
//! | kind | fields | resolved by |
//! |------|--------|-------------|
//! | `full` | `t` | any resolver (materialized inline) |
//! | `corridor` | `t`, `band` | any resolver (materialized inline) |
//! | `learned` | `theta`, `gamma` | a resolver holding a train set or occupancy grid |
//! | `registered` | `key` | the coordinator's grid registry |
//!
//! The [`GridResolver`] trait decouples the spec from where grids come
//! from: the CLI/experiments resolve `learned` against a train set
//! ([`TrainGridResolver`]), the coordinator resolves `registered`
//! against its registry, and inline `full`/`corridor` grids work
//! everywhere (bounded by [`MAX_INLINE_GRID_CELLS`] so a wire request
//! cannot allocate an arbitrarily large grid).

use std::sync::Arc;

use crate::data::{LabeledSet, TimeSeries};
use crate::error::{Error, Result};
use crate::measures::corr::CorrDist;
use crate::measures::daco::Daco;
use crate::measures::dtw::{BandedDtw, Dtw};
use crate::measures::euclidean::{Euclidean, Minkowski};
use crate::measures::itakura::ItakuraDtw;
use crate::measures::kga::Kga;
use crate::measures::krdtw::Krdtw;
use crate::measures::sakoe_chiba::SakoeChibaDtw;
use crate::measures::spdtw::SpDtw;
use crate::measures::spkrdtw::SpKrdtw;
use crate::measures::workspace::DpWorkspace;
use crate::measures::{DistResult, KernelMeasure, Measure};
use crate::sparse::{LocMatrix, OccupancyGrid};
use crate::util::json::Json;

/// Upper bound on the cell count of an inline (`full` / `corridor`)
/// grid: a wire-supplied spec must not be able to allocate an
/// arbitrarily large LOC matrix.  16M cells ≈ a full 4096×4096 grid,
/// far past every UCR length.
pub const MAX_INLINE_GRID_CELLS: u64 = 1 << 24;

/// A serializable reference to a LOC sparse grid.
#[derive(Clone, Debug, PartialEq)]
pub enum GridSpec {
    /// Full `t`×`t` grid with unit weights (SP measures degenerate to
    /// their dense counterparts).
    Full { t: usize },
    /// Sakoe-Chiba corridor of half-width `band` cells, unit weights.
    Corridor { t: usize, band: usize },
    /// Grid learned from a train set: occupancy grid thresholded at
    /// `theta` (a percentage of the max cell count, 0–100 — the
    /// paper's Fig. 4 axis), weights `f(p) = p^-gamma` (§III;
    /// `gamma = 0` gives the unit-weight mask the kernel variants
    /// require).
    Learned { theta: f64, gamma: f64 },
    /// A grid already registered with the coordinator (its
    /// `register_grid` key).
    Registered { key: u64 },
}

impl GridSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            GridSpec::Full { .. } => "full",
            GridSpec::Corridor { .. } => "corridor",
            GridSpec::Learned { .. } => "learned",
            GridSpec::Registered { .. } => "registered",
        }
    }

    /// Cell count an inline grid would materialize to (None for
    /// `learned` / `registered`, whose size the resolver owns).
    /// Callers must bound `t` first ([`Self::validate`] does): all
    /// arithmetic here is u128 with `t` already ≤
    /// [`MAX_INLINE_GRID_CELLS`], so nothing can overflow or loop.
    fn inline_cells(&self) -> Option<u64> {
        match self {
            GridSpec::Full { t } => {
                let t = *t as u128;
                Some((t * t).min(u64::MAX as u128) as u64)
            }
            GridSpec::Corridor { t, band } => {
                // closed form of sakoe_chiba::band_cells (no O(t) loop
                // on untrusted input): t·(2b+1) minus the two corner
                // truncations of b·(b+1)/2 each, with b clamped to t-1
                let t = *t as u128;
                let b = (*band as u128).min(t.saturating_sub(1));
                Some((t * (2 * b + 1) - b * (b + 1)).min(u64::MAX as u128) as u64)
            }
            _ => None,
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            GridSpec::Full { t } | GridSpec::Corridor { t, .. } => {
                if *t == 0 {
                    return Err(Error::config("grid 't' must be >= 1"));
                }
                // bound t itself before any multiplying arithmetic or
                // O(t) work: even the cheapest grid (the diagonal) has
                // t cells, so an oversized t can never fit the cap
                if *t as u64 > MAX_INLINE_GRID_CELLS {
                    return Err(Error::config(format!(
                        "inline grid 't' too large: {t} (cell cap {MAX_INLINE_GRID_CELLS}); \
                         register the grid instead"
                    )));
                }
                let cells = self.inline_cells().unwrap_or(0);
                if cells > MAX_INLINE_GRID_CELLS {
                    return Err(Error::config(format!(
                        "inline grid too large: {cells} cells (max {MAX_INLINE_GRID_CELLS}); \
                         register the grid instead"
                    )));
                }
                Ok(())
            }
            GridSpec::Learned { theta, gamma } => {
                // theta is a percentage of the occupancy grid's max
                // count (OccupancyGrid::cutoff), like the paper's
                // Fig. 4 x-axis
                if !theta.is_finite() || !(0.0..=100.0).contains(theta) {
                    return Err(Error::config(format!(
                        "grid 'theta' must be in [0, 100], got {theta}"
                    )));
                }
                if !gamma.is_finite() || *gamma < 0.0 {
                    return Err(Error::config(format!(
                        "grid 'gamma' must be finite and >= 0, got {gamma}"
                    )));
                }
                Ok(())
            }
            GridSpec::Registered { .. } => Ok(()),
        }
    }

    pub fn from_json(json: &Json) -> Result<GridSpec> {
        let kind = json.req_str("kind")?;
        let spec = match kind {
            "full" => GridSpec::Full { t: json.req_usize("t")? },
            "corridor" => GridSpec::Corridor {
                t: json.req_usize("t")?,
                band: json.req_usize("band")?,
            },
            "learned" => GridSpec::Learned {
                theta: json.req_f64("theta")?,
                gamma: json.get("gamma").and_then(Json::as_f64).unwrap_or(1.0),
            },
            "registered" => GridSpec::Registered { key: json.req_usize("key")? as u64 },
            other => {
                return Err(Error::config(format!(
                    "unknown grid kind '{other}' (expected full|corridor|learned|registered)"
                )))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> Json {
        match self {
            GridSpec::Full { t } => Json::obj(vec![
                ("kind", Json::str("full")),
                ("t", Json::num(*t as f64)),
            ]),
            GridSpec::Corridor { t, band } => Json::obj(vec![
                ("kind", Json::str("corridor")),
                ("t", Json::num(*t as f64)),
                ("band", Json::num(*band as f64)),
            ]),
            GridSpec::Learned { theta, gamma } => Json::obj(vec![
                ("kind", Json::str("learned")),
                ("theta", Json::num(*theta)),
                ("gamma", Json::num(*gamma)),
            ]),
            GridSpec::Registered { key } => Json::obj(vec![
                ("kind", Json::str("registered")),
                ("key", Json::num(*key as f64)),
            ]),
        }
    }
}

/// Where LOC grids come from when a spec is turned into a runnable
/// measure.  Each surface supplies its own resolver; inline
/// `full`/`corridor` grids are materialized by every implementation.
pub trait GridResolver {
    fn resolve(&self, grid: &GridSpec) -> Result<Arc<LocMatrix>>;
}

/// Materialize an inline (`full` / `corridor`) grid, or `None` when the
/// reference needs external state.  Shared by every resolver.
pub fn materialize_inline(grid: &GridSpec) -> Result<Option<Arc<LocMatrix>>> {
    grid.validate()?;
    Ok(match grid {
        GridSpec::Full { t } => Some(Arc::new(LocMatrix::full(*t))),
        GridSpec::Corridor { t, band } => Some(Arc::new(LocMatrix::corridor(*t, *band))),
        _ => None,
    })
}

/// Resolver for contexts with no train set and no registry: inline
/// grids only.
pub struct InlineGrids;

impl GridResolver for InlineGrids {
    fn resolve(&self, grid: &GridSpec) -> Result<Arc<LocMatrix>> {
        materialize_inline(grid)?.ok_or_else(|| {
            Error::config(format!(
                "grid kind '{}' cannot be resolved here (no train set or grid registry); \
                 use an inline 'full'/'corridor' grid",
                grid.kind()
            ))
        })
    }
}

/// Resolver backed by a train set (and optionally a pre-learned
/// occupancy grid, so callers that already paid for the learning phase
/// — the experiments runner — do not relearn it per spec).
pub struct TrainGridResolver<'a> {
    pub train: Option<&'a LabeledSet>,
    /// Reuse this occupancy grid for `learned` references instead of
    /// learning one from `train`.
    pub grid: Option<&'a OccupancyGrid>,
    pub threads: usize,
}

impl GridResolver for TrainGridResolver<'_> {
    fn resolve(&self, grid: &GridSpec) -> Result<Arc<LocMatrix>> {
        if let Some(loc) = materialize_inline(grid)? {
            return Ok(loc);
        }
        match grid {
            GridSpec::Learned { theta, gamma } => {
                let loc = match (self.grid, self.train) {
                    (Some(g), _) => g.threshold(*theta).to_loc(*gamma),
                    (None, Some(train)) => crate::sparse::learn::learn_occupancy_grid(
                        train,
                        self.threads.max(1),
                    )
                    .threshold(*theta)
                    .to_loc(*gamma),
                    (None, None) => {
                        return Err(Error::config(
                            "learned grid needs a train set to learn from",
                        ))
                    }
                };
                Ok(Arc::new(loc))
            }
            GridSpec::Registered { .. } => Err(Error::config(
                "registered grids only resolve inside the coordinator",
            )),
            _ => unreachable!("inline kinds handled above"),
        }
    }
}

/// Resolver that answers every reference with one pre-resolved grid —
/// used when the grid was already resolved (and length-checked) by the
/// caller, e.g. the coordinator's `register_measure`.
pub struct FixedGrid(pub Arc<LocMatrix>);

impl GridResolver for FixedGrid {
    fn resolve(&self, _grid: &GridSpec) -> Result<Arc<LocMatrix>> {
        Ok(Arc::clone(&self.0))
    }
}

/// Typed description of any measure in the family (kind + parameters).
/// See the module docs for the JSON shape.
#[derive(Clone, Debug, PartialEq)]
pub enum MeasureSpec {
    /// Euclidean distance (paper Eq. 3 with p = 2).
    Euclidean,
    /// Minkowski L_p distance, `p >= 1`.
    Minkowski { p: f64 },
    /// Pearson-correlation distance (paper Eq. 1).
    Corr,
    /// Auto-correlation operator distance over `lags` lags (Eq. 2).
    Daco { lags: usize },
    /// Unconstrained DTW (Eq. 4).
    Dtw,
    /// DTW with a band of `band_cells` cells around the diagonal.
    BandedDtw { band_cells: usize },
    /// Sakoe-Chiba DTW with the band as a percentage of T.
    SakoeChiba { band_pct: f64 },
    /// DTW constrained to the Itakura parallelogram.
    Itakura,
    /// SP-DTW over a LOC sparse grid (Eq. 9, Algorithm 1).
    SpDtw { grid: GridSpec },
    /// K_rdtw kernel (Eq. 6-7), optionally corridor-constrained.
    Krdtw { nu: f64, band_cells: Option<usize> },
    /// SP-K_rdtw kernel over a LOC grid (mask semantics, Algorithm 2).
    SpKrdtw { nu: f64, grid: GridSpec },
    /// Global-alignment kernel K_ga (Eq. 5), optionally banded.
    Kga { nu: f64, band_cells: Option<usize> },
}

impl MeasureSpec {
    /// The JSON `"kind"` discriminator.
    pub fn kind(&self) -> &'static str {
        match self {
            MeasureSpec::Euclidean => "euclidean",
            MeasureSpec::Minkowski { .. } => "minkowski",
            MeasureSpec::Corr => "corr",
            MeasureSpec::Daco { .. } => "daco",
            MeasureSpec::Dtw => "dtw",
            MeasureSpec::BandedDtw { .. } => "banded_dtw",
            MeasureSpec::SakoeChiba { .. } => "sakoe_chiba",
            MeasureSpec::Itakura => "itakura",
            MeasureSpec::SpDtw { .. } => "spdtw",
            MeasureSpec::Krdtw { .. } => "krdtw",
            MeasureSpec::SpKrdtw { .. } => "spkrdtw",
            MeasureSpec::Kga { .. } => "kga",
        }
    }

    /// Human-readable label, matching the names the concrete measures
    /// report (tables, CLI output).
    pub fn name(&self) -> String {
        match self {
            MeasureSpec::Euclidean => "Ed".into(),
            MeasureSpec::Minkowski { p } => format!("L{p}"),
            MeasureSpec::Corr => "CORR".into(),
            MeasureSpec::Daco { .. } => "DACO".into(),
            MeasureSpec::Dtw => "DTW".into(),
            MeasureSpec::BandedDtw { band_cells } => format!("DTW_band({band_cells})"),
            MeasureSpec::SakoeChiba { band_pct } => format!("DTW_sc({band_pct}%)"),
            MeasureSpec::Itakura => "DTW_it".into(),
            MeasureSpec::SpDtw { .. } => "SP-DTW".into(),
            MeasureSpec::Krdtw { band_cells: None, .. } => "Krdtw".into(),
            MeasureSpec::Krdtw { band_cells: Some(b), .. } => format!("Krdtw_sc({b})"),
            MeasureSpec::SpKrdtw { .. } => "SP-Krdtw".into(),
            MeasureSpec::Kga { band_cells: None, .. } => "Kga".into(),
            MeasureSpec::Kga { band_cells: Some(b), .. } => format!("Kga_sc({b})"),
        }
    }

    /// Whether this measure is a kernel (similarity) — buildable via
    /// [`Self::build_kernel`]; distances come from the normalized
    /// wrapper [`KernelDist`] instead.
    pub fn is_kernel(&self) -> bool {
        matches!(
            self,
            MeasureSpec::Krdtw { .. } | MeasureSpec::SpKrdtw { .. } | MeasureSpec::Kga { .. }
        )
    }

    /// The grid reference, for the two sparsified measures.
    pub fn grid(&self) -> Option<&GridSpec> {
        match self {
            MeasureSpec::SpDtw { grid } | MeasureSpec::SpKrdtw { grid, .. } => Some(grid),
            _ => None,
        }
    }

    /// Validate every parameter (the boundary check: factories call
    /// this, so no invalid spec ever reaches a DP kernel's asserts).
    pub fn validate(&self) -> Result<()> {
        match self {
            MeasureSpec::Euclidean
            | MeasureSpec::Corr
            | MeasureSpec::Dtw
            | MeasureSpec::BandedDtw { .. }
            | MeasureSpec::Itakura => Ok(()),
            MeasureSpec::Minkowski { p } => {
                if p.is_nan() || *p < 1.0 {
                    Err(Error::config(format!("minkowski 'p' must be >= 1, got {p}")))
                } else {
                    Ok(())
                }
            }
            MeasureSpec::Daco { lags } => {
                if *lags == 0 {
                    Err(Error::config("daco 'lags' must be >= 1"))
                } else {
                    Ok(())
                }
            }
            MeasureSpec::SakoeChiba { band_pct } => {
                if !band_pct.is_finite() || !(0.0..=100.0).contains(band_pct) {
                    Err(Error::config(format!(
                        "sakoe_chiba 'band_pct' must be in [0, 100], got {band_pct}"
                    )))
                } else {
                    Ok(())
                }
            }
            MeasureSpec::SpDtw { grid } => grid.validate(),
            MeasureSpec::Krdtw { nu, .. } | MeasureSpec::Kga { nu, .. } => check_nu(*nu),
            MeasureSpec::SpKrdtw { nu, grid } => {
                check_nu(*nu)?;
                grid.validate()
            }
        }
    }

    /// Operand-shape check applied at the wire/CLI boundary: the DP
    /// kernels `assert!` on shape violations, the boundary must reject
    /// them as typed errors instead.  Grid-length checks happen where
    /// the grid is resolved.
    pub fn check_operands(&self, xlen: usize, ylen: usize) -> Result<()> {
        if xlen == 0 || ylen == 0 {
            return Err(Error::data("series must be non-empty"));
        }
        match self {
            // banded/plain DTW support unequal lengths
            MeasureSpec::Dtw | MeasureSpec::BandedDtw { .. } => Ok(()),
            _ if xlen != ylen => Err(Error::data(format!(
                "measure '{}' requires equal lengths, got {xlen} vs {ylen}",
                self.name()
            ))),
            _ => Ok(()),
        }
    }

    /// Parse from the JSON shape in the module docs.  Unknown kinds and
    /// invalid parameters are rejected here — the boundary validation.
    pub fn from_json(json: &Json) -> Result<MeasureSpec> {
        let kind = json.req_str("kind")?;
        let band_opt = |j: &Json| j.get("band_cells").and_then(Json::as_usize);
        let spec = match kind {
            "euclidean" => MeasureSpec::Euclidean,
            "minkowski" => MeasureSpec::Minkowski { p: json.req_f64("p")? },
            "corr" => MeasureSpec::Corr,
            "daco" => MeasureSpec::Daco { lags: json.req_usize("lags")? },
            "dtw" => MeasureSpec::Dtw,
            "banded_dtw" => MeasureSpec::BandedDtw { band_cells: json.req_usize("band_cells")? },
            "sakoe_chiba" => MeasureSpec::SakoeChiba { band_pct: json.req_f64("band_pct")? },
            "itakura" => MeasureSpec::Itakura,
            "spdtw" => MeasureSpec::SpDtw {
                grid: GridSpec::from_json(json.get("grid").ok_or_else(|| {
                    Error::config("spdtw spec needs a 'grid' object")
                })?)?,
            },
            "krdtw" => MeasureSpec::Krdtw {
                nu: json.req_f64("nu")?,
                band_cells: band_opt(json),
            },
            "spkrdtw" => MeasureSpec::SpKrdtw {
                nu: json.req_f64("nu")?,
                grid: GridSpec::from_json(json.get("grid").ok_or_else(|| {
                    Error::config("spkrdtw spec needs a 'grid' object")
                })?)?,
            },
            "kga" => MeasureSpec::Kga {
                nu: json.req_f64("nu")?,
                band_cells: band_opt(json),
            },
            other => {
                return Err(Error::config(format!(
                    "unknown measure kind '{other}' (expected euclidean|minkowski|corr|daco|\
                     dtw|banded_dtw|sakoe_chiba|itakura|spdtw|krdtw|spkrdtw|kga)"
                )))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Serialize to the JSON shape in the module docs.  `from_json ∘
    /// to_json` is the identity (bit-exact on every f64 parameter —
    /// numbers print in Rust's shortest-roundtrip form).
    pub fn to_json(&self) -> Json {
        match self {
            MeasureSpec::Euclidean => Json::obj(vec![("kind", Json::str("euclidean"))]),
            MeasureSpec::Minkowski { p } => Json::obj(vec![
                ("kind", Json::str("minkowski")),
                ("p", Json::num(*p)),
            ]),
            MeasureSpec::Corr => Json::obj(vec![("kind", Json::str("corr"))]),
            MeasureSpec::Daco { lags } => Json::obj(vec![
                ("kind", Json::str("daco")),
                ("lags", Json::num(*lags as f64)),
            ]),
            MeasureSpec::Dtw => Json::obj(vec![("kind", Json::str("dtw"))]),
            MeasureSpec::BandedDtw { band_cells } => Json::obj(vec![
                ("kind", Json::str("banded_dtw")),
                ("band_cells", Json::num(*band_cells as f64)),
            ]),
            MeasureSpec::SakoeChiba { band_pct } => Json::obj(vec![
                ("kind", Json::str("sakoe_chiba")),
                ("band_pct", Json::num(*band_pct)),
            ]),
            MeasureSpec::Itakura => Json::obj(vec![("kind", Json::str("itakura"))]),
            MeasureSpec::SpDtw { grid } => Json::obj(vec![
                ("kind", Json::str("spdtw")),
                ("grid", grid.to_json()),
            ]),
            MeasureSpec::Krdtw { nu, band_cells } => {
                let mut fields = vec![("kind", Json::str("krdtw")), ("nu", Json::num(*nu))];
                if let Some(b) = band_cells {
                    fields.push(("band_cells", Json::num(*b as f64)));
                }
                Json::obj(fields)
            }
            MeasureSpec::SpKrdtw { nu, grid } => Json::obj(vec![
                ("kind", Json::str("spkrdtw")),
                ("nu", Json::num(*nu)),
                ("grid", grid.to_json()),
            ]),
            MeasureSpec::Kga { nu, band_cells } => {
                let mut fields = vec![("kind", Json::str("kga")), ("nu", Json::num(*nu))];
                if let Some(b) = band_cells {
                    fields.push(("band_cells", Json::num(*b as f64)));
                }
                Json::obj(fields)
            }
        }
    }

    /// Build a runnable distance measure.  Kernel specs come back as
    /// the normalized-kernel distance ([`KernelDist`], the ranking the
    /// paper's 1-NN protocol uses); everything else is the concrete
    /// measure.  Validates first — invalid parameters never reach a
    /// constructor's `assert!`.
    pub fn build_measure(&self, grids: &dyn GridResolver) -> Result<Arc<dyn Measure>> {
        self.validate()?;
        Ok(match self {
            MeasureSpec::Euclidean => Arc::new(Euclidean),
            MeasureSpec::Minkowski { p } => Arc::new(Minkowski::new(*p)),
            MeasureSpec::Corr => Arc::new(CorrDist),
            MeasureSpec::Daco { lags } => Arc::new(Daco::new(*lags)),
            MeasureSpec::Dtw => Arc::new(Dtw),
            MeasureSpec::BandedDtw { band_cells } => Arc::new(BandedDtw(*band_cells)),
            MeasureSpec::SakoeChiba { band_pct } => Arc::new(SakoeChibaDtw::new(*band_pct)),
            MeasureSpec::Itakura => Arc::new(ItakuraDtw),
            MeasureSpec::SpDtw { grid } => Arc::new(SpDtw::from_arc(grids.resolve(grid)?)),
            MeasureSpec::Krdtw { .. } | MeasureSpec::SpKrdtw { .. } | MeasureSpec::Kga { .. } => {
                Arc::new(KernelDist::new(self.build_kernel(grids)?))
            }
        })
    }

    /// Build a runnable kernel measure.  Distance-only specs are a
    /// typed error (the wire's `kernel` op on a non-kernel measure).
    pub fn build_kernel(&self, grids: &dyn GridResolver) -> Result<Arc<dyn KernelMeasure>> {
        self.validate()?;
        match self {
            MeasureSpec::Krdtw { nu, band_cells } => Ok(match band_cells {
                None => Arc::new(Krdtw::new(*nu)),
                Some(b) => Arc::new(Krdtw::with_band(*nu, *b)),
            }),
            MeasureSpec::SpKrdtw { nu, grid } => {
                Ok(Arc::new(SpKrdtw::from_arc(grids.resolve(grid)?, *nu)))
            }
            MeasureSpec::Kga { nu, band_cells } => Ok(match band_cells {
                None => Arc::new(Kga::new(*nu)),
                Some(b) => Arc::new(Kga::with_band(*nu, *b)),
            }),
            other => Err(Error::config(format!(
                "measure '{}' is a distance, not a kernel",
                other.name()
            ))),
        }
    }
}

fn check_nu(nu: f64) -> Result<()> {
    if !nu.is_finite() || nu <= 0.0 {
        Err(Error::config(format!("'nu' must be finite and > 0, got {nu}")))
    } else {
        Ok(())
    }
}

/// Normalized-kernel distance over any boxed [`KernelMeasure`]:
/// `d(x,y) = -(log K(x,y) - (log K(x,x) + log K(y,y)) / 2)` — the same
/// monotone ranking as the kernel-induced distance, and exactly the
/// formula of the per-kernel wrappers (`krdtw::KrdtwDist`,
/// `spkrdtw::SpKrdtwDist`); this one works for every kernel the
/// factory can build.
pub struct KernelDist {
    pub kernel: Arc<dyn KernelMeasure>,
}

impl KernelDist {
    pub fn new(kernel: Arc<dyn KernelMeasure>) -> Self {
        KernelDist { kernel }
    }
}

impl Measure for KernelDist {
    fn name(&self) -> String {
        self.kernel.name()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let kxy = self.kernel.log_k(x, y);
        let kxx = self.kernel.log_k(x, x);
        let kyy = self.kernel.log_k(y, y);
        let norm = kxy.value - 0.5 * (kxx.value + kyy.value);
        DistResult::new(-norm, kxy.visited_cells + kxx.visited_cells + kyy.visited_cells)
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let kxy = self.kernel.log_k_with(ws, x, y);
        let kxx = self.kernel.log_k_with(ws, x, x);
        let kyy = self.kernel.log_k_with(ws, y, y);
        let norm = kxy.value - 0.5 * (kxx.value + kyy.value);
        DistResult::new(-norm, kxy.visited_cells + kxx.visited_cells + kyy.visited_cells)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::krdtw::KrdtwDist;
    use crate::util::rng::Pcg64;

    fn every_spec() -> Vec<MeasureSpec> {
        vec![
            MeasureSpec::Euclidean,
            MeasureSpec::Minkowski { p: 3.5 },
            MeasureSpec::Corr,
            MeasureSpec::Daco { lags: 7 },
            MeasureSpec::Dtw,
            MeasureSpec::BandedDtw { band_cells: 12 },
            MeasureSpec::SakoeChiba { band_pct: 0.1 + 0.2 }, // non-representable decimal
            MeasureSpec::Itakura,
            MeasureSpec::SpDtw { grid: GridSpec::Corridor { t: 16, band: 3 } },
            MeasureSpec::SpDtw { grid: GridSpec::Full { t: 8 } },
            MeasureSpec::SpDtw { grid: GridSpec::Registered { key: 5 } },
            MeasureSpec::Krdtw { nu: 1e-300, band_cells: None },
            MeasureSpec::Krdtw { nu: 0.5, band_cells: Some(4) },
            MeasureSpec::SpKrdtw {
                nu: 2.0 / 3.0,
                grid: GridSpec::Learned { theta: 0.25, gamma: 0.0 },
            },
            MeasureSpec::Kga { nu: 0.7, band_cells: Some(9) },
        ]
    }

    #[test]
    fn json_roundtrip_is_bit_exact_for_every_kind() {
        for spec in every_spec() {
            let text = spec.to_json().to_string();
            let back = MeasureSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            // PartialEq on f64 fields is bit-exact for these values
            // (none are NaN/-0.0); double-check the payload bits for
            // the fractional parameters explicitly.
            assert_eq!(back, spec, "{text}");
            if let (MeasureSpec::SakoeChiba { band_pct: a }, MeasureSpec::SakoeChiba { band_pct: b }) =
                (&spec, &back)
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            if let (MeasureSpec::Krdtw { nu: a, .. }, MeasureSpec::Krdtw { nu: b, .. }) =
                (&spec, &back)
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn invalid_parameters_rejected_at_the_boundary() {
        let bad = [
            r#"{"kind":"nope"}"#,
            r#"{"kind":"minkowski","p":0.5}"#,
            r#"{"kind":"daco","lags":0}"#,
            r#"{"kind":"sakoe_chiba","band_pct":150}"#,
            r#"{"kind":"sakoe_chiba","band_pct":-1}"#,
            r#"{"kind":"krdtw","nu":0}"#,
            r#"{"kind":"krdtw","nu":-1}"#,
            r#"{"kind":"krdtw"}"#,
            r#"{"kind":"kga","nu":1e999}"#, // parses to +inf
            r#"{"kind":"spdtw"}"#,
            r#"{"kind":"spdtw","grid":{"kind":"what"}}"#,
            r#"{"kind":"spdtw","grid":{"kind":"corridor","t":0,"band":1}}"#,
            r#"{"kind":"spdtw","grid":{"kind":"full","t":100000}}"#, // cell cap
            r#"{"kind":"spkrdtw","nu":1,"grid":{"kind":"learned","theta":200,"gamma":1}}"#,
            r#"{"kind":"spkrdtw","nu":1,"grid":{"kind":"learned","theta":0.5,"gamma":-1}}"#,
        ];
        for text in bad {
            let json = Json::parse(text).unwrap();
            assert!(MeasureSpec::from_json(&json).is_err(), "{text}");
        }
        // and via the factory (typed construction can also be invalid)
        assert!(MeasureSpec::Minkowski { p: f64::NAN }
            .build_measure(&InlineGrids)
            .is_err());
        assert!(MeasureSpec::Krdtw { nu: -1.0, band_cells: None }
            .build_kernel(&InlineGrids)
            .is_err());
    }

    #[test]
    fn inline_grid_cap_rejects_huge_t_without_overflow_or_spin() {
        // t values that would overflow t*t or spin an O(t) loop must be
        // rejected by the t-bound alone (cheap, before any arithmetic)
        for t in [
            MAX_INLINE_GRID_CELLS as usize + 1,
            u32::MAX as usize,
            usize::MAX,
        ] {
            assert!(GridSpec::Full { t }.validate().is_err(), "t={t}");
            assert!(GridSpec::Corridor { t, band: 1 }.validate().is_err(), "t={t}");
            // and through the JSON boundary (as_usize saturates huge nums)
            let j = Json::parse(&format!(r#"{{"kind":"full","t":{}}}"#, 1e300)).unwrap();
            assert!(GridSpec::from_json(&j).is_err());
        }
        // the closed-form corridor count matches the loop-based oracle
        for (t, band) in [(1usize, 0usize), (10, 0), (10, 1), (10, 9), (16, 3), (50, 5)] {
            let spec = GridSpec::Corridor { t, band };
            assert_eq!(
                spec.inline_cells().unwrap(),
                crate::measures::sakoe_chiba::band_cells(t, band.min(t)),
                "t={t} band={band}"
            );
        }
        // boundary: the largest diagonal-only corridor fits exactly
        let max_t = MAX_INLINE_GRID_CELLS as usize;
        assert!(GridSpec::Corridor { t: max_t, band: 0 }.validate().is_ok());
        assert!(GridSpec::Corridor { t: max_t, band: 1 }.validate().is_err());
    }

    #[test]
    fn factory_builds_every_measure_and_matches_direct_constructors() {
        use crate::data::TimeSeries;
        let mut rng = Pcg64::new(11);
        let t = 12;
        let x = TimeSeries::new(0, (0..t).map(|_| rng.normal()).collect());
        let y = TimeSeries::new(1, (0..t).map(|_| rng.normal()).collect());
        let r = InlineGrids;

        let pairs: Vec<(MeasureSpec, Box<dyn Measure>)> = vec![
            (MeasureSpec::Euclidean, Box::new(Euclidean)),
            (MeasureSpec::Minkowski { p: 3.0 }, Box::new(Minkowski::new(3.0))),
            (MeasureSpec::Corr, Box::new(CorrDist)),
            (MeasureSpec::Daco { lags: 4 }, Box::new(Daco::new(4))),
            (MeasureSpec::Dtw, Box::new(Dtw)),
            (MeasureSpec::BandedDtw { band_cells: 3 }, Box::new(BandedDtw(3))),
            (
                MeasureSpec::SakoeChiba { band_pct: 20.0 },
                Box::new(SakoeChibaDtw::new(20.0)),
            ),
            (MeasureSpec::Itakura, Box::new(ItakuraDtw)),
            (
                MeasureSpec::SpDtw { grid: GridSpec::Corridor { t, band: 2 } },
                Box::new(SpDtw::from_arc(Arc::new(LocMatrix::corridor(t, 2)))),
            ),
        ];
        for (spec, direct) in pairs {
            let built = spec.build_measure(&r).unwrap();
            let a = built.dist(&x, &y);
            let b = direct.dist(&x, &y);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", spec.name());
            assert_eq!(a.visited_cells, b.visited_cells, "{}", spec.name());
        }

        // kernels: build_kernel matches direct log_k; build_measure is
        // the normalized distance, bit-identical to the KrdtwDist
        // wrapper for the krdtw kind.
        let kspec = MeasureSpec::Krdtw { nu: 0.8, band_cells: Some(4) };
        let k = kspec.build_kernel(&r).unwrap();
        let direct = Krdtw::with_band(0.8, 4);
        assert_eq!(
            k.log_k(&x, &y).value.to_bits(),
            direct.log_k(&x, &y).value.to_bits()
        );
        let dist = kspec.build_measure(&r).unwrap();
        let wrapper = KrdtwDist::new(Krdtw::with_band(0.8, 4));
        assert_eq!(
            dist.dist(&x, &y).value.to_bits(),
            wrapper.dist(&x, &y).value.to_bits()
        );

        let sp = MeasureSpec::SpKrdtw {
            nu: 0.8,
            grid: GridSpec::Corridor { t, band: 2 },
        };
        let spk = sp.build_kernel(&r).unwrap();
        let direct = SpKrdtw::from_arc(Arc::new(LocMatrix::corridor(t, 2)), 0.8);
        assert_eq!(
            spk.log_k(&x, &y).value.to_bits(),
            direct.log_k(&x, &y).value.to_bits()
        );

        let kga = MeasureSpec::Kga { nu: 0.5, band_cells: None };
        assert_eq!(
            kga.build_kernel(&r).unwrap().log_k(&x, &y).value.to_bits(),
            Kga::new(0.5).log_k(&x, &y).value.to_bits()
        );
    }

    #[test]
    fn kernel_dist_mismatch_is_typed_error() {
        assert!(MeasureSpec::Dtw.build_kernel(&InlineGrids).is_err());
        assert!(MeasureSpec::Euclidean.build_kernel(&InlineGrids).is_err());
        // kernels DO build as measures (normalized distance)
        assert!(MeasureSpec::Kga { nu: 1.0, band_cells: None }
            .build_measure(&InlineGrids)
            .is_ok());
    }

    #[test]
    fn resolvers_gate_grid_kinds() {
        let learned = GridSpec::Learned { theta: 0.5, gamma: 1.0 };
        let registered = GridSpec::Registered { key: 0 };
        assert!(InlineGrids.resolve(&learned).is_err());
        assert!(InlineGrids.resolve(&registered).is_err());
        assert_eq!(
            InlineGrids
                .resolve(&GridSpec::Corridor { t: 8, band: 1 })
                .unwrap()
                .nnz(),
            LocMatrix::corridor(8, 1).nnz()
        );

        use crate::data::splits::from_pairs;
        let train = from_pairs(vec![
            (0, vec![0.0, 1.0, 2.0, 3.0]),
            (1, vec![3.0, 2.0, 1.0, 0.0]),
        ]);
        let r = TrainGridResolver { train: Some(&train), grid: None, threads: 1 };
        let loc = r.resolve(&learned).unwrap();
        assert_eq!(loc.t, 4);
        assert!(loc.has_diagonal());
        assert!(r.resolve(&registered).is_err());

        // a prebuilt occupancy grid is reused (and gamma=0 gives the
        // unit-weight mask — identical support)
        let grid = crate::sparse::learn::learn_occupancy_grid(&train, 1);
        let r2 = TrainGridResolver { train: None, grid: Some(&grid), threads: 1 };
        let mask = r2
            .resolve(&GridSpec::Learned { theta: 0.5, gamma: 0.0 })
            .unwrap();
        assert_eq!(mask.nnz(), grid.threshold(0.5).to_loc_mask().nnz());
        assert!(mask.min_weight() >= 1.0);

        // no train set and no grid: typed error
        let r3 = TrainGridResolver { train: None, grid: None, threads: 1 };
        assert!(r3.resolve(&learned).is_err());
    }

    #[test]
    fn operand_checks_reject_shape_violations() {
        assert!(MeasureSpec::Dtw.check_operands(5, 7).is_ok());
        assert!(MeasureSpec::BandedDtw { band_cells: 2 }.check_operands(5, 7).is_ok());
        assert!(MeasureSpec::Euclidean.check_operands(5, 7).is_err());
        assert!(MeasureSpec::Krdtw { nu: 1.0, band_cells: None }
            .check_operands(5, 7)
            .is_err());
        assert!(MeasureSpec::Dtw.check_operands(0, 3).is_err());
        assert!(MeasureSpec::Itakura.check_operands(6, 6).is_ok());
    }

    #[test]
    fn kernel_dist_matches_per_kernel_wrapper_bitwise() {
        use crate::data::TimeSeries;
        let mut rng = Pcg64::new(3);
        let x = TimeSeries::new(0, (0..20).map(|_| rng.normal()).collect());
        let y = TimeSeries::new(0, (0..20).map(|_| rng.normal()).collect());
        let generic = KernelDist::new(Arc::new(Krdtw::new(1.3)));
        let specific = KrdtwDist::new(Krdtw::new(1.3));
        let a = generic.dist(&x, &y);
        let b = specific.dist(&x, &y);
        assert_eq!(a.value.to_bits(), b.value.to_bits());
        assert_eq!(a.visited_cells, b.visited_cells);
    }
}
