//! All (dis)similarity measures evaluated by the paper.
//!
//! | module | measure | paper ref |
//! |--------|---------|-----------|
//! | [`euclidean`] | Ed / L_p norms | Eq. 3 |
//! | [`corr`]      | Pearson CORR distance | Eq. 1 |
//! | [`daco`]      | auto-correlation operator distance | Eq. 2 |
//! | [`dtw`]       | DTW (+ optimal path backtracking) | Eq. 4 |
//! | [`sakoe_chiba`] | DTW_sc corridor | [25], [26] |
//! | [`krdtw`]     | K_rdtw / K_rdtw_sc | Eq. 6-7, Alg. 2 |
//! | [`kga`]       | global alignment kernel (extra baseline) | Eq. 5 |
//! | [`spdtw`]     | SP-DTW over the LOC sparse grid | Eq. 9, Alg. 1 |
//! | [`spkrdtw`]   | SP-K_rdtw over the LOC sparse grid | Alg. 2 |
//! | [`lb_keogh`]  | LB_Keogh envelopes + 1-NN pruning baseline | §II-B.2 [27] |
//! | [`spec`]      | [`spec::MeasureSpec`]: one typed, serializable entrypoint to the family | — |
//!
//! Every DP measure reports the number of **visited cells**, the unit of
//! the paper's Table VI speed-up comparison.
//!
//! The [`lb_keogh`] envelopes also power [`crate::search`], the cascaded
//! lower-bound + early-abandoning k-NN subsystem, which cuts the number
//! of full comparisons per query the same way the LOC grid cuts the
//! cells per comparison.

pub mod corr;
pub mod daco;
pub mod dtw;
pub mod euclidean;
pub mod itakura;
pub mod kga;
pub mod krdtw;
pub mod lb_keogh;
pub mod sakoe_chiba;
pub mod spdtw;
pub mod spec;
pub mod spkrdtw;
pub mod workspace;

use crate::data::TimeSeries;
use crate::measures::workspace::DpWorkspace;

/// Result of one pairwise evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DistResult {
    /// Dissimilarity value — smaller means closer (kernel measures are
    /// wrapped so this holds uniformly; see [`krdtw::KrdtwDist`]).
    pub value: f64,
    /// DP cells visited to produce the value (Table VI unit). Linear
    /// measures report T.
    pub visited_cells: u64,
}

impl DistResult {
    pub fn new(value: f64, visited_cells: u64) -> Self {
        DistResult {
            value,
            visited_cells,
        }
    }
}

/// A (dis)similarity measure on time series.
pub trait Measure: Send + Sync {
    /// Stable identifier used in tables and the CLI.
    fn name(&self) -> String;

    /// Dissimilarity between two series (smaller = closer).
    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult;

    /// Workspace-threaded variant of [`Self::dist`]: DP-backed measures
    /// run allocation-free against `ws` and MUST return a bit-identical
    /// result regardless of the workspace's prior contents (the reuse
    /// contract of [`workspace::DpWorkspace`]).  The default falls back
    /// to the allocating path — correct for the linear measures
    /// (Euclidean, CORR, DACO) that have no DP scratch to reuse.
    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let _ = ws;
        self.dist(x, y)
    }
}

/// A kernel (similarity) measure exposing log-kernel values, from which
/// normalized Gram matrices are built (`classify::gram`).
pub trait KernelMeasure: Send + Sync {
    fn name(&self) -> String;

    /// `log K(x, y)` plus visited-cell count.
    fn log_k(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult;

    /// Workspace-threaded variant of [`Self::log_k`], same bit-exact
    /// reuse contract as [`Measure::dist_with`].
    fn log_k_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let _ = ws;
        self.log_k(x, y)
    }
}

/// The "unreachable" sentinel shared with the Pallas kernels
/// (`python/compile/kernels/common.py`): any DP value at or above
/// [`BIG_THRESH`] means no admissible path existed.
pub const BIG: f64 = 1.0e30;
pub const BIG_THRESH: f64 = 1.0e29;
/// Log-domain zero for kernel DPs.
pub const NEG: f64 = -1.0e30;
pub const NEG_THRESH: f64 = -1.0e29;

/// Squared pointwise divergence φ used by all DP measures (the paper's
/// choice: squared Euclidean norm).
#[inline(always)]
pub fn phi(a: f64, b: f64) -> f64 {
    let d = a - b;
    d * d
}
