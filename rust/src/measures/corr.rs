//! Pearson correlation coefficient as a behavior-based (dis)similarity
//! (paper Eq. 1) with the distance form `1 - CORR`.
//!
//! Appendix A of the paper proves that on z-normalized data
//! `CORR(x, y) = 1 - d_E^2(x, y) / (2T)`, hence 1-NN under `1 - CORR`
//! ranks identically to 1-NN under Ed — reproduced in the tests.

use crate::data::TimeSeries;
use crate::measures::{DistResult, Measure};

/// Pearson correlation coefficient in [-1, 1].
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let (mut num, mut dx, mut dy) = (0.0, 0.0, 0.0);
    for (a, b) in x.iter().zip(y) {
        let u = a - mx;
        let v = b - my;
        num += u * v;
        dx += u * u;
        dy += v * v;
    }
    let den = (dx.sqrt()) * (dy.sqrt());
    if den <= 1e-300 {
        0.0
    } else {
        (num / den).clamp(-1.0, 1.0)
    }
}

/// CORR-based dissimilarity: `1 - CORR` (0 for perfectly correlated).
#[derive(Clone, Debug, Default)]
pub struct CorrDist;

impl Measure for CorrDist {
    fn name(&self) -> String {
        "CORR".into()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        DistResult::new(1.0 - pearson(&x.values, &y.values), x.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TimeSeries;
    use crate::measures::euclidean::Euclidean;
    use crate::util::rng::Pcg64;

    fn ts(v: Vec<f64>) -> TimeSeries {
        TimeSeries::new(0, v)
    }

    #[test]
    fn perfect_and_anti_correlation() {
        let x = ts(vec![1.0, 2.0, 3.0, 4.0]);
        let y = ts(vec![2.0, 4.0, 6.0, 8.0]);
        assert!((CorrDist.dist(&x, &y).value).abs() < 1e-12); // corr = +1
        let z = ts(vec![4.0, 3.0, 2.0, 1.0]);
        assert!((CorrDist.dist(&x, &z).value - 2.0).abs() < 1e-12); // corr = -1
    }

    #[test]
    fn appendix_a_identity_on_znormalized() {
        // corr(x, y) = 1 - dE^2 / (2T) for z-normalized series
        let mut rng = Pcg64::new(5);
        for _ in 0..20 {
            let t = 32;
            let mut x = ts((0..t).map(|_| rng.normal()).collect());
            let mut y = ts((0..t).map(|_| rng.normal()).collect());
            x.znormalize();
            y.znormalize();
            let corr = pearson(&x.values, &y.values);
            let de = Euclidean.dist(&x, &y).value;
            let rhs = 1.0 - de * de / (2.0 * t as f64);
            assert!((corr - rhs).abs() < 1e-9, "corr={corr} rhs={rhs}");
        }
    }

    #[test]
    fn corr_and_ed_rank_identically_on_znormalized() {
        // The Table II observation: 1-NN(CORR) == 1-NN(Ed) on UCR data.
        let mut rng = Pcg64::new(9);
        let t = 24;
        let probe = ts((0..t).map(|_| rng.normal()).collect()).znormalized();
        let cands: Vec<TimeSeries> = (0..10)
            .map(|_| ts((0..t).map(|_| rng.normal()).collect()).znormalized())
            .collect();
        let by_corr: Vec<usize> = {
            let mut idx: Vec<usize> = (0..cands.len()).collect();
            idx.sort_by(|&a, &b| {
                CorrDist
                    .dist(&probe, &cands[a])
                    .value
                    .total_cmp(&CorrDist.dist(&probe, &cands[b]).value)
            });
            idx
        };
        let by_ed: Vec<usize> = {
            let mut idx: Vec<usize> = (0..cands.len()).collect();
            idx.sort_by(|&a, &b| {
                Euclidean
                    .dist(&probe, &cands[a])
                    .value
                    .total_cmp(&Euclidean.dist(&probe, &cands[b]).value)
            });
            idx
        };
        assert_eq!(by_corr, by_ed);
    }

    #[test]
    fn constant_series_yield_zero_corr() {
        let x = ts(vec![1.0; 8]);
        let y = ts(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert!((CorrDist.dist(&x, &y).value - 1.0).abs() < 1e-12);
    }
}
