//! K_rdtw — the recursive edit-distance time-elastic kernel of Marteau &
//! Gibet (paper Eq. 6-7, Algorithm 2), computed in **log domain**: the
//! plain recursion multiplies `kappa/3 < 1` factors ~2T times and
//! underflows f64 beyond T ≈ 150 (DESIGN.md §6).  `log K(x,y)` values
//! feed the normalized Gram construction in `classify::gram`.
//!
//! The corridor variant K_rdtw_sc restricts the admissible cells to a
//! Sakoe-Chiba band; the sparsified variant lives in `spkrdtw.rs`.

use crate::data::TimeSeries;
use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, DistResult, KernelMeasure, Measure, NEG, NEG_THRESH};

/// Elementwise logsumexp over three values, NEG-safe.
#[inline(always)]
pub(crate) fn lse3(a: f64, b: f64, c: f64) -> f64 {
    let m = a.max(b).max(c);
    if m <= NEG_THRESH {
        return NEG;
    }
    m + ((a - m).exp() + (b - m).exp() + (c - m).exp()).ln()
}

#[inline(always)]
pub(crate) fn lse2(a: f64, b: f64) -> f64 {
    let m = a.max(b);
    if m <= NEG_THRESH {
        return NEG;
    }
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// K_rdtw with local kernel `kappa(a,b) = exp(-nu * (a-b)^2)` and an
/// optional Sakoe-Chiba corridor (`band = None` = full grid).
#[derive(Clone, Debug)]
pub struct Krdtw {
    pub nu: f64,
    /// Corridor half-width in *cells* (None = unconstrained).
    pub band: Option<usize>,
}

impl Krdtw {
    pub fn new(nu: f64) -> Self {
        assert!(nu > 0.0);
        Krdtw { nu, band: None }
    }

    pub fn with_band(nu: f64, band: usize) -> Self {
        assert!(nu > 0.0);
        Krdtw {
            nu,
            band: Some(band),
        }
    }

    /// Core DP: returns log(K1 + K2) at the corner + visited cell count.
    /// Equal lengths are assumed (UCR setting); the K2 term requires it.
    /// Routes through the calling thread's TLS workspace; see
    /// [`Self::log_kernel_with`].
    pub fn log_kernel(&self, x: &[f64], y: &[f64]) -> DistResult {
        workspace::with_tls(|ws| self.log_kernel_with(ws, x, y))
    }

    /// [`Self::log_kernel`] against caller-provided scratch: the
    /// `(lK1, lK2)` pair rows and the `ls` local-kernel vector come
    /// from `ws` — zero allocations once warm, bit-identical results.
    pub fn log_kernel_with(&self, ws: &mut DpWorkspace, x: &[f64], y: &[f64]) -> DistResult {
        let t = x.len();
        assert_eq!(t, y.len(), "K_rdtw requires equal lengths");
        assert!(t > 0);
        let nu = self.nu;
        let log3 = 3.0f64.ln();
        let DpWorkspace {
            local_ls,
            pair_row_a,
            pair_row_b,
            ..
        } = ws;
        // Same-index local log kernel ls[i] = -nu (x_i - y_i)^2.
        local_ls.clear();
        local_ls.extend((0..t).map(|i| -nu * phi(x[i], y[i])));
        let ls: &[f64] = local_ls;

        crate::measures::workspace::reset(pair_row_a, t, (NEG, NEG));
        crate::measures::workspace::reset(pair_row_b, t, (NEG, NEG));
        let (mut prev, mut cur) = (pair_row_a, pair_row_b); // (lK1, lK2) rows
        let mut visited = 0u64;

        for i in 0..t {
            let (lo, hi) = match self.band {
                Some(b) => (i.saturating_sub(b), (i + b).min(t - 1)),
                None => (0, t - 1),
            };
            for c in cur.iter_mut() {
                *c = (NEG, NEG);
            }
            for j in lo..=hi {
                visited += 1;
                let lk = -nu * phi(x[i], y[j]);
                if i == 0 && j == 0 {
                    cur[0] = (lk, ls[0]);
                    continue;
                }
                let p11 = if i > 0 && j > 0 { prev[j - 1].0 } else { NEG };
                let p10 = if i > 0 { prev[j].0 } else { NEG };
                let p01 = if j > 0 { cur[j - 1].0 } else { NEG };
                let l1 = lk - log3 + lse3(p11, p10, p01);

                let q11 = if i > 0 && j > 0 { prev[j - 1].1 } else { NEG };
                let q10 = if i > 0 { prev[j].1 } else { NEG };
                let q01 = if j > 0 { cur[j - 1].1 } else { NEG };
                let ls_i = ls[i];
                let ls_j = ls[j];
                let avg = (((ls_i.exp() + ls_j.exp()) * 0.5).max(1e-300)).ln();
                let l2 = -log3 + lse3(avg + q11, ls_i + q10, ls_j + q01);
                cur[j] = (l1, l2);
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        let (l1, l2) = prev[t - 1];
        DistResult::new(lse2(l1, l2), visited)
    }
}

impl KernelMeasure for Krdtw {
    fn name(&self) -> String {
        match self.band {
            None => "Krdtw".into(),
            Some(b) => format!("Krdtw_sc({b})"),
        }
    }

    fn log_k(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.log_kernel(&x.values, &y.values)
    }

    fn log_k_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.log_kernel_with(ws, &x.values, &y.values)
    }
}

/// Distance wrapper for 1-NN: `d(x,y) = -(log K(x,y) - (log K(x,x) +
/// log K(y,y))/2)` — the negative log of the cosine-normalized kernel,
/// which ranks identically to the kernel-induced distance
/// `sqrt(2 - 2 K̃)` (both are monotone decreasing in K̃).
pub struct KrdtwDist {
    pub kernel: Krdtw,
}

impl KrdtwDist {
    pub fn new(kernel: Krdtw) -> Self {
        KrdtwDist { kernel }
    }
}

impl Measure for KrdtwDist {
    fn name(&self) -> String {
        KernelMeasure::name(&self.kernel)
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let kxy = self.kernel.log_kernel(&x.values, &y.values);
        let kxx = self.kernel.log_kernel(&x.values, &x.values);
        let kyy = self.kernel.log_kernel(&y.values, &y.values);
        let norm = kxy.value - 0.5 * (kxx.value + kyy.value);
        DistResult::new(-norm, kxy.visited_cells + kxx.visited_cells + kyy.visited_cells)
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let kxy = self.kernel.log_kernel_with(ws, &x.values, &y.values);
        let kxx = self.kernel.log_kernel_with(ws, &x.values, &x.values);
        let kyy = self.kernel.log_kernel_with(ws, &y.values, &y.values);
        let norm = kxy.value - 0.5 * (kxx.value + kyy.value);
        DistResult::new(-norm, kxy.visited_cells + kxx.visited_cells + kyy.visited_cells)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Plain-domain Algorithm 2 (small T only) — the textbook oracle.
    /// Flat row-major DP buffers (cell (i, j) at `i * t + j`).
    fn krdtw_plain(x: &[f64], y: &[f64], nu: f64, band: Option<usize>) -> f64 {
        let t = x.len();
        let kap = |a: f64, b: f64| (-nu * (a - b) * (a - b)).exp();
        let mut k1 = vec![0.0f64; t * t];
        let mut k2 = vec![0.0f64; t * t];
        for i in 0..t {
            for j in 0..t {
                if let Some(b) = band {
                    if i.abs_diff(j) > b {
                        continue;
                    }
                }
                if i == 0 && j == 0 {
                    k1[0] = kap(x[0], y[0]);
                    k2[0] = kap(x[0], y[0]);
                    continue;
                }
                let p11 = if i > 0 && j > 0 { k1[(i - 1) * t + j - 1] } else { 0.0 };
                let p10 = if i > 0 { k1[(i - 1) * t + j] } else { 0.0 };
                let p01 = if j > 0 { k1[i * t + j - 1] } else { 0.0 };
                k1[i * t + j] = kap(x[i], y[j]) / 3.0 * (p11 + p10 + p01);
                let q11 = if i > 0 && j > 0 { k2[(i - 1) * t + j - 1] } else { 0.0 };
                let q10 = if i > 0 { k2[(i - 1) * t + j] } else { 0.0 };
                let q01 = if j > 0 { k2[i * t + j - 1] } else { 0.0 };
                let kii = kap(x[i], y[i]);
                let kjj = kap(x[j], y[j]);
                k2[i * t + j] = ((kii + kjj) * 0.5 * q11 + q10 * kii + q01 * kjj) / 3.0;
            }
        }
        k1[t * t - 1] + k2[t * t - 1]
    }

    #[test]
    fn log_matches_plain_small_t() {
        let mut rng = Pcg64::new(1);
        for _ in 0..20 {
            let t = 3 + rng.below(10);
            let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            for nu in [0.1, 1.0, 5.0] {
                let plain = krdtw_plain(&x, &y, nu, None);
                let log = Krdtw::new(nu).log_kernel(&x, &y).value;
                assert!(
                    (log - plain.ln()).abs() < 1e-9,
                    "t={t} nu={nu}: {log} vs {}",
                    plain.ln()
                );
            }
        }
    }

    #[test]
    fn corridor_matches_plain_small_t() {
        let mut rng = Pcg64::new(2);
        let t = 9;
        let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
        for band in [1usize, 2, 4] {
            let plain = krdtw_plain(&x, &y, 0.5, Some(band));
            let log = Krdtw::with_band(0.5, band).log_kernel(&x, &y).value;
            assert!((log - plain.ln()).abs() < 1e-9);
        }
    }

    #[test]
    fn symmetry() {
        let mut rng = Pcg64::new(3);
        let x: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let k = Krdtw::new(1.0);
        assert!((k.log_kernel(&x, &y).value - k.log_kernel(&y, &x).value).abs() < 1e-10);
    }

    #[test]
    fn long_series_stay_finite() {
        // T = 600 underflows plain f64; log domain must survive.
        let mut rng = Pcg64::new(4);
        let x: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
        let v = Krdtw::new(1.0).log_kernel(&x, &y).value;
        assert!(v.is_finite() && v > NEG_THRESH && v < 0.0);
    }

    #[test]
    fn self_kernel_dominates_cross() {
        // normalized K̃(x,y) <= 1 = K̃(x,x)
        let mut rng = Pcg64::new(5);
        let x: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let k = Krdtw::new(1.0);
        let kxy = k.log_kernel(&x, &y).value;
        let kxx = k.log_kernel(&x, &x).value;
        let kyy = k.log_kernel(&y, &y).value;
        assert!(kxy - 0.5 * (kxx + kyy) <= 1e-9);
    }

    #[test]
    fn dist_wrapper_zero_on_self() {
        use crate::data::TimeSeries;
        let mut rng = Pcg64::new(6);
        let x = TimeSeries::new(0, (0..25).map(|_| rng.normal()).collect());
        let d = KrdtwDist::new(Krdtw::new(1.0)).dist(&x, &x);
        assert!(d.value.abs() < 1e-9);
    }

    #[test]
    fn small_gram_is_positive_definite() {
        // Eq. 6's p.d. claim, checked via eigen-free Cholesky attempt.
        // Flat row-major matrices (cell (i, j) at `i * n + j`).
        let mut rng = Pcg64::new(7);
        let n = 6;
        let series: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..15).map(|_| rng.normal()).collect())
            .collect();
        let k = Krdtw::new(0.8);
        let mut lk = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                lk[i * n + j] = k.log_kernel(&series[i], &series[j]).value;
            }
        }
        let mut g = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                g[i * n + j] = (lk[i * n + j] - 0.5 * (lk[i * n + i] + lk[j * n + j])).exp();
            }
        }
        // Cholesky with small jitter must succeed for a p.s.d. matrix.
        let mut a = g.clone();
        for i in 0..n {
            a[i * n + i] += 1e-10;
        }
        for c in 0..n {
            for r in c..n {
                let mut sum = a[r * n + c];
                for k2 in 0..c {
                    sum -= a[r * n + k2] * a[c * n + k2];
                }
                if r == c {
                    assert!(sum > 0.0, "not p.d. at {c}: {sum}");
                    a[r * n + c] = sum.sqrt();
                } else {
                    a[r * n + c] = sum / a[c * n + c];
                }
            }
        }
    }

    #[test]
    fn visited_cells_band_vs_full() {
        let mut rng = Pcg64::new(8);
        let x: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let full = Krdtw::new(1.0).log_kernel(&x, &y).visited_cells;
        let banded = Krdtw::with_band(1.0, 5).log_kernel(&x, &y).visited_cells;
        assert_eq!(full, 2500);
        assert!(banded < full);
    }
}
