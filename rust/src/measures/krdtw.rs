//! K_rdtw — the recursive edit-distance time-elastic kernel of Marteau &
//! Gibet (paper Eq. 6-7, Algorithm 2), computed in **log domain**: the
//! plain recursion multiplies `kappa/3 < 1` factors ~2T times and
//! underflows f64 beyond T ≈ 150 (DESIGN.md §6).  `log K(x,y)` values
//! feed the normalized Gram construction in `classify::gram`.
//!
//! The corridor variant K_rdtw_sc restricts the admissible cells to a
//! Sakoe-Chiba band; the sparsified variant lives in `spkrdtw.rs`.

use crate::data::TimeSeries;
use crate::measures::{phi, DistResult, KernelMeasure, Measure, NEG, NEG_THRESH};

/// Elementwise logsumexp over three values, NEG-safe.
#[inline(always)]
pub(crate) fn lse3(a: f64, b: f64, c: f64) -> f64 {
    let m = a.max(b).max(c);
    if m <= NEG_THRESH {
        return NEG;
    }
    m + ((a - m).exp() + (b - m).exp() + (c - m).exp()).ln()
}

#[inline(always)]
pub(crate) fn lse2(a: f64, b: f64) -> f64 {
    let m = a.max(b);
    if m <= NEG_THRESH {
        return NEG;
    }
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// K_rdtw with local kernel `kappa(a,b) = exp(-nu * (a-b)^2)` and an
/// optional Sakoe-Chiba corridor (`band = None` = full grid).
#[derive(Clone, Debug)]
pub struct Krdtw {
    pub nu: f64,
    /// Corridor half-width in *cells* (None = unconstrained).
    pub band: Option<usize>,
}

impl Krdtw {
    pub fn new(nu: f64) -> Self {
        assert!(nu > 0.0);
        Krdtw { nu, band: None }
    }

    pub fn with_band(nu: f64, band: usize) -> Self {
        assert!(nu > 0.0);
        Krdtw {
            nu,
            band: Some(band),
        }
    }

    /// Core DP: returns log(K1 + K2) at the corner + visited cell count.
    /// Equal lengths are assumed (UCR setting); the K2 term requires it.
    pub fn log_kernel(&self, x: &[f64], y: &[f64]) -> DistResult {
        let t = x.len();
        assert_eq!(t, y.len(), "K_rdtw requires equal lengths");
        assert!(t > 0);
        let nu = self.nu;
        let log3 = 3.0f64.ln();
        // Same-index local log kernel ls[i] = -nu (x_i - y_i)^2.
        let ls: Vec<f64> = (0..t).map(|i| -nu * phi(x[i], y[i])).collect();

        let mut prev = vec![(NEG, NEG); t]; // (lK1, lK2) row i-1
        let mut cur = vec![(NEG, NEG); t];
        let mut visited = 0u64;

        for i in 0..t {
            let (lo, hi) = match self.band {
                Some(b) => (i.saturating_sub(b), (i + b).min(t - 1)),
                None => (0, t - 1),
            };
            for c in cur.iter_mut() {
                *c = (NEG, NEG);
            }
            for j in lo..=hi {
                visited += 1;
                let lk = -nu * phi(x[i], y[j]);
                if i == 0 && j == 0 {
                    cur[0] = (lk, ls[0]);
                    continue;
                }
                let p11 = if i > 0 && j > 0 { prev[j - 1].0 } else { NEG };
                let p10 = if i > 0 { prev[j].0 } else { NEG };
                let p01 = if j > 0 { cur[j - 1].0 } else { NEG };
                let l1 = lk - log3 + lse3(p11, p10, p01);

                let q11 = if i > 0 && j > 0 { prev[j - 1].1 } else { NEG };
                let q10 = if i > 0 { prev[j].1 } else { NEG };
                let q01 = if j > 0 { cur[j - 1].1 } else { NEG };
                let ls_i = ls[i];
                let ls_j = ls[j];
                let avg = (((ls_i.exp() + ls_j.exp()) * 0.5).max(1e-300)).ln();
                let l2 = -log3 + lse3(avg + q11, ls_i + q10, ls_j + q01);
                cur[j] = (l1, l2);
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        let (l1, l2) = prev[t - 1];
        DistResult::new(lse2(l1, l2), visited)
    }
}

impl KernelMeasure for Krdtw {
    fn name(&self) -> String {
        match self.band {
            None => "Krdtw".into(),
            Some(b) => format!("Krdtw_sc({b})"),
        }
    }

    fn log_k(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.log_kernel(&x.values, &y.values)
    }
}

/// Distance wrapper for 1-NN: `d(x,y) = -(log K(x,y) - (log K(x,x) +
/// log K(y,y))/2)` — the negative log of the cosine-normalized kernel,
/// which ranks identically to the kernel-induced distance
/// `sqrt(2 - 2 K̃)` (both are monotone decreasing in K̃).
pub struct KrdtwDist {
    pub kernel: Krdtw,
}

impl KrdtwDist {
    pub fn new(kernel: Krdtw) -> Self {
        KrdtwDist { kernel }
    }
}

impl Measure for KrdtwDist {
    fn name(&self) -> String {
        KernelMeasure::name(&self.kernel)
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let kxy = self.kernel.log_kernel(&x.values, &y.values);
        let kxx = self.kernel.log_kernel(&x.values, &x.values);
        let kyy = self.kernel.log_kernel(&y.values, &y.values);
        let norm = kxy.value - 0.5 * (kxx.value + kyy.value);
        DistResult::new(-norm, kxy.visited_cells + kxx.visited_cells + kyy.visited_cells)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Plain-domain Algorithm 2 (small T only) — the textbook oracle.
    fn krdtw_plain(x: &[f64], y: &[f64], nu: f64, band: Option<usize>) -> f64 {
        let t = x.len();
        let kap = |a: f64, b: f64| (-nu * (a - b) * (a - b)).exp();
        let mut k1 = vec![vec![0.0f64; t]; t];
        let mut k2 = vec![vec![0.0f64; t]; t];
        for i in 0..t {
            for j in 0..t {
                if let Some(b) = band {
                    if i.abs_diff(j) > b {
                        continue;
                    }
                }
                if i == 0 && j == 0 {
                    k1[0][0] = kap(x[0], y[0]);
                    k2[0][0] = kap(x[0], y[0]);
                    continue;
                }
                let p11 = if i > 0 && j > 0 { k1[i - 1][j - 1] } else { 0.0 };
                let p10 = if i > 0 { k1[i - 1][j] } else { 0.0 };
                let p01 = if j > 0 { k1[i][j - 1] } else { 0.0 };
                k1[i][j] = kap(x[i], y[j]) / 3.0 * (p11 + p10 + p01);
                let q11 = if i > 0 && j > 0 { k2[i - 1][j - 1] } else { 0.0 };
                let q10 = if i > 0 { k2[i - 1][j] } else { 0.0 };
                let q01 = if j > 0 { k2[i][j - 1] } else { 0.0 };
                let kii = kap(x[i], y[i]);
                let kjj = kap(x[j], y[j]);
                k2[i][j] = ((kii + kjj) * 0.5 * q11 + q10 * kii + q01 * kjj) / 3.0;
            }
        }
        k1[t - 1][t - 1] + k2[t - 1][t - 1]
    }

    #[test]
    fn log_matches_plain_small_t() {
        let mut rng = Pcg64::new(1);
        for _ in 0..20 {
            let t = 3 + rng.below(10);
            let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            for nu in [0.1, 1.0, 5.0] {
                let plain = krdtw_plain(&x, &y, nu, None);
                let log = Krdtw::new(nu).log_kernel(&x, &y).value;
                assert!(
                    (log - plain.ln()).abs() < 1e-9,
                    "t={t} nu={nu}: {log} vs {}",
                    plain.ln()
                );
            }
        }
    }

    #[test]
    fn corridor_matches_plain_small_t() {
        let mut rng = Pcg64::new(2);
        let t = 9;
        let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
        for band in [1usize, 2, 4] {
            let plain = krdtw_plain(&x, &y, 0.5, Some(band));
            let log = Krdtw::with_band(0.5, band).log_kernel(&x, &y).value;
            assert!((log - plain.ln()).abs() < 1e-9);
        }
    }

    #[test]
    fn symmetry() {
        let mut rng = Pcg64::new(3);
        let x: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let k = Krdtw::new(1.0);
        assert!((k.log_kernel(&x, &y).value - k.log_kernel(&y, &x).value).abs() < 1e-10);
    }

    #[test]
    fn long_series_stay_finite() {
        // T = 600 underflows plain f64; log domain must survive.
        let mut rng = Pcg64::new(4);
        let x: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
        let v = Krdtw::new(1.0).log_kernel(&x, &y).value;
        assert!(v.is_finite() && v > NEG_THRESH && v < 0.0);
    }

    #[test]
    fn self_kernel_dominates_cross() {
        // normalized K̃(x,y) <= 1 = K̃(x,x)
        let mut rng = Pcg64::new(5);
        let x: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let k = Krdtw::new(1.0);
        let kxy = k.log_kernel(&x, &y).value;
        let kxx = k.log_kernel(&x, &x).value;
        let kyy = k.log_kernel(&y, &y).value;
        assert!(kxy - 0.5 * (kxx + kyy) <= 1e-9);
    }

    #[test]
    fn dist_wrapper_zero_on_self() {
        use crate::data::TimeSeries;
        let mut rng = Pcg64::new(6);
        let x = TimeSeries::new(0, (0..25).map(|_| rng.normal()).collect());
        let d = KrdtwDist::new(Krdtw::new(1.0)).dist(&x, &x);
        assert!(d.value.abs() < 1e-9);
    }

    #[test]
    fn small_gram_is_positive_definite() {
        // Eq. 6's p.d. claim, checked via eigen-free Cholesky attempt.
        let mut rng = Pcg64::new(7);
        let n = 6;
        let series: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..15).map(|_| rng.normal()).collect())
            .collect();
        let k = Krdtw::new(0.8);
        let mut lk = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in 0..n {
                lk[i][j] = k.log_kernel(&series[i], &series[j]).value;
            }
        }
        let mut g = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in 0..n {
                g[i][j] = (lk[i][j] - 0.5 * (lk[i][i] + lk[j][j])).exp();
            }
        }
        // Cholesky with small jitter must succeed for a p.s.d. matrix.
        let mut a = g.clone();
        for i in 0..n {
            a[i][i] += 1e-10;
        }
        for c in 0..n {
            for r in c..n {
                let mut sum = a[r][c];
                for k2 in 0..c {
                    sum -= a[r][k2] * a[c][k2];
                }
                if r == c {
                    assert!(sum > 0.0, "not p.d. at {c}: {sum}");
                    a[r][c] = sum.sqrt();
                } else {
                    a[r][c] = sum / a[c][c];
                }
            }
        }
    }

    #[test]
    fn visited_cells_band_vs_full() {
        let mut rng = Pcg64::new(8);
        let x: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let full = Krdtw::new(1.0).log_kernel(&x, &y).visited_cells;
        let banded = Krdtw::with_band(1.0, 5).log_kernel(&x, &y).visited_cells;
        assert_eq!(full, 2500);
        assert!(banded < full);
    }
}
