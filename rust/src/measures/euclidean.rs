//! Euclidean and Minkowski (L_p) distances — the lock-step baselines
//! (paper Eq. 3).  Linear complexity; visited cells = T.

use crate::data::TimeSeries;
use crate::measures::{DistResult, Measure};

/// Euclidean distance (L2).
#[derive(Clone, Debug, Default)]
pub struct Euclidean;

impl Measure for Euclidean {
    fn name(&self) -> String {
        "Ed".into()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        assert_eq!(x.len(), y.len(), "Ed requires equal lengths");
        let s: f64 = x
            .values
            .iter()
            .zip(&y.values)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        DistResult::new(s.sqrt(), x.len() as u64)
    }
}

/// Minkowski distance of order p (p=1 Manhattan, p=2 Euclidean, ...).
#[derive(Clone, Debug)]
pub struct Minkowski {
    pub p: f64,
}

impl Minkowski {
    pub fn new(p: f64) -> Self {
        assert!(p >= 1.0, "Minkowski order must be >= 1");
        Minkowski { p }
    }
}

impl Measure for Minkowski {
    fn name(&self) -> String {
        format!("L{}", self.p)
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        assert_eq!(x.len(), y.len(), "L_p requires equal lengths");
        if self.p.is_infinite() {
            let m = x
                .values
                .iter()
                .zip(&y.values)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            return DistResult::new(m, x.len() as u64);
        }
        let s: f64 = x
            .values
            .iter()
            .zip(&y.values)
            .map(|(a, b)| (a - b).abs().powf(self.p))
            .sum();
        DistResult::new(s.powf(1.0 / self.p), x.len() as u64)
    }
}

/// Gaussian (RBF) kernel on the Euclidean distance — the "Ed" column of
/// the paper's SVM comparison (Table IV): `K(x,y) = exp(-nu d_E^2)`.
/// Exposed as a log-kernel so it plugs into the same normalized-Gram
/// machinery as the elastic kernels.
#[derive(Clone, Debug)]
pub struct GaussianEd {
    pub nu: f64,
}

impl GaussianEd {
    pub fn new(nu: f64) -> Self {
        assert!(nu > 0.0);
        GaussianEd { nu }
    }

    /// Median heuristic: `nu = 1 / median(d_E^2)` over a sample of pairs.
    pub fn median_heuristic(set: &crate::data::LabeledSet) -> f64 {
        let n = set.len().min(40);
        // lint:allow(hot-alloc): one-shot training heuristic, not a DP kernel.
        let mut d2s = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                let d: f64 = set.series[i]
                    .values
                    .iter()
                    .zip(&set.series[j].values)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                d2s.push(d);
            }
        }
        if d2s.is_empty() {
            return 1.0;
        }
        d2s.sort_by(|a, b| a.total_cmp(b));
        let med = d2s[d2s.len() / 2].max(1e-12);
        1.0 / med
    }
}

impl crate::measures::KernelMeasure for GaussianEd {
    fn name(&self) -> String {
        "Ed".into()
    }

    fn log_k(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let d2: f64 = x
            .values
            .iter()
            .zip(&y.values)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        DistResult::new(-self.nu * d2, x.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TimeSeries;
    use crate::measures::KernelMeasure;

    fn ts(v: &[f64]) -> TimeSeries {
        TimeSeries::new(0, v.to_vec())
    }

    #[test]
    fn gaussian_ed_self_is_log_one() {
        let k = GaussianEd::new(0.5);
        let x = ts(&[1.0, 2.0, 3.0]);
        assert_eq!(k.log_k(&x, &x).value, 0.0);
        assert!(k.log_k(&x, &ts(&[0.0, 0.0, 0.0])).value < 0.0);
    }

    #[test]
    fn median_heuristic_positive() {
        use crate::data::splits::from_pairs;
        let set = from_pairs(vec![
            (0, vec![0.0, 1.0]),
            (0, vec![1.0, 0.0]),
            (1, vec![5.0, 5.0]),
        ]);
        let nu = GaussianEd::median_heuristic(&set);
        assert!(nu > 0.0 && nu.is_finite());
    }

    #[test]
    fn euclidean_basics() {
        let e = Euclidean;
        let d = e.dist(&ts(&[0.0, 0.0]), &ts(&[3.0, 4.0]));
        assert!((d.value - 5.0).abs() < 1e-12);
        assert_eq!(d.visited_cells, 2);
        assert_eq!(e.dist(&ts(&[1.0, 2.0]), &ts(&[1.0, 2.0])).value, 0.0);
    }

    #[test]
    fn minkowski_orders() {
        let x = ts(&[0.0, 0.0, 0.0]);
        let y = ts(&[1.0, -2.0, 2.0]);
        assert!((Minkowski::new(1.0).dist(&x, &y).value - 5.0).abs() < 1e-12);
        assert!((Minkowski::new(2.0).dist(&x, &y).value - 3.0).abs() < 1e-12);
        assert!((Minkowski::new(f64::INFINITY).dist(&x, &y).value - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lp_monotone_in_p() {
        // ||.||_p is non-increasing in p
        let x = ts(&[0.3, -1.2, 0.7, 2.0]);
        let y = ts(&[-0.5, 0.2, 1.9, 0.0]);
        let d1 = Minkowski::new(1.0).dist(&x, &y).value;
        let d2 = Minkowski::new(2.0).dist(&x, &y).value;
        let d4 = Minkowski::new(4.0).dist(&x, &y).value;
        assert!(d1 >= d2 && d2 >= d4);
    }
}
