//! Itakura parallelogram DTW — the other classic global *constraint*
//! baseline (paper §II-B.2 category 1, ref [15]): admissible cells lie
//! inside a parallelogram enforcing local slope bounds [1/2, 2] from
//! both endpoints.  Included alongside Sakoe-Chiba so the learned
//! sparsification can be compared against both fixed-shape search
//! spaces.

use crate::data::TimeSeries;
use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, DistResult, Measure, BIG};

/// Column range [lo, hi] of the Itakura parallelogram on row `i` of a
/// `t`×`t` grid (slope bounds 1/2 and 2 through (0,0) and (t-1,t-1)).
pub fn itakura_range(i: usize, t: usize) -> (usize, usize) {
    let n = (t - 1) as f64;
    let fi = i as f64;
    // from the start: j <= 2i, j >= i/2 ; from the end: mirrored
    let lo = (0.5 * fi).max(n - 2.0 * (n - fi)).ceil().max(0.0) as usize;
    let hi = (2.0 * fi).min(n - 0.5 * (n - fi)).floor() as usize;
    (lo.min(t - 1), hi.min(t - 1))
}

/// Number of admissible cells (Table-VI style accounting).
pub fn itakura_cells(t: usize) -> u64 {
    (0..t)
        .map(|i| {
            let (lo, hi) = itakura_range(i, t);
            if hi >= lo {
                (hi - lo + 1) as u64
            } else {
                0
            }
        })
        .sum()
}

/// DTW constrained to the Itakura parallelogram (equal lengths).
#[derive(Clone, Debug, Default)]
pub struct ItakuraDtw;

impl ItakuraDtw {
    /// The DP against caller-provided scratch (the two rolling rows) —
    /// zero allocations once warm, bit-identical to the TLS-backed
    /// [`Measure::dist`] path.
    pub fn eval_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        let t = x.len();
        assert_eq!(t, y.len(), "Itakura DTW requires equal lengths");
        assert!(t > 0);
        let (mut prev, mut cur) = ws.rows(t, BIG);
        let mut visited = 0u64;
        for i in 0..t {
            let (lo, hi) = itakura_range(i, t);
            for c in cur.iter_mut() {
                *c = BIG;
            }
            for j in lo..=hi.max(lo) {
                visited += 1;
                let local = phi(x.values[i], y.values[j]);
                let best = if i == 0 && j == 0 {
                    0.0
                } else {
                    let mut b = BIG;
                    if i > 0 {
                        b = b.min(prev[j]);
                        if j > 0 {
                            b = b.min(prev[j - 1]);
                        }
                    }
                    if j > 0 {
                        b = b.min(cur[j - 1]);
                    }
                    b
                };
                cur[j] = local + best;
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        DistResult::new(prev[t - 1], visited)
    }
}

impl Measure for ItakuraDtw {
    fn name(&self) -> String {
        "DTW_it".into()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        workspace::with_tls(|ws| self.eval_with(ws, x, y))
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.eval_with(ws, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::dtw::Dtw;
    use crate::util::rng::Pcg64;

    fn ts(rng: &mut Pcg64, t: usize) -> TimeSeries {
        TimeSeries::new(0, (0..t).map(|_| rng.normal()).collect())
    }

    #[test]
    fn range_contains_endpoints_and_diagonal() {
        for t in [2usize, 5, 17, 100] {
            let (lo0, hi0) = itakura_range(0, t);
            assert_eq!((lo0, hi0), (0, 0), "t={t}");
            let (lon, hin) = itakura_range(t - 1, t);
            assert_eq!((lon, hin), (t - 1, t - 1));
            for i in 0..t {
                let (lo, hi) = itakura_range(i, t);
                assert!(lo <= i && i <= hi, "diagonal cell (i,i) must be admissible");
            }
        }
    }

    #[test]
    fn cells_fewer_than_full_grid() {
        for t in [8usize, 64, 256] {
            let c = itakura_cells(t);
            assert!(c < (t * t) as u64);
            assert!(c >= t as u64);
        }
    }

    #[test]
    fn upper_bounds_unconstrained_dtw() {
        let mut rng = Pcg64::new(1);
        for _ in 0..10 {
            let x = ts(&mut rng, 24);
            let y = ts(&mut rng, 24);
            let full = Dtw.dist(&x, &y).value;
            let ita = ItakuraDtw.dist(&x, &y).value;
            assert!(ita >= full - 1e-12);
        }
    }

    #[test]
    fn identity_zero_and_symmetry() {
        let mut rng = Pcg64::new(2);
        let x = ts(&mut rng, 20);
        let y = ts(&mut rng, 20);
        assert!(ItakuraDtw.dist(&x, &x).value.abs() < 1e-12);
        let a = ItakuraDtw.dist(&x, &y).value;
        let b = ItakuraDtw.dist(&y, &x).value;
        assert!((a - b).abs() < 1e-9, "parallelogram is symmetric");
    }

    #[test]
    fn visited_matches_cell_formula() {
        let mut rng = Pcg64::new(3);
        let t = 50;
        let x = ts(&mut rng, t);
        let y = ts(&mut rng, t);
        assert_eq!(ItakuraDtw.dist(&x, &y).visited_cells, itakura_cells(t));
    }
}
