//! SP-DTW — Sparsified-Paths search space DTW (paper Eq. 9, Algorithm 1).
//!
//! The DP iterates ONLY over the cells of the learned LOC sparse matrix
//! (sorted by row, then column), so the complexity is linear in the
//! number of retained cells — between O(T) and O(T²) (paper §IV).
//! Cells absent from LOC behave as Max_Float (here `BIG`), exactly as in
//! Algorithm 1's initialization.

use crate::data::TimeSeries;
use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, DistResult, Measure, BIG};
use crate::sparse::LocMatrix;
use std::sync::Arc;

/// SP-DTW over a learned sparse alignment-path matrix.
#[derive(Clone)]
pub struct SpDtw {
    pub loc: Arc<LocMatrix>,
}

impl SpDtw {
    pub fn new(loc: LocMatrix) -> Self {
        SpDtw { loc: Arc::new(loc) }
    }

    pub fn from_arc(loc: Arc<LocMatrix>) -> Self {
        SpDtw { loc }
    }

    /// Algorithm 1 over raw slices — flat loop over LOC entries using the
    /// precomputed predecessor table (§Perf: ~3x over the row-cursor scan
    /// of [`Self::eval_scan`], which is kept as the reference).  Routes
    /// through the calling thread's TLS workspace; see
    /// [`Self::eval_with`].
    pub fn eval(&self, x: &[f64], y: &[f64]) -> DistResult {
        workspace::with_tls(|ws| self.eval_with(ws, x, y))
    }

    /// [`Self::eval`] against caller-provided scratch: the
    /// entry-parallel DP array comes from `ws`, so repeated evaluations
    /// allocate nothing and stay bit-identical to the allocating path.
    pub fn eval_with(&self, ws: &mut DpWorkspace, x: &[f64], y: &[f64]) -> DistResult {
        let loc = &*self.loc;
        let t = loc.t;
        assert_eq!(x.len(), t, "series length {} != grid size {t}", x.len());
        assert_eq!(y.len(), t, "series length {} != grid size {t}", y.len());
        let n = loc.nnz();
        let d = &mut ws.entries;
        d.clear();
        d.resize(n, BIG);
        for k in 0..n {
            let r = loc.rows[k] as usize;
            let c = loc.cols[k] as usize;
            let local = loc.weights[k] * phi(x[r], y[c]);
            let best = if r == 0 && c == 0 {
                0.0
            } else {
                let p = loc.preds[k];
                let mut b = BIG;
                for &pi in &p {
                    if pi != crate::sparse::loc::NO_PRED {
                        let v = d[pi as usize];
                        if v < b {
                            b = v;
                        }
                    }
                }
                b
            };
            d[k] = local + best;
        }
        let corner = loc
            .index_of(t - 1, t - 1)
            .map(|k| d[k])
            .unwrap_or(BIG + BIG);
        DistResult::new(corner, n as u64)
    }

    /// Reference implementation: row-cursor predecessor scan (the direct
    /// transcription of Algorithm 1's iteration).  Kept for §Perf
    /// before/after measurement and as a cross-check oracle.
    pub fn eval_scan(&self, x: &[f64], y: &[f64]) -> DistResult {
        let loc = &*self.loc;
        let t = loc.t;
        assert_eq!(x.len(), t, "series length {} != grid size {t}", x.len());
        assert_eq!(y.len(), t, "series length {} != grid size {t}", y.len());
        // DP values parallel to the LOC entry array.
        // lint:allow(hot-alloc): reference scan kept as a cross-check oracle.
        let mut d = vec![BIG; loc.nnz()];
        // Fast predecessor lookup inside the current and previous rows:
        // rows are contiguous CSR ranges, so we walk them with cursors.
        for r in 0..t {
            let (rs, re) = (loc.row_ptr[r], loc.row_ptr[r + 1]);
            let (ps, pe) = if r > 0 {
                (loc.row_ptr[r - 1], loc.row_ptr[r])
            } else {
                (0, 0)
            };
            let mut p_cursor = ps;
            for k in rs..re {
                let c = loc.cols[k] as usize;
                let w = loc.weights[k];
                let local = w * phi(x[r], y[c]);
                if r == 0 && c == 0 {
                    d[k] = local;
                    continue;
                }
                // advance previous-row cursor to the first col >= c-1
                while p_cursor < pe && (loc.cols[p_cursor] as usize) < c.saturating_sub(1) {
                    p_cursor += 1;
                }
                let mut best = BIG;
                // (r-1, c-1) and (r-1, c): at p_cursor / p_cursor+1 if match
                if r > 0 {
                    let mut q = p_cursor;
                    while q < pe && (loc.cols[q] as usize) <= c {
                        let pc = loc.cols[q] as usize;
                        if (c > 0 && pc == c - 1) || pc == c {
                            if d[q] < best {
                                best = d[q];
                            }
                        }
                        q += 1;
                    }
                }
                // (r, c-1): the immediately preceding entry of this row
                if c > 0 && k > rs && loc.cols[k - 1] as usize == c - 1 && d[k - 1] < best {
                    best = d[k - 1];
                }
                d[k] = local + best;
            }
        }
        let corner = loc
            .index_of(t - 1, t - 1)
            .map(|k| d[k])
            .unwrap_or(BIG + BIG);
        DistResult::new(corner, loc.nnz() as u64)
    }
}

impl Measure for SpDtw {
    fn name(&self) -> String {
        "SP-DTW".into()
    }

    fn dist(&self, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.eval(&x.values, &y.values)
    }

    fn dist_with(&self, ws: &mut DpWorkspace, x: &TimeSeries, y: &TimeSeries) -> DistResult {
        self.eval_with(ws, &x.values, &y.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::dtw::{dtw_banded, Dtw};
    use crate::measures::sakoe_chiba::SakoeChibaDtw;
    use crate::measures::BIG_THRESH;
    use crate::sparse::OccupancyGrid;
    use crate::util::rng::Pcg64;

    fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
        (0..t).map(|_| rng.normal()).collect()
    }

    #[test]
    fn full_grid_equals_dtw() {
        let mut rng = Pcg64::new(1);
        for t in [2usize, 5, 17, 40] {
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            let sp = SpDtw::new(LocMatrix::full(t));
            let got = sp.eval(&x, &y).value;
            let exp = dtw_banded(&x, &y, usize::MAX).value;
            assert!((got - exp).abs() < 1e-9, "t={t}: {got} vs {exp}");
        }
    }

    #[test]
    fn fast_eval_matches_scan_reference() {
        // §Perf invariant: the flat predecessor-table DP must agree with
        // the row-cursor reference on arbitrary sparse supports.
        let mut rng = Pcg64::new(99);
        for t in [3usize, 9, 21, 33] {
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            let mut triples = vec![(0usize, 0usize, 1.0f64), (t - 1, t - 1, 1.0)];
            for i in 0..t {
                for j in 0..t {
                    if rng.f64() < 0.4 {
                        triples.push((i, j, rng.range(0.5, 3.0)));
                    }
                }
            }
            let sp = SpDtw::new(LocMatrix::from_triples(t, triples));
            let a = sp.eval(&x, &y);
            let b = sp.eval_scan(&x, &y);
            assert_eq!(a.visited_cells, b.visited_cells);
            if a.value < crate::measures::BIG_THRESH {
                assert!((a.value - b.value).abs() < 1e-9, "t={t}");
            } else {
                assert!(b.value >= crate::measures::BIG_THRESH);
            }
        }
    }

    #[test]
    fn corridor_grid_equals_sakoe_chiba() {
        let mut rng = Pcg64::new(2);
        let t = 30;
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        for band in [0usize, 1, 3, 7] {
            let sp = SpDtw::new(LocMatrix::corridor(t, band));
            let got = sp.eval(&x, &y);
            let exp = dtw_banded(&x, &y, band);
            assert!((got.value - exp.value).abs() < 1e-9);
            assert_eq!(got.visited_cells, exp.visited_cells);
        }
    }

    #[test]
    fn visited_equals_nnz() {
        let loc = LocMatrix::corridor(20, 2);
        let nnz = loc.nnz() as u64;
        let sp = SpDtw::new(loc);
        let mut rng = Pcg64::new(3);
        let x = rand_vec(&mut rng, 20);
        let y = rand_vec(&mut rng, 20);
        assert_eq!(sp.eval(&x, &y).visited_cells, nnz);
    }

    #[test]
    fn weighted_cells_scale_cost() {
        // doubling all weights doubles the optimal cost
        let t = 10;
        let mut rng = Pcg64::new(4);
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        let base = LocMatrix::full(t);
        let doubled = LocMatrix::from_triples(
            t,
            base.to_triples().into_iter().map(|(r, c, w)| (r, c, 2.0 * w)).collect(),
        );
        let a = SpDtw::new(base).eval(&x, &y).value;
        let b = SpDtw::new(doubled).eval(&x, &y).value;
        assert!((b - 2.0 * a).abs() < 1e-9);
    }

    #[test]
    fn diagonal_only_grid_is_weighted_euclid() {
        let t = 8;
        let mut rng = Pcg64::new(5);
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        let sp = SpDtw::new(LocMatrix::corridor(t, 0));
        let got = sp.eval(&x, &y).value;
        let exp: f64 = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!((got - exp).abs() < 1e-12);
    }

    #[test]
    fn disconnected_grid_unreachable() {
        // cells (0,0) and (2,2) only: no continuity step can bridge them
        let loc = LocMatrix::from_triples(3, vec![(0, 0, 1.0), (2, 2, 1.0)]);
        let sp = SpDtw::new(loc);
        let d = sp.eval(&[0.0, 0.0, 0.0], &[0.0, 0.0, 0.0]);
        assert!(d.value >= BIG_THRESH);
    }

    #[test]
    fn missing_origin_unreachable() {
        let loc = LocMatrix::from_triples(2, vec![(0, 1, 1.0), (1, 1, 1.0)]);
        let sp = SpDtw::new(loc);
        let d = sp.eval(&[1.0, 2.0], &[1.0, 2.0]);
        assert!(d.value >= BIG_THRESH);
    }

    #[test]
    fn sparsification_never_decreases_cost() {
        // P ⊂ A: restricting the path set can only raise the minimum.
        let mut rng = Pcg64::new(6);
        let t = 16;
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        let full = SpDtw::new(LocMatrix::full(t)).eval(&x, &y).value;
        for band in [1usize, 2, 5] {
            let sparse = SpDtw::new(LocMatrix::corridor(t, band)).eval(&x, &y).value;
            assert!(sparse >= full - 1e-12);
        }
    }

    #[test]
    fn learned_grid_gamma0_interpolates_dtw_and_band() {
        // end-to-end shape: a learned LOC (θ=0, γ=0) must produce costs
        // >= full DTW (restriction) on cells it retains.
        use crate::data::synthetic;
        let ds = synthetic::generate_scaled("CBF", 11, 10, 4).unwrap();
        let grid: OccupancyGrid =
            crate::sparse::learn::learn_occupancy_grid(&ds.train, 2);
        let loc = grid.threshold(0.0).to_loc(0.0);
        let sp = SpDtw::new(loc);
        let a = &ds.test.series[0];
        let b = &ds.test.series[1];
        let d_sp = sp.dist(a, b).value;
        let d_full = Dtw.dist(a, b).value;
        assert!(d_sp >= d_full - 1e-9);
        assert!(d_sp < BIG_THRESH, "learned grid must keep pairs reachable");
        let _ = SakoeChibaDtw::new(10.0); // (referenced for comparison tests elsewhere)
    }
}
