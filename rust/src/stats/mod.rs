//! Statistical machinery for the evaluation tables: the Wilcoxon
//! signed-rank test (Tables III and V) and mean-rank aggregation (the
//! last rows of Tables II and IV).

pub mod wilcoxon;

use crate::util::mathx::avg_ranks;

/// Mean rank of each method across datasets (rows = datasets, columns =
/// methods; lower error -> better -> rank 1).  The "Mean rank" row of
/// Tables II/IV.
pub fn mean_ranks(rows: &[Vec<f64>]) -> Vec<f64> {
    assert!(!rows.is_empty());
    let m = rows[0].len();
    let mut acc = vec![0.0; m];
    for row in rows {
        assert_eq!(row.len(), m, "ragged results table");
        let r = avg_ranks(row);
        for (a, v) in acc.iter_mut().zip(&r) {
            *a += v;
        }
    }
    for a in &mut acc {
        *a /= rows.len() as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_ranks_basic() {
        // method 1 always best, method 0 always worst
        let rows = vec![vec![0.5, 0.1, 0.3], vec![0.4, 0.2, 0.3]];
        let r = mean_ranks(&rows);
        assert_eq!(r, vec![3.0, 1.0, 2.0]);
    }

    #[test]
    fn ties_share_rank() {
        let rows = vec![vec![0.2, 0.2, 0.5]];
        let r = mean_ranks(&rows);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }
}
