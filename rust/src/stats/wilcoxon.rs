//! Wilcoxon signed-rank test (two-sided), the significance machinery of
//! the paper's Tables III and V.
//!
//! - Exact null distribution by dynamic programming for n <= 25 zero-
//!   excluded pairs (feasible: 2^n states collapse to rank-sum counts).
//! - Normal approximation with tie correction and continuity correction
//!   for larger n (n = 30 datasets in the paper).
//! Zero differences are dropped (the standard Wilcoxon convention, also
//! matching the paper's treatment of equal error rates).

use crate::util::mathx::{avg_ranks, norm_cdf};

/// Test result.
#[derive(Clone, Debug)]
pub struct WilcoxonResult {
    /// Two-sided p-value.
    pub p_value: f64,
    /// W statistic = min(W+, W-).
    pub w: f64,
    /// Non-zero differences used.
    pub n_used: usize,
    /// Whether the exact distribution was used.
    pub exact: bool,
}

/// Two-sided Wilcoxon signed-rank test on paired samples.
pub fn wilcoxon_signed_rank(a: &[f64], b: &[f64]) -> WilcoxonResult {
    assert_eq!(a.len(), b.len(), "paired samples must match");
    let diffs: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(x, y)| x - y)
        .filter(|d| d.abs() > 1e-12)
        .collect();
    let n = diffs.len();
    if n == 0 {
        return WilcoxonResult {
            p_value: 1.0,
            w: 0.0,
            n_used: 0,
            exact: true,
        };
    }
    let abs: Vec<f64> = diffs.iter().map(|d| d.abs()).collect();
    let ranks = avg_ranks(&abs);
    let w_plus: f64 = diffs
        .iter()
        .zip(&ranks)
        .filter(|(d, _)| **d > 0.0)
        .map(|(_, r)| *r)
        .sum();
    let w_minus: f64 = diffs
        .iter()
        .zip(&ranks)
        .filter(|(d, _)| **d < 0.0)
        .map(|(_, r)| *r)
        .sum();
    let w = w_plus.min(w_minus);

    let has_ties = {
        let mut s = abs.clone();
        s.sort_by(|x, y| x.total_cmp(y));
        s.windows(2).any(|p| (p[0] - p[1]).abs() < 1e-12)
    };

    // Exact DP only valid for integer ranks (no ties) and small n.
    if n <= 25 && !has_ties {
        let p = exact_p_two_sided(n, w as usize);
        return WilcoxonResult {
            p_value: p,
            w,
            n_used: n,
            exact: true,
        };
    }

    // Normal approximation with tie + continuity corrections.
    let nn = n as f64;
    let mean = nn * (nn + 1.0) / 4.0;
    let mut var = nn * (nn + 1.0) * (2.0 * nn + 1.0) / 24.0;
    // tie correction: subtract sum(t^3 - t)/48 over tie groups
    {
        let mut s = abs.clone();
        s.sort_by(|x, y| x.total_cmp(y));
        let mut i = 0;
        while i < n {
            let mut j = i;
            while j + 1 < n && (s[j + 1] - s[i]).abs() < 1e-12 {
                j += 1;
            }
            let t = (j - i + 1) as f64;
            if t > 1.0 {
                var -= (t * t * t - t) / 48.0;
            }
            i = j + 1;
        }
    }
    let sd = var.sqrt();
    if sd <= 0.0 {
        return WilcoxonResult {
            p_value: 1.0,
            w,
            n_used: n,
            exact: false,
        };
    }
    let z = (w - mean + 0.5) / sd; // continuity correction toward the mean
    let p = (2.0 * norm_cdf(z)).min(1.0);
    WilcoxonResult {
        p_value: p,
        w,
        n_used: n,
        exact: false,
    }
}

/// Exact two-sided p-value: P(W <= w_obs) * 2 under the exact null
/// (rank-sum distribution over all 2^n sign assignments, computed by DP
/// over achievable sums).
fn exact_p_two_sided(n: usize, w_obs: usize) -> f64 {
    let max_sum = n * (n + 1) / 2;
    // counts[s] = number of subsets of {1..n} with sum s
    let mut counts = vec![0.0f64; max_sum + 1];
    counts[0] = 1.0;
    for r in 1..=n {
        for s in (r..=max_sum).rev() {
            counts[s] += counts[s - r];
        }
    }
    let total = 2.0f64.powi(n as i32);
    // P(W+ <= w_obs) ; W = min tail, two-sided doubles it
    let tail: f64 = counts[..=w_obs.min(max_sum)].iter().sum();
    (2.0 * tail / total).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_samples_p_one() {
        let a = [0.1, 0.2, 0.3];
        let r = wilcoxon_signed_rank(&a, &a);
        assert_eq!(r.p_value, 1.0);
        assert_eq!(r.n_used, 0);
    }

    #[test]
    fn textbook_exact_example() {
        // classic example (Conover): n=8 distinct diffs, all positive
        // => W = 0, exact two-sided p = 2/2^8 = 0.0078125
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let b = [0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0];
        let r = wilcoxon_signed_rank(&a, &b);
        assert!(r.exact);
        assert!((r.p_value - 2.0 / 256.0).abs() < 1e-12, "p={}", r.p_value);
    }

    #[test]
    fn exact_symmetric_case() {
        // diffs +1, -2: ranks 1, 2 -> W+ = 1, W- = 2, W = 1
        // exact: P(W+ <= 1) = (#{sum<=1} = 2)/4 -> p = 1.0
        let a = [1.0, 0.0];
        let b = [0.0, 2.0];
        let r = wilcoxon_signed_rank(&a, &b);
        assert!(r.exact);
        assert!((r.p_value - 1.0).abs() < 1e-12);
    }

    #[test]
    fn approx_used_for_large_or_tied() {
        let a: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..30).map(|i| i as f64 + 1.0).collect(); // all diffs tied
        let r = wilcoxon_signed_rank(&a, &b);
        assert!(!r.exact);
        assert!(r.p_value < 0.001, "uniform improvement must be significant, p={}", r.p_value);
    }

    #[test]
    fn one_sided_dominance_is_significant() {
        // method B better on 28/30 datasets by varying margins
        let a: Vec<f64> = (0..30).map(|i| 0.3 + 0.001 * i as f64).collect();
        let mut b = a.clone();
        for (i, v) in b.iter_mut().enumerate() {
            *v -= if i < 28 { 0.02 + 0.001 * i as f64 } else { -0.005 };
        }
        let r = wilcoxon_signed_rank(&a, &b);
        assert!(r.p_value < 0.01, "p={}", r.p_value);
    }

    #[test]
    fn noise_is_not_significant() {
        // symmetric ± noise
        let a: Vec<f64> = (0..30).map(|i| 0.3 + 0.01 * ((i * 37 % 11) as f64)).collect();
        let b: Vec<f64> = (0..30)
            .map(|i| 0.3 + 0.01 * (((i * 37 + 5) % 11) as f64))
            .collect();
        let r = wilcoxon_signed_rank(&a, &b);
        assert!(r.p_value > 0.05, "p={}", r.p_value);
    }

    #[test]
    fn symmetry_in_arguments() {
        let a = [0.1, 0.5, 0.3, 0.9, 0.2, 0.8];
        let b = [0.2, 0.4, 0.6, 0.5, 0.1, 0.3];
        let r1 = wilcoxon_signed_rank(&a, &b);
        let r2 = wilcoxon_signed_rank(&b, &a);
        assert!((r1.p_value - r2.p_value).abs() < 1e-12);
    }

    #[test]
    fn exact_dp_total_mass() {
        // sanity on the DP: tail at max W is 1.0 (doubled then clamped)
        assert_eq!(exact_p_two_sided(5, 15), 1.0);
    }
}
