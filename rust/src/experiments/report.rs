//! Report writers: aligned markdown tables + JSON dumps for every
//! regenerated table/figure.

use std::path::Path;

use crate::error::Result;
use crate::util::json::Json;

/// A simple table: header + rows of strings, rendered as markdown.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "ragged row");
        self.rows.push(row);
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&line(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row));
        }
        let _ = ncol;
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "header",
                Json::arr(self.header.iter().map(|h| Json::str(h.clone()))),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::str(c.clone())))),
                ),
            ),
        ])
    }

    /// Write `<dir>/<stem>.md` and `<dir>/<stem>.json`.
    pub fn write(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{stem}.json")), self.to_json().to_pretty())?;
        Ok(())
    }
}

/// Format an error rate like the paper (3 decimals).
pub fn fmt_err(e: f64) -> String {
    format!("{e:.3}")
}

/// Format a p-value like the paper's Tables III/V.
pub fn fmt_p(p: f64) -> String {
    if p < 0.0001 {
        "p<0.0001".to_string()
    } else {
        format!("{p:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_render_and_files() {
        let mut t = Table::new("Demo", &["a", "bbbb"]);
        t.push_row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a"));
        let dir = std::env::temp_dir().join(format!("spdtw_rep_{}", std::process::id()));
        t.write(&dir, "demo").unwrap();
        assert!(dir.join("demo.md").exists());
        assert!(dir.join("demo.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_err(0.12345), "0.123");
        assert_eq!(fmt_p(0.00005), "p<0.0001");
        assert_eq!(fmt_p(0.0125), "0.0125");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push_row(vec!["1".into()]);
    }
}
