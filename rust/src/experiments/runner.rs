//! Per-dataset evaluation pipeline shared by every table/figure:
//! generate → learn occupancy grid → tune meta-parameters on train →
//! evaluate all measures on test → one [`DatasetEval`] row.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::classify::gram::{cross_gram, gram_1nn_error};
use crate::classify::nn::{classify_1nn, classify_knn_indexed};
use crate::classify::svm::{classify_svm, SvmParams};
use crate::config::ExperimentConfig;
use crate::data::synthetic;
use crate::data::Dataset;
use crate::error::Result;
use crate::measures::euclidean::GaussianEd;
use crate::measures::sakoe_chiba::{band_cells, SakoeChibaDtw};
use crate::measures::spec::{GridResolver, GridSpec, MeasureSpec, TrainGridResolver};
use crate::search::{Cascade, Index};
use crate::sparse::learn::learn_occupancy_grid;
use crate::sparse::OccupancyGrid;
use crate::tuning;

/// Everything the tables need about one dataset run.
#[derive(Clone, Debug)]
pub struct DatasetEval {
    pub name: String,
    pub t: usize,
    pub n_train: usize,
    pub n_test: usize,
    /// Tuned meta-parameters.
    pub band_pct: f64,
    pub theta: f64,
    pub gamma: f64,
    pub nu: f64,
    /// 1-NN error per measure (Table II columns).
    pub err_1nn: BTreeMap<String, f64>,
    /// SVM error per kernel (Table IV columns).
    pub err_svm: BTreeMap<String, f64>,
    /// Visited cells per single pairwise comparison (Table VI).
    pub cells: BTreeMap<String, u64>,
    /// Cascade pruning ratio (candidates resolved without a completed
    /// full DP) for the index-backed search path over the same measure
    /// — the Table VI column next to the visited-cell counts (ROADMAP
    /// PR-1 follow-up).  Keys: `DTW_sc`, `SP-DTW`.
    pub prune: BTreeMap<String, f64>,
    /// θ grid-search curve (Fig. 4).
    pub theta_curve: Vec<(f64, f64)>,
}

/// Order of the 1-NN columns (paper Table II).
pub const NN_METHODS: &[&str] = &[
    "CORR", "DACO", "Ed", "DTW", "DTW_sc", "Krdtw", "SP-DTW", "SP-Krdtw",
];

/// Order of the SVM columns (paper Table IV).
pub const SVM_METHODS: &[&str] = &["Ed", "Krdtw", "Krdtw_sc", "SP-Krdtw"];

/// Generate the (possibly capped) dataset for a config.
pub fn load_dataset(cfg: &ExperimentConfig, name: &str) -> Result<Dataset> {
    let (mut cap_train, mut cap_test) = cfg.caps();
    if !cfg.full {
        // long-series datasets get smaller caps so the default sweep
        // stays laptop-scale (documented in DESIGN.md §4)
        let t = crate::data::registry::find(name).map(|s| s.length).unwrap_or(0);
        if t > 800 {
            cap_train = cap_train.min(20);
            cap_test = cap_test.min(20);
        } else if t > 400 {
            cap_train = cap_train.min(30);
            cap_test = cap_test.min(40);
        }
    }
    synthetic::generate_scaled(name, cfg.seed, cap_train, cap_test)
}

/// Learn grid + tune parameters only (the cheap prefix used by the
/// figures and by `evaluate_dataset`).
pub struct TunedModels {
    pub grid: OccupancyGrid,
    pub band_pct: f64,
    pub theta: f64,
    pub gamma: f64,
    pub nu: f64,
    pub daco_lags: usize,
    pub theta_curve: Vec<(f64, f64)>,
}

pub fn tune_on_train(cfg: &ExperimentConfig, ds: &Dataset) -> TunedModels {
    let threads = cfg.threads;
    let grid = learn_occupancy_grid(&ds.train, threads);
    let (band_pct, _) = tuning::tune_band_pct(&ds.train, &tuning::band_pct_grid(), threads);
    let (theta, theta_curve) =
        tuning::tune_theta(&grid, &ds.train, 1.0, &tuning::theta_grid(), threads);
    let (gamma, _) = tuning::tune_gamma(&grid, &ds.train, theta, &tuning::gamma_grid(), threads);
    // nu tuned on a corridor for tractability; reused by all kernels
    let t = ds.series_len();
    let tune_band = ((0.1 * t as f64) as usize).max(2);
    let (nu, _) = tuning::tune_nu(&ds.train, &tuning::nu_grid(), Some(tune_band), threads);
    let (daco_lags, _) = tuning::tune_daco_lags(&ds.train, &tuning::lag_grid(), threads);
    TunedModels {
        grid,
        band_pct,
        theta,
        gamma,
        nu,
        daco_lags,
        theta_curve,
    }
}

/// The full pipeline for one dataset.
pub fn evaluate_dataset(cfg: &ExperimentConfig, name: &str, with_svm: bool) -> Result<DatasetEval> {
    let ds = load_dataset(cfg, name)?;
    let threads = cfg.threads;
    let t = ds.series_len();
    let tuned = tune_on_train(cfg, &ds);

    let mut err_1nn = BTreeMap::new();
    let mut cells = BTreeMap::new();
    let mut prune = BTreeMap::new();

    // Every measure is constructed through the unified MeasureSpec
    // factory; the resolver reuses the tuned occupancy grid so
    // `learned` grid references do not re-learn it per spec.
    let resolver = TrainGridResolver {
        train: Some(&ds.train),
        grid: Some(&tuned.grid),
        threads,
    };
    let learned_w = GridSpec::Learned { theta: tuned.theta, gamma: tuned.gamma };
    // kernel grids drop weights (mask semantics): gamma = 0 emits the
    // same cell support with unit weights, i.e. exactly to_loc_mask()
    let learned_m = GridSpec::Learned { theta: tuned.theta, gamma: 0.0 };

    // ---- behavior-based + lock-step baselines -----------------------------
    for (label, spec) in [
        ("CORR", MeasureSpec::Corr),
        ("DACO", MeasureSpec::Daco { lags: tuned.daco_lags }),
        ("Ed", MeasureSpec::Euclidean),
        ("DTW", MeasureSpec::Dtw),
    ] {
        let m = spec.build_measure(&resolver)?;
        err_1nn.insert(
            label.into(),
            classify_1nn(&*m, &ds.train, &ds.test, threads).error_rate,
        );
    }
    cells.insert("DTW".into(), (t * t) as u64);

    // DTW_sc and SP-DTW run through the index-backed search cascade:
    // results are bit-identical to exhaustive `classify_1nn` over the
    // same measure (the `search` exactness contract, asserted in
    // `classification_agrees_with_bruteforce_knn`), so one pass yields
    // both the Table II error rate and the Table VI pruning ratio —
    // no duplicate exhaustive evaluation of the test set.
    let sc = SakoeChibaDtw::new(tuned.band_pct);
    cells.insert("DTW_sc".into(), band_cells(t, sc.band_for(t)));
    let sc_index = Arc::new(Index::build_from_spec(
        &ds.train,
        &MeasureSpec::SakoeChiba { band_pct: tuned.band_pct },
        false,
        &resolver,
        threads,
    )?);
    let (sc_eval, sc_stats) =
        classify_knn_indexed(&sc_index, Cascade::default(), &ds.test, 1, threads);
    err_1nn.insert("DTW_sc".into(), sc_eval.error_rate);
    prune.insert("DTW_sc".into(), sc_stats.prune_ratio());

    let sp_index = Arc::new(Index::build_from_spec(
        &ds.train,
        &MeasureSpec::SpDtw { grid: learned_w },
        false,
        &resolver,
        threads,
    )?);
    cells.insert(
        "SP-DTW".into(),
        sp_index.loc.as_ref().map(|l| l.nnz()).unwrap_or(0) as u64,
    );
    let (sp_eval, sp_stats) =
        classify_knn_indexed(&sp_index, Cascade::default(), &ds.test, 1, threads);
    err_1nn.insert("SP-DTW".into(), sp_eval.error_rate);
    prune.insert("SP-DTW".into(), sp_stats.prune_ratio());

    // ---- kernel family (via normalized Grams) ------------------------------
    let krdtw = MeasureSpec::Krdtw { nu: tuned.nu, band_cells: None }.build_kernel(&resolver)?;
    let cg = cross_gram(&*krdtw, &ds.test, &ds.train, threads);
    err_1nn.insert("Krdtw".into(), gram_1nn_error(&cg, &ds.test, &ds.train));
    cells.insert("Krdtw".into(), (t * t) as u64);

    let spk_spec = MeasureSpec::SpKrdtw { nu: tuned.nu, grid: learned_m.clone() };
    let spk = spk_spec.build_kernel(&resolver)?;
    cells.insert(
        "SP-Krdtw".into(),
        resolver.resolve(&learned_m)?.nnz() as u64,
    );
    let cg = cross_gram(&*spk, &ds.test, &ds.train, threads);
    err_1nn.insert("SP-Krdtw".into(), gram_1nn_error(&cg, &ds.test, &ds.train));

    // ---- SVM (Table IV) -----------------------------------------------------
    let mut err_svm = BTreeMap::new();
    if with_svm {
        let params = SvmParams::default();
        // the Gaussian-Ed kernel's nu comes from a data-dependent
        // median heuristic, so it stays a direct construction
        let ed_nu = GaussianEd::median_heuristic(&ds.train);
        err_svm.insert(
            "Ed".into(),
            classify_svm(&GaussianEd::new(ed_nu), &ds.train, &ds.test, &params, threads, cfg.seed)
                .error_rate,
        );
        let sc_band = sc.band_for(t).max(1);
        for (label, spec) in [
            ("Krdtw", MeasureSpec::Krdtw { nu: tuned.nu, band_cells: None }),
            ("Krdtw_sc", MeasureSpec::Krdtw { nu: tuned.nu, band_cells: Some(sc_band) }),
            ("SP-Krdtw", MeasureSpec::SpKrdtw { nu: tuned.nu, grid: learned_m.clone() }),
        ] {
            let kernel = spec.build_kernel(&resolver)?;
            err_svm.insert(
                label.into(),
                classify_svm(&*kernel, &ds.train, &ds.test, &params, threads, cfg.seed)
                    .error_rate,
            );
        }
    }

    Ok(DatasetEval {
        name: name.to_string(),
        t,
        n_train: ds.train.len(),
        n_test: ds.test.len(),
        band_pct: tuned.band_pct,
        theta: tuned.theta,
        gamma: tuned.gamma,
        nu: tuned.nu,
        err_1nn,
        err_svm,
        cells,
        prune,
        theta_curve: tuned.theta_curve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            max_train: 12,
            max_test: 9,
            threads: 4,
            ..Default::default()
        }
    }

    #[test]
    fn pipeline_produces_all_columns() {
        let cfg = tiny_cfg();
        let ev = evaluate_dataset(&cfg, "CBF", true).unwrap();
        for m in NN_METHODS {
            assert!(ev.err_1nn.contains_key(*m), "missing 1-NN column {m}");
            let e = ev.err_1nn[*m];
            assert!((0.0..=1.0).contains(&e), "{m}: {e}");
        }
        for m in SVM_METHODS {
            assert!(ev.err_svm.contains_key(*m), "missing SVM column {m}");
        }
        // Table VI accounting
        assert_eq!(ev.cells["DTW"], (ev.t * ev.t) as u64);
        assert!(ev.cells["SP-DTW"] <= ev.cells["DTW"]);
        assert!(ev.cells["DTW_sc"] <= ev.cells["DTW"]);
        // cascade pruning ratios ride along (ROADMAP PR-1 follow-up)
        for m in ["DTW_sc", "SP-DTW"] {
            let p = ev.prune[m];
            assert!((0.0..=1.0).contains(&p), "{m}: prune ratio {p}");
        }
        assert!(!ev.theta_curve.is_empty());
    }

    #[test]
    fn corr_equals_ed_observation() {
        // the Appendix A equivalence must show up in the pipeline output
        let cfg = tiny_cfg();
        let ev = evaluate_dataset(&cfg, "SyntheticControl", false).unwrap();
        assert_eq!(ev.err_1nn["CORR"], ev.err_1nn["Ed"]);
    }

    #[test]
    fn long_series_caps_applied() {
        let cfg = tiny_cfg();
        let ds = load_dataset(&cfg, "InlineSkate").unwrap();
        assert!(ds.train.len() <= 20);
    }
}
