//! Experiments harness: regenerates every table and figure of the
//! paper's evaluation section (see DESIGN.md §4 for the index).
//!
//! `run("all", &cfg)` executes the per-dataset pipeline once and derives
//! Tables II/III/IV/V/VI from the shared results; figures re-use the
//! cached grids.  Reports land in `cfg.out_dir` as markdown + JSON (+
//! PGM/PPM for the figures).

pub mod report;
pub mod runner;

use std::path::Path;

use crate::config::ExperimentConfig;
use crate::data::registry;
use crate::error::{Error, Result};
use crate::sparse::learn::learn_occupancy_grid;
use crate::stats::mean_ranks;
use crate::stats::wilcoxon::wilcoxon_signed_rank;
use crate::tuning;
use crate::viz::Heatmap;
use report::{fmt_err, fmt_p, Table};
use runner::{evaluate_dataset, DatasetEval, NN_METHODS, SVM_METHODS};

/// Known experiment ids.
pub const EXPERIMENTS: &[&str] = &[
    "table1", "table2", "table3", "table4", "table5", "table6", "fig4", "fig5", "fig6", "fig7",
    "fig8",
];

/// Entry point: run one experiment id (or "all").
pub fn run(id: &str, cfg: &ExperimentConfig) -> Result<()> {
    match id {
        "all" => run_all(cfg),
        "table1" => table1(cfg),
        "table2" | "table3" | "table6" => {
            let evals = run_pipeline(cfg, false)?;
            table2(cfg, &evals)?;
            table3(cfg, &evals)?;
            table6(cfg, &evals)
        }
        "table4" | "table5" => {
            let evals = run_pipeline(cfg, true)?;
            table4(cfg, &evals)?;
            table5(cfg, &evals)
        }
        "fig4" => fig4(cfg),
        "fig5" => figure_grid(cfg, "Beef", "fig5"),
        "fig6" => figure_grid(cfg, "BeetleFly", "fig6"),
        "fig7" => figure_grid(cfg, "ElectricDevices", "fig7"),
        "fig8" => figure_grid(cfg, "MedicalImages", "fig8"),
        other => Err(Error::Unknown {
            kind: "experiment",
            name: other.to_string(),
        }),
    }
}

fn run_all(cfg: &ExperimentConfig) -> Result<()> {
    table1(cfg)?;
    let evals = run_pipeline(cfg, true)?;
    table2(cfg, &evals)?;
    table3(cfg, &evals)?;
    table4(cfg, &evals)?;
    table5(cfg, &evals)?;
    table6(cfg, &evals)?;
    fig4(cfg)?;
    for (ds, fig) in [
        ("Beef", "fig5"),
        ("BeetleFly", "fig6"),
        ("ElectricDevices", "fig7"),
        ("MedicalImages", "fig8"),
    ] {
        figure_grid(cfg, ds, fig)?;
    }
    Ok(())
}

/// Run the per-dataset pipeline over the configured datasets.
pub fn run_pipeline(cfg: &ExperimentConfig, with_svm: bool) -> Result<Vec<DatasetEval>> {
    let names = cfg.dataset_names();
    let mut evals = Vec::with_capacity(names.len());
    for (i, name) in names.iter().enumerate() {
        let t0 = std::time::Instant::now();
        let ev = evaluate_dataset(cfg, name, with_svm)?;
        eprintln!(
            "[{}/{}] {name}: T={} train={} test={} θ={} γ={} ν={} band={}%  ({:.1}s)",
            i + 1,
            names.len(),
            ev.t,
            ev.n_train,
            ev.n_test,
            ev.theta,
            ev.gamma,
            ev.nu,
            ev.band_pct,
            t0.elapsed().as_secs_f64()
        );
        evals.push(ev);
    }
    Ok(evals)
}

// ---------------------------------------------------------------------------
// Table I — dataset inventory
// ---------------------------------------------------------------------------

fn table1(cfg: &ExperimentConfig) -> Result<()> {
    let mut t = Table::new(
        "Table I — data description (paper sizes; scaled caps in brackets)",
        &["DataSet", "k", "N(train)", "N(test)", "T", "family"],
    );
    let (cap_tr, cap_te) = cfg.caps();
    for spec in registry::TABLE1 {
        let tr = if cfg.full {
            format!("{}", spec.train)
        } else {
            format!("{} [{}]", spec.train, spec.train.min(cap_tr))
        };
        let te = if cfg.full {
            format!("{}", spec.test)
        } else {
            format!("{} [{}]", spec.test, spec.test.min(cap_te))
        };
        t.push_row(vec![
            spec.name.to_string(),
            spec.classes.to_string(),
            tr,
            te,
            spec.length.to_string(),
            format!("{:?}", spec.family),
        ]);
    }
    t.write(&cfg.out_dir, "table1")?;
    println!("{}", t.to_markdown());
    Ok(())
}

// ---------------------------------------------------------------------------
// Table II — 1-NN error rates + mean rank
// ---------------------------------------------------------------------------

fn table2(cfg: &ExperimentConfig, evals: &[DatasetEval]) -> Result<()> {
    let mut header = vec!["DataSet"];
    header.extend(NN_METHODS);
    let mut t = Table::new("Table II — 1-NN classification error rate", &header);
    let mut rows_numeric: Vec<Vec<f64>> = Vec::new();
    for ev in evals {
        let mut row = vec![ev.name.clone()];
        let mut numeric = Vec::new();
        for m in NN_METHODS {
            let e = ev.err_1nn[*m];
            numeric.push(e);
            if *m == "DTW_sc" {
                row.push(format!("{}({})", fmt_err(e), ev.band_pct as i64));
            } else {
                row.push(fmt_err(e));
            }
        }
        rows_numeric.push(numeric);
        t.push_row(row);
    }
    let ranks = mean_ranks(&rows_numeric);
    let mut rank_row = vec!["Mean rank".to_string()];
    rank_row.extend(ranks.iter().map(|r| format!("{r:.2}")));
    t.push_row(rank_row);
    t.write(&cfg.out_dir, "table2")?;
    println!("{}", t.to_markdown());
    Ok(())
}

// ---------------------------------------------------------------------------
// Tables III / V — Wilcoxon signed-rank p-values
// ---------------------------------------------------------------------------

fn wilcoxon_table(
    title: &str,
    methods: &[&str],
    errors_of: impl Fn(&DatasetEval, &str) -> f64,
    evals: &[DatasetEval],
) -> Table {
    let mut header = vec!["Method"];
    header.extend(&methods[1..]);
    let mut t = Table::new(title, &header);
    for (i, a) in methods.iter().enumerate().take(methods.len() - 1) {
        let mut row = vec![a.to_string()];
        for b in &methods[1..] {
            if methods.iter().position(|m| m == b).unwrap() <= i {
                row.push("-".to_string());
                continue;
            }
            let ea: Vec<f64> = evals.iter().map(|ev| errors_of(ev, a)).collect();
            let eb: Vec<f64> = evals.iter().map(|ev| errors_of(ev, b)).collect();
            let w = wilcoxon_signed_rank(&ea, &eb);
            row.push(fmt_p(w.p_value));
        }
        t.push_row(row);
    }
    t
}

fn table3(cfg: &ExperimentConfig, evals: &[DatasetEval]) -> Result<()> {
    // paper groups CORR/Ed together (identical on z-normalized data)
    let methods = ["CORR", "DACO", "DTW", "DTW_sc", "Krdtw", "SP-DTW", "SP-Krdtw"];
    let t = wilcoxon_table(
        "Table III — Wilcoxon signed-rank p-values (1-NN)",
        &methods,
        |ev, m| ev.err_1nn[m],
        evals,
    );
    t.write(&cfg.out_dir, "table3")?;
    println!("{}", t.to_markdown());
    Ok(())
}

// ---------------------------------------------------------------------------
// Table IV — SVM error rates + mean rank
// ---------------------------------------------------------------------------

fn table4(cfg: &ExperimentConfig, evals: &[DatasetEval]) -> Result<()> {
    let mut header = vec!["DataSet"];
    header.extend(SVM_METHODS);
    let mut t = Table::new("Table IV — SVM classification error rate", &header);
    let mut rows_numeric = Vec::new();
    for ev in evals {
        if ev.err_svm.is_empty() {
            continue;
        }
        let mut row = vec![ev.name.clone()];
        let mut numeric = Vec::new();
        for m in SVM_METHODS {
            let e = ev.err_svm[*m];
            numeric.push(e);
            row.push(fmt_err(e));
        }
        rows_numeric.push(numeric);
        t.push_row(row);
    }
    if !rows_numeric.is_empty() {
        let ranks = mean_ranks(&rows_numeric);
        let mut rank_row = vec!["Mean rank".to_string()];
        rank_row.extend(ranks.iter().map(|r| format!("{r:.2}")));
        t.push_row(rank_row);
    }
    t.write(&cfg.out_dir, "table4")?;
    println!("{}", t.to_markdown());
    Ok(())
}

fn table5(cfg: &ExperimentConfig, evals: &[DatasetEval]) -> Result<()> {
    let with_svm: Vec<DatasetEval> = evals
        .iter()
        .filter(|e| !e.err_svm.is_empty())
        .cloned()
        .collect();
    let t = wilcoxon_table(
        "Table V — Wilcoxon signed-rank p-values (SVM)",
        SVM_METHODS,
        |ev, m| ev.err_svm[m],
        &with_svm,
    );
    t.write(&cfg.out_dir, "table5")?;
    println!("{}", t.to_markdown());
    Ok(())
}

// ---------------------------------------------------------------------------
// Table VI — visited cells / speed-up
// ---------------------------------------------------------------------------

fn table6(cfg: &ExperimentConfig, evals: &[DatasetEval]) -> Result<()> {
    // Two sparsification axes side by side: cells per comparison (the
    // paper's S(%) speed-up) and the search cascade's pruning ratio —
    // the fraction of k-NN candidates resolved without a completed full
    // DP when the same measure is served through the `search` engine.
    let mut t = Table::new(
        "Table VI — time speed-up vs standard DTW \
         (visited cells per comparison + cascade pruning ratio)",
        &[
            "DataSet", "DTW cells", "SC cells", "SC S(%)", "SC pruned(%)", "SP-DTW cells",
            "SP-DTW S(%)", "SP-DTW pruned(%)", "SP-Krdtw cells", "SP-Krdtw S(%)",
        ],
    );
    let (mut s_sc, mut s_sp, mut s_spk) = (0.0, 0.0, 0.0);
    let (mut p_sc, mut p_sp) = (0.0, 0.0);
    for ev in evals {
        let full = ev.cells["DTW"] as f64;
        let sc = ev.cells["DTW_sc"] as f64;
        let sp = ev.cells["SP-DTW"] as f64;
        let spk = ev.cells["SP-Krdtw"] as f64;
        let pct = |c: f64| 100.0 * (1.0 - c / full);
        let prune_sc = 100.0 * ev.prune.get("DTW_sc").copied().unwrap_or(0.0);
        let prune_sp = 100.0 * ev.prune.get("SP-DTW").copied().unwrap_or(0.0);
        s_sc += pct(sc);
        s_sp += pct(sp);
        s_spk += pct(spk);
        p_sc += prune_sc;
        p_sp += prune_sp;
        t.push_row(vec![
            ev.name.clone(),
            format!("{}", full as u64),
            format!("{}", sc as u64),
            format!("{:.1}", pct(sc)),
            format!("{prune_sc:.1}"),
            format!("{}", sp as u64),
            format!("{:.1}", pct(sp)),
            format!("{prune_sp:.1}"),
            format!("{}", spk as u64),
            format!("{:.1}", pct(spk)),
        ]);
    }
    let n = evals.len().max(1) as f64;
    t.push_row(vec![
        "Average (speed-up)".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", s_sc / n),
        format!("{:.1}", p_sc / n),
        "-".into(),
        format!("{:.1}", s_sp / n),
        format!("{:.1}", p_sp / n),
        "-".into(),
        format!("{:.1}", s_spk / n),
    ]);
    t.write(&cfg.out_dir, "table6")?;
    println!("{}", t.to_markdown());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — θ grid-search curves
// ---------------------------------------------------------------------------

fn fig4(cfg: &ExperimentConfig) -> Result<()> {
    let mut t = Table::new(
        "Fig. 4 — LOO error rate vs θ (train split)",
        &["DataSet", "θ", "LOO error"],
    );
    for name in ["50Words", "FacesUCR", "Wine"] {
        // LOO needs >= 2 series per class to be meaningful; lift the cap
        // to 3 per class for the many-class figure subjects.
        let mut fcfg = cfg.clone();
        if let Some(spec) = registry::find(name) {
            fcfg.max_train = fcfg.max_train.max(3 * spec.classes);
        }
        let cfg = &fcfg;
        let ds = runner::load_dataset(cfg, name)?;
        let grid = learn_occupancy_grid(&ds.train, cfg.threads);
        let (best, curve) =
            tuning::tune_theta(&grid, &ds.train, 1.0, &tuning::theta_grid(), cfg.threads);
        for (theta, err) in &curve {
            let marker = if *theta == best { " *" } else { "" };
            t.push_row(vec![
                name.to_string(),
                format!("{theta}{marker}"),
                fmt_err(*err),
            ]);
        }
    }
    t.write(&cfg.out_dir, "fig4")?;
    println!("{}", t.to_markdown());
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 5-8 — occupancy-grid panels
// ---------------------------------------------------------------------------

fn figure_grid(cfg: &ExperimentConfig, dataset: &str, fig: &str) -> Result<()> {
    let ds = runner::load_dataset(cfg, dataset)?;
    let threads = cfg.threads;
    let grid = learn_occupancy_grid(&ds.train, threads);
    let (band_pct, _) = tuning::tune_band_pct(&ds.train, &tuning::band_pct_grid(), threads);
    let (theta, _) = tuning::tune_theta(&grid, &ds.train, 1.0, &tuning::theta_grid(), threads);
    let t = ds.series_len();
    let band = ((band_pct / 100.0) * t as f64).round() as usize;

    let dir = cfg.out_dir.join(fig);
    let panels = [
        ("sakoe_chiba", Heatmap::corridor(t, band)),
        ("sparse_paths", Heatmap::from_occupancy(&grid)),
        (
            "sparse_thresholded",
            Heatmap::from_loc_support(&grid.threshold(theta).to_loc_mask()),
        ),
    ];
    let mut md = format!(
        "### {fig} — {dataset}: occupancy grids (T={t}, band={band}, θ={theta})\n\n"
    );
    for (name, hm) in &panels {
        hm.write_ppm(&dir.join(format!("{name}.ppm")), 256)?;
        hm.write_pgm(&dir.join(format!("{name}.pgm")), 256)?;
        md.push_str(&format!("**{name}**\n\n```\n{}```\n\n", hm.ascii(48)));
    }
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("panels.md"), &md)?;
    println!("{md}");
    Ok(())
}

/// Used by fig writers in `figure_grid` and the CLI.
pub fn out_dir_of(cfg: &ExperimentConfig) -> &Path {
    &cfg.out_dir
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(dir: &str) -> ExperimentConfig {
        ExperimentConfig {
            max_train: 10,
            max_test: 6,
            threads: 4,
            datasets: vec!["CBF".into(), "SyntheticControl".into(), "Gun-Point".into()],
            out_dir: std::env::temp_dir().join(format!("spdtw_exp_{dir}_{}", std::process::id())),
            ..Default::default()
        }
    }

    #[test]
    fn unknown_experiment_rejected() {
        let cfg = tiny_cfg("unknown");
        assert!(run("table99", &cfg).is_err());
    }

    #[test]
    fn table1_writes_files() {
        let cfg = tiny_cfg("t1");
        run("table1", &cfg).unwrap();
        assert!(cfg.out_dir.join("table1.md").exists());
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }

    #[test]
    fn tables_2_3_6_from_shared_pipeline() {
        let cfg = tiny_cfg("t236");
        let evals = run_pipeline(&cfg, false).unwrap();
        assert_eq!(evals.len(), 3);
        table2(&cfg, &evals).unwrap();
        table3(&cfg, &evals).unwrap();
        table6(&cfg, &evals).unwrap();
        for f in ["table2.md", "table3.md", "table6.md"] {
            assert!(cfg.out_dir.join(f).exists(), "{f}");
        }
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }

    #[test]
    fn figure_grid_writes_panels() {
        let mut cfg = tiny_cfg("fig");
        cfg.datasets = vec!["CBF".into()];
        figure_grid(&cfg, "CBF", "fig5").unwrap();
        let dir = cfg.out_dir.join("fig5");
        for f in ["sakoe_chiba.ppm", "sparse_paths.ppm", "sparse_thresholded.ppm", "panels.md"] {
            assert!(dir.join(f).exists(), "{f}");
        }
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
