//! Synchronization facade: `std::sync` in normal builds, `loom` under
//! `--cfg loom`.
//!
//! The concurrent-epoch scheduler in [`super`] is the riskiest code in
//! the crate — raw-pointer result slots, a `Runner<'_> → Runner<'static>`
//! transmute, hand-written `Send`/`Sync` impls — and its correctness
//! argument is a happens-before chain through a mutex, two condvars and
//! two atomics.  `tests/stress_pool.rs` *samples* schedules of that
//! chain; `tests/loom_pool.rs` *enumerates* them by compiling this exact
//! scheduler against [loom](https://docs.rs/loom)'s model-checked
//! primitives instead of `std`'s (see EXPERIMENTS.md §Correctness
//! toolchain).
//!
//! Everything the scheduler synchronizes through is imported from here
//! and nowhere else, so the model checks the shipped code path, not a
//! parallel reimplementation.  The facade is intentionally minimal:
//!
//! - [`Mutex`] / [`MutexGuard`] / [`Condvar`] / [`Arc`] — re-exported
//!   verbatim from `std::sync` or `loom::sync` (identical APIs,
//!   including `LockResult` poisoning signatures).
//! - [`AtomicBool`] / [`AtomicUsize`] / [`Ordering`] — ditto, from the
//!   respective `atomic` modules.
//! - [`thread`] — `loom::thread` models `spawn`/`JoinHandle`; the
//!   [`spawn_named`] helper papers over loom's missing
//!   `thread::Builder`.
//! - [`UnsafeCell`] — loom's instrumented cell (every access is
//!   causality-checked against every other access) with a thin std
//!   wrapper exposing the same `with_mut` API, so epoch output slots go
//!   through an access-tracked window in the model build and compile to
//!   a zero-cost `std::cell::UnsafeCell` otherwise.
//!
//! `loom` is **not** a dependency of this crate: the `--cfg loom` build
//! only compiles on CI (or locally) after a `cargo add --dev loom`
//! (see `.github/workflows/ci.yml` `loom-model` job), keeping the
//! shipped manifest dependency-free.

#[cfg(not(loom))]
pub(crate) use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub(crate) use std::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub(crate) use std::thread;

#[cfg(loom)]
pub(crate) use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(loom)]
pub(crate) use loom::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(loom)]
pub(crate) use loom::thread;

/// Spawn a worker thread.  `std` builds get a named thread (visible in
/// debuggers and panic messages); loom's `thread` has no `Builder`, so
/// the model build drops the name.
#[cfg(not(loom))]
pub(crate) fn spawn_named(
    name: String,
    f: impl FnOnce() + Send + 'static,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(name)
        .spawn(f)
        .expect("spawn compute-pool worker")
}

/// Spawn a worker thread (loom model build: unnamed `loom::thread`).
#[cfg(loom)]
pub(crate) fn spawn_named(
    _name: String,
    f: impl FnOnce() + Send + 'static,
) -> thread::JoinHandle<()> {
    thread::spawn(f)
}

/// Interior-mutability cell for epoch output slots.
///
/// `std` build: a transparent wrapper over [`std::cell::UnsafeCell`]
/// mirroring loom's `with_mut(*mut T)` access style.  Loom build: the
/// real `loom::cell::UnsafeCell`, which records every access and fails
/// the model if two threads ever touch a cell without a happens-before
/// edge between them — exactly the "disjoint slot writes are race-free"
/// claim the scheduler's `// SAFETY:` comments make in prose.
#[cfg(loom)]
pub(crate) use loom::cell::UnsafeCell;

/// Interior-mutability cell for epoch output slots (`std` flavor; see
/// the loom-side docs above).
#[cfg(not(loom))]
#[derive(Debug)]
pub(crate) struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

#[cfg(not(loom))]
impl<T> UnsafeCell<T> {
    /// Wrap a value.
    pub(crate) fn new(data: T) -> UnsafeCell<T> {
        UnsafeCell(std::cell::UnsafeCell::new(data))
    }

    /// Hand `f` a raw mutable pointer to the contents.  The caller's
    /// `unsafe` block around the dereference carries the aliasing
    /// argument (see the slot-write SAFETY comments in `pool/mod.rs`).
    pub(crate) fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }
}
