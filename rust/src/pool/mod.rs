//! Thread-pool substrate (rayon is not in the vendored crate set).
//!
//! Two tools:
//! - [`par_map`] / [`par_map_chunked`]: scoped data-parallel map over an
//!   index space with an atomic work counter — used for pairwise distance
//!   matrices, occupancy-grid learning and 1-NN search.
//! - [`WorkerPool`]: a persistent pool consuming boxed jobs from a shared
//!   queue — the execution engine under `coordinator::worker`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default (min(cores, 16)).
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Parallel map over `0..n` with dynamic (work-stealing-ish) scheduling:
/// each worker grabs chunks of indices from a shared atomic counter.
/// Returns results in index order.
pub fn par_map<R: Send, F: Fn(usize) -> R + Sync>(n: usize, threads: usize, f: F) -> Vec<R> {
    par_map_chunked(n, threads, 1, f)
}

/// Like [`par_map`] but workers claim `chunk` indices at a time — use a
/// larger chunk when the per-item body is tiny.
pub fn par_map_chunked<R: Send, F: Fn(usize) -> R + Sync>(
    n: usize,
    threads: usize,
    chunk: usize,
    f: F,
) -> Vec<R> {
    assert!(chunk > 0);
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let f = &f;
    // SAFETY-free approach: split `out` into per-index cells via raw
    // pointers is unnecessary — instead collect (idx, value) pairs per
    // worker and merge. Memory overhead is one Vec per worker.
    let mut partials: Vec<Vec<(usize, R)>> = Vec::new();
    thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                s.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            local.push((i, f(i)));
                        }
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            partials.push(h.join().expect("pool worker panicked"));
        }
    });
    for part in partials {
        for (i, v) in part {
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|v| v.expect("index not produced")).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent worker pool with a bounded job queue.
///
/// Bounded submission gives the coordinator backpressure: `submit` blocks
/// when `capacity` jobs are in flight.  Dropping the pool joins all
/// workers after draining the queue.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, Condvar)>,
    capacity: usize,
}

impl WorkerPool {
    pub fn new(threads: usize, capacity: usize) -> Self {
        assert!(threads > 0 && capacity > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("pool rx poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            let (lock, cv) = &*inflight;
                            let mut n = lock.lock().unwrap();
                            *n -= 1;
                            cv.notify_all();
                        }
                        Err(_) => break, // channel closed: shut down
                    }
                })
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            handles,
            inflight,
            capacity,
        }
    }

    /// Submit a job, blocking while the queue is at capacity
    /// (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let (lock, cv) = &*self.inflight;
        {
            let mut n = lock.lock().unwrap();
            while *n >= self.capacity {
                n = cv.wait(n).unwrap();
            }
            *n += 1;
        }
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn inflight(&self) -> usize {
        *self.inflight.0.lock().unwrap()
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers drain & exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<u64> = (0..257).map(|i| (i as u64) * 3 + 1).collect();
        let parallel = par_map(257, 4, |i| (i as u64) * 3 + 1);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_chunked_matches_serial() {
        let parallel = par_map_chunked(1000, 8, 13, |i| i * i);
        assert_eq!(parallel, (0..1000).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn worker_pool_runs_everything_once() {
        let pool = WorkerPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn worker_pool_backpressure_bounds_inflight() {
        let pool = WorkerPool::new(1, 2);
        for _ in 0..10 {
            pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(1)));
            assert!(pool.inflight() <= 2);
        }
        pool.wait_idle();
        assert_eq!(pool.inflight(), 0);
    }
}
