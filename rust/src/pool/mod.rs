//! Thread-pool substrate (rayon is not in the vendored crate set).
//!
//! Two tools:
//! - [`par_map`] / [`par_map_chunked`] / [`par_map_ws`]: data-parallel
//!   map over an index space, executed on a **persistent** process-wide
//!   compute pool.  Each pool worker owns a long-lived
//!   [`DpWorkspace`], so the distance kernels under pairwise-matrix,
//!   occupancy-grid and k-NN workloads run allocation-free
//!   (EXPERIMENTS.md §Perf).  Results are written straight into
//!   pre-sized disjoint output slots — no per-worker `(idx, value)`
//!   partials, no merge pass, no per-call thread spawn.
//! - [`WorkerPool`]: a persistent pool consuming boxed jobs from a
//!   shared queue — the execution engine under `coordinator::worker`.
//!
//! ## Concurrent epochs
//!
//! Each `par_map` call is one **epoch**: a slot holding the epoch's
//! type-erased runner, a participant count and a per-epoch completion
//! latch.  Any number of epochs can be live at once — workers claim
//! whichever live epoch is least served, so N simultaneous `par_map`
//! callers from distinct threads each make progress instead of queueing
//! behind a global submit lock (the PR 3 design serialized them; the
//! throughput collapse under multi-client coordinator load is the bug
//! this replaces).  The submitting thread participates in its own
//! epoch, so an epoch advances even when every pool worker is busy
//! elsewhere — there is no cross-epoch blocking anywhere, hence no
//! deadlock, and epoch completion waits only on its own participants.
//!
//! ## Scheduling & exactness
//!
//! Within an epoch, work is claimed dynamically from an atomic counter
//! (in `chunk`-sized runs), so the mapping of items to workers is
//! nondeterministic — but every item is computed by exactly one
//! participant and written to its own output slot, and the
//! workspace-reuse contract ([`crate::measures::workspace`]) guarantees
//! results are independent of which (dirty) workspace computed them.
//! `par_map(n, t, f)` is therefore bit-identical to `(0..n).map(f)` for
//! any thread count and any set of concurrently running epochs
//! (stress-tested in `tests/stress_pool.rs`).
//!
//! Panics stay contained per epoch: a panicking job aborts only its own
//! epoch (re-raised to that epoch's submitter as "pool worker
//! panicked"); concurrently running epochs are unaffected.
//!
//! ## Machine-checked correctness
//!
//! Everything this scheduler synchronizes through comes from the
//! [`sync`] facade, which compiles to `std::sync` normally and to
//! `loom`'s model-checked primitives under `--cfg loom` — so
//! `tests/loom_pool.rs` exhaustively enumerates the interleavings of
//! the *shipped* claim/latch/slot-write protocol (2-epoch overlap,
//! least-served claiming, submitter self-participation, panic
//! isolation, disjoint slot writes) rather than sampling them the way
//! `tests/stress_pool.rs` does.  The same code also runs under Miri and
//! ThreadSanitizer in CI.  EXPERIMENTS.md §Correctness toolchain
//! documents how to run each analysis locally and what each one
//! guarantees.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{mpsc, OnceLock};

use crate::measures::workspace::{self, DpWorkspace};

pub(crate) mod sync;

use self::sync::{
    spawn_named, thread, Arc, AtomicBool, AtomicUsize, Condvar, Mutex, MutexGuard, Ordering,
    UnsafeCell,
};

/// Number of worker threads to use by default (min(cores, 16)).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Poison-tolerant lock: pool invariants are maintained by drop guards,
/// so a poisoned mutex still holds consistent state.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// True on compute-pool worker threads: a nested `par_map` issued
    /// from inside a pool job must not wait on the pool it is running
    /// on, so it degrades to the serial path.
    static ON_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Parallel map over `0..n` with dynamic scheduling on the persistent
/// compute pool.  Returns results in index order.
pub fn par_map<R: Send, F: Fn(usize) -> R + Sync>(n: usize, threads: usize, f: F) -> Vec<R> {
    par_map_chunked(n, threads, 1, f)
}

/// Like [`par_map`] but workers claim `chunk` indices at a time — use a
/// larger chunk when the per-item body is tiny.
pub fn par_map_chunked<R: Send, F: Fn(usize) -> R + Sync>(
    n: usize,
    threads: usize,
    chunk: usize,
    f: F,
) -> Vec<R> {
    par_map_ws(n, threads, chunk, move |i, _ws| f(i))
}

/// Workspace-threaded parallel map: `f` receives the executing worker's
/// long-lived [`DpWorkspace`] alongside the item index, so DP kernels
/// inside the body can run their `*_into` / `dist_with` variants with
/// zero steady-state allocations.  Serial fallbacks (`threads <= 1`,
/// nested calls from a pool worker) reuse the calling thread's TLS
/// workspace instead.
///
/// Each call is its own concurrent epoch: simultaneous calls from
/// distinct threads overlap on the shared worker set instead of
/// serializing (see the module docs).
pub fn par_map_ws<R, F>(n: usize, threads: usize, chunk: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut DpWorkspace) -> R + Sync,
{
    assert!(chunk > 0);
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 || ON_POOL_WORKER.with(|c| c.get()) {
        return workspace::with_tls(|ws| (0..n).map(|i| f(i, ws)).collect());
    }
    compute_pool().run(n, threads, chunk, &f)
}

/// Size-aware variant of [`par_map_ws`]: instead of fixed-size chunks,
/// workers claim contiguous *spans* of roughly equal total `weight`
/// (e.g. candidate length, DP cell count).  With mixed per-item costs a
/// fixed chunk makes the unlucky worker the critical path; weighting
/// bounds each claim's cost at ~1/(4·threads) of the total.  Results
/// are in index order and bit-identical to the serial map — scheduling
/// never affects values, only which participant computes them.
pub fn par_map_ws_weighted<R, F>(n: usize, threads: usize, weights: &[usize], f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut DpWorkspace) -> R + Sync,
{
    assert_eq!(weights.len(), n, "one weight per item");
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 || ON_POOL_WORKER.with(|c| c.get()) {
        return workspace::with_tls(|ws| (0..n).map(|i| f(i, ws)).collect());
    }
    let spans = weighted_spans(weights, threads);
    compute_pool().run_spans(n, threads, &spans, &f)
}

/// Partition `0..weights.len()` into contiguous spans whose total
/// weights are roughly equal, targeting ~4 spans per thread (enough
/// slack for dynamic claiming to absorb stragglers without per-item
/// claim overhead).  Zero weights count as 1 so empty items still make
/// progress; spans always cover the index space exactly, in order.
pub fn weighted_spans(weights: &[usize], threads: usize) -> Vec<(usize, usize)> {
    let n = weights.len();
    let mut spans = Vec::new();
    if n == 0 {
        return spans;
    }
    let total: u128 = weights.iter().map(|&w| w.max(1) as u128).sum();
    let parts = (threads.max(1) as u128) * 4;
    let target = (total / parts).max(1);
    let mut start = 0usize;
    let mut acc: u128 = 0;
    for (i, &w) in weights.iter().enumerate() {
        acc += w.max(1) as u128;
        if acc >= target {
            spans.push((start, i + 1));
            start = i + 1;
            acc = 0;
        }
    }
    if start < n {
        spans.push((start, n));
    }
    spans
}

/// Point-in-time view of the compute pool's scheduler state — the
/// queue-depth / concurrency signal exported by the coordinator metrics
/// and asserted by the overlap tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Pool worker threads (0 until the first parallel epoch spins the
    /// pool up).
    pub workers: usize,
    /// Epochs currently live (submitted, not yet completed).
    pub active_epochs: usize,
    /// Participants (pool workers + submitting threads) currently
    /// executing some epoch's runner.
    pub running_participants: usize,
    /// High-water mark of simultaneously live epochs since process
    /// start — `>= 2` proves two `par_map` calls actually overlapped.
    pub peak_concurrent_epochs: usize,
}

/// Snapshot the scheduler state.  Cheap (one mutex acquisition).
pub fn pool_stats() -> PoolStats {
    match POOL.get() {
        None => PoolStats::default(),
        Some(pool) => {
            let st = lock(&pool.state);
            PoolStats {
                workers: pool.workers,
                active_epochs: st.epochs.len(),
                running_participants: st.epochs.iter().map(|e| e.running).sum(),
                peak_concurrent_epochs: st.peak_epochs,
            }
        }
    }
}

// ---------------------------------------------------------------------
// Persistent compute pool (concurrent-epoch scheduler)
// ---------------------------------------------------------------------

/// Type-erased per-epoch job body: claims work until the epoch's index
/// space is exhausted, using the executing participant's workspace.
type Runner<'a> = dyn Fn(&mut DpWorkspace) + Sync + 'a;

/// Raw pointer to one epoch's runner.
#[derive(Clone, Copy)]
struct RunnerPtr(*const Runner<'static>);

// SAFETY: the pointee is `Sync` (so `&Runner` may be shared across
// threads) and `ComputePool::execute` keeps it alive — and its epoch
// slot registered — until every participant has finished running it, so
// a `RunnerPtr` handed to a worker never dangles while dereferenceable.
unsafe impl Send for RunnerPtr {}

/// Borrow of one epoch's output-slot array, shared by every
/// participant.  Slot `i` is written only by the participant that
/// claimed index `i` from the epoch's atomic counter, so all writes are
/// disjoint; the submitter reads the slots only after the epoch's
/// completion latch.  Under `--cfg loom` each slot is an instrumented
/// `loom::cell::UnsafeCell`, so the model checker verifies that
/// disjointness claim on every explored interleaving.
struct EpochSlots<'a, R>(&'a [UnsafeCell<Option<R>>]);

// SAFETY: participants only touch disjoint slots (each index is claimed
// exactly once from the epoch's `AtomicUsize`), and results (`R`) move
// to the submitting thread when it drains the slots after the
// completion latch — hence `R: Send` is required and sufficient.
unsafe impl<R: Send> Sync for EpochSlots<'_, R> {}

impl<R> EpochSlots<'_, R> {
    /// Store the result for claimed index `i`.
    fn write(&self, i: usize, v: R) {
        // SAFETY: `i` was claimed by exactly this participant via the
        // epoch counter, so no other thread accesses slot `i` until the
        // submitter reads it back after the completion latch
        // (happens-after every participant's decrement under the state
        // mutex).
        self.0[i].with_mut(|p| unsafe { *p = Some(v) });
    }
}

/// One live epoch in the scheduler.
struct EpochSlot {
    id: u64,
    runner: RunnerPtr,
    /// Participants (workers + the submitter) currently inside
    /// `runner`.
    running: usize,
    /// Set once any participant's `runner` call returned: the index
    /// space is drained (or the epoch panicked), so no new participant
    /// may join.
    exhausted: bool,
    /// Max simultaneous participants (the caller's `threads` hint).
    target: usize,
}

struct PoolState {
    epochs: Vec<EpochSlot>,
    next_id: u64,
    peak_epochs: usize,
    /// Workspace-trim generation (bumped by [`trim_workspaces`]); each
    /// worker trims once per generation and acks.
    trim_gen: u64,
    trim_acks: usize,
    /// Terminal: set by [`ComputePool::shutdown`]; workers exit instead
    /// of parking.  Never set on the process-wide pool — it exists so
    /// bounded-lifetime pools (loom models, tests) leave no threads
    /// behind.
    shutdown: bool,
}

/// The persistent worker pool behind [`par_map_ws`]: `workers` threads,
/// each owning one long-lived [`DpWorkspace`], parked on a condvar
/// while no epoch has claimable work.
///
/// Normal code never constructs one — [`par_map_ws`] lazily starts the
/// process-wide instance with [`default_threads`] workers.  The type
/// and its [`start`](ComputePool::start) / [`run`](ComputePool::run) /
/// [`shutdown`](ComputePool::shutdown) methods are public so
/// bounded-lifetime harnesses (the loom models in
/// `tests/loom_pool.rs`, sanitizer runs) can model-check the exact
/// shipped scheduler with small worker counts and then join every
/// thread.
pub struct ComputePool {
    state: Mutex<PoolState>,
    /// Signaled when a new epoch arrives, a trim is requested, or the
    /// pool shuts down.
    work_cv: Condvar,
    /// Signaled when an epoch's participant count drops to zero or a
    /// trim is acked.
    done_cv: Condvar,
    workers: usize,
    /// Worker join handles, taken by [`shutdown`](ComputePool::shutdown).
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

static POOL: OnceLock<Arc<ComputePool>> = OnceLock::new();

fn compute_pool() -> &'static Arc<ComputePool> {
    POOL.get_or_init(|| ComputePool::start(default_threads()))
}

/// Release the large one-off scratch (the O(T²) path-backtracking
/// matrix) from the calling thread's TLS workspace and from every pool
/// worker's long-lived workspace.  Call after a bulk learning pass
/// (`sparse::learn`) so long-lived processes don't pin
/// workers × T² × 8 bytes of heap they will never touch again; the
/// steady-state serving buffers (rows, entry arrays, candidate scratch)
/// are left warm.  Blocks until every worker has trimmed; workers busy
/// inside an epoch trim right after their current runner call returns.
pub fn trim_workspaces() {
    workspace::with_tls(|ws| ws.trim());
    // Nested calls run jobs serially on the caller's TLS workspace, so
    // there is nothing more to trim from inside a pool worker.
    if ON_POOL_WORKER.with(|c| c.get()) {
        return;
    }
    // Only touch the pool if something already spun it up.
    if let Some(pool) = POOL.get() {
        pool.trim_all();
    }
}

impl ComputePool {
    /// Start a pool with `workers` worker threads (min 1).
    ///
    /// The process-wide instance is started lazily by [`par_map_ws`];
    /// direct use is for bounded-lifetime harnesses (loom models,
    /// sanitizer tests), which must pair it with
    /// [`shutdown`](ComputePool::shutdown).
    pub fn start(workers: usize) -> Arc<ComputePool> {
        let pool = Arc::new(ComputePool {
            state: Mutex::new(PoolState {
                epochs: Vec::new(),
                next_id: 0,
                peak_epochs: 0,
                trim_gen: 0,
                trim_acks: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            workers: workers.max(1),
            handles: Mutex::new(Vec::new()),
        });
        let mut handles = Vec::with_capacity(pool.workers);
        for idx in 0..pool.workers {
            let p = Arc::clone(&pool);
            handles.push(spawn_named(format!("spdtw-pool-{idx}"), move || {
                p.worker_loop()
            }));
        }
        *lock(&pool.handles) = handles;
        pool
    }

    /// Claimable epoch with the fewest running participants (ties to
    /// the oldest): balances workers across concurrent epochs while
    /// keeping FIFO-ish fairness.
    fn pick(epochs: &[EpochSlot]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in epochs.iter().enumerate() {
            if e.exhausted || e.running >= e.target {
                continue;
            }
            best = match best {
                Some(b) if (epochs[b].running, epochs[b].id) <= (e.running, e.id) => Some(b),
                _ => Some(i),
            };
        }
        best
    }

    fn worker_loop(&self) {
        ON_POOL_WORKER.with(|c| c.set(true));
        // The long-lived workspace: reused across every epoch this
        // worker ever joins, for the lifetime of the pool.
        let mut ws = DpWorkspace::new();
        let mut trim_seen = 0u64;
        loop {
            let (id, task) = {
                let mut st = lock(&self.state);
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.trim_gen != trim_seen {
                        trim_seen = st.trim_gen;
                        ws.trim();
                        st.trim_acks += 1;
                        self.done_cv.notify_all();
                    }
                    if let Some(i) = Self::pick(&st.epochs) {
                        st.epochs[i].running += 1;
                        break (st.epochs[i].id, st.epochs[i].runner);
                    }
                    st = self.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
                }
            };
            // SAFETY: `execute` keeps the runner borrow alive until this
            // epoch's `running` count returns to zero, which cannot
            // happen before the decrement below.
            let runner = unsafe { &*task.0 };
            let _ = catch_unwind(AssertUnwindSafe(|| runner(&mut ws)));
            let mut st = lock(&self.state);
            if let Some(slot) = st.epochs.iter_mut().find(|e| e.id == id) {
                // The runner returned: the epoch's index space is
                // drained (or it panicked) — nobody new may join.
                slot.exhausted = true;
                slot.running -= 1;
                if slot.running == 0 {
                    self.done_cv.notify_all();
                }
            }
        }
    }

    /// Run one epoch to completion: register its slot, wake workers,
    /// participate from the calling thread, then wait for the epoch's
    /// own completion latch.  No cross-epoch lock is held at any point.
    fn execute(&self, threads: usize, runner: &Runner<'_>) {
        // SAFETY: the lifetime is erased only for storage in the slot;
        // this function does not return (and the slot is removed)
        // until every participant has finished running the pointee.
        let ptr: *const Runner<'static> =
            unsafe { std::mem::transmute::<*const Runner<'_>, *const Runner<'static>>(runner) };
        let id = {
            let mut st = lock(&self.state);
            let id = st.next_id;
            st.next_id = st.next_id.wrapping_add(1);
            st.epochs.push(EpochSlot {
                id,
                runner: RunnerPtr(ptr),
                // the submitting thread is participant #1
                running: 1,
                exhausted: false,
                target: threads.max(1),
            });
            st.peak_epochs = st.peak_epochs.max(st.epochs.len());
            self.work_cv.notify_all();
            id
        };
        // Participate: the submitter drains its own epoch alongside the
        // workers, so progress never depends on worker availability.
        // (`with_tls` is re-entrant, handing nested callers a fresh
        // arena.)  The unwind guard keeps the slot bookkeeping sound
        // even if a runner ever leaks a panic.
        let panicked =
            catch_unwind(AssertUnwindSafe(|| workspace::with_tls(|ws| runner(ws)))).err();
        let mut st = lock(&self.state);
        let pos = |st: &PoolState| {
            st.epochs
                .iter()
                .position(|e| e.id == id)
                .expect("live epoch slot")
        };
        {
            let i = pos(&st);
            st.epochs[i].exhausted = true;
            st.epochs[i].running -= 1;
        }
        while st.epochs[pos(&st)].running > 0 {
            st = self.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let i = pos(&st);
        st.epochs.remove(i);
        drop(st);
        if let Some(payload) = panicked {
            resume_unwind(payload);
        }
    }

    /// Bump the trim generation and wait until every worker has trimmed
    /// its workspace (workers mid-epoch trim after their current runner
    /// call returns).
    fn trim_all(&self) {
        let mut st = lock(&self.state);
        st.trim_gen = st.trim_gen.wrapping_add(1);
        st.trim_acks = 0;
        self.work_cv.notify_all();
        while st.trim_acks < self.workers {
            st = self.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Terminally stop the pool: workers exit instead of parking, and
    /// every worker thread is joined before this returns.
    ///
    /// Epochs still live when this is called complete normally (their
    /// participants — including the submitter — drain the index space
    /// before observing the flag).  The process-wide pool never shuts
    /// down; this exists so bounded-lifetime harnesses (loom models,
    /// sanitizer runs, tests) terminate every thread they spawned —
    /// loom in particular requires all model threads to finish.
    pub fn shutdown(&self) {
        {
            let mut st = lock(&self.state);
            st.shutdown = true;
            self.work_cv.notify_all();
        }
        let mut handles = lock(&self.handles);
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Run one `par_map_ws`-shaped epoch on this pool: dynamic
    /// chunk-claiming over `0..n` with at most `threads` simultaneous
    /// participants (the calling thread included), results in index
    /// order.  Bit-identical to `(0..n).map(|i| f(i, ws)).collect()`.
    ///
    /// Public for the same reason as [`start`](ComputePool::start);
    /// normal code calls [`par_map_ws`], which adds the serial
    /// fallbacks and TLS-workspace reuse on top.
    ///
    /// # Panics
    ///
    /// Panics with "pool worker panicked" if any item's `f` panicked
    /// (the epoch aborts early; concurrent epochs are unaffected).
    pub fn run<R, F>(&self, n: usize, threads: usize, chunk: usize, f: &F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &mut DpWorkspace) -> R + Sync,
    {
        assert!(chunk > 0, "chunk must be positive");
        let slots: Vec<UnsafeCell<Option<R>>> = (0..n).map(|_| UnsafeCell::new(None)).collect();
        let out = EpochSlots(&slots);
        let next = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let runner = |ws: &mut DpWorkspace| loop {
            // Fail fast: once any item panicked the epoch's result is a
            // panic regardless, so don't drain the remaining index
            // space just to throw it away.
            if panicked.load(Ordering::Relaxed) {
                return;
            }
            let start = next.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            for i in start..end {
                match catch_unwind(AssertUnwindSafe(|| f(i, ws))) {
                    Ok(v) => out.write(i, v),
                    Err(_) => {
                        panicked.store(true, Ordering::SeqCst);
                        return;
                    }
                }
            }
        };
        self.execute(threads, &runner);
        if panicked.load(Ordering::SeqCst) {
            panic!("pool worker panicked");
        }
        slots
            .iter()
            .map(|slot| {
                // SAFETY: the epoch's completion latch has passed (every
                // participant decremented under the state mutex), so no
                // other thread holds a reference into the slots.
                slot.with_mut(|p| unsafe { (*p).take() })
                    .expect("index not produced")
            })
            .collect()
    }

    /// [`run`](ComputePool::run) over precomputed contiguous spans
    /// (see [`weighted_spans`]): participants claim whole spans from one
    /// atomic counter instead of fixed-size chunks.  `spans` must cover
    /// `0..n` exactly, in order, without overlap — every index is
    /// produced exactly once, results in index order.
    ///
    /// # Panics
    ///
    /// Panics with "pool worker panicked" if any item's `f` panicked
    /// (the epoch aborts early; concurrent epochs are unaffected).
    pub fn run_spans<R, F>(
        &self,
        n: usize,
        threads: usize,
        spans: &[(usize, usize)],
        f: &F,
    ) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &mut DpWorkspace) -> R + Sync,
    {
        debug_assert_eq!(
            spans.iter().map(|&(s, e)| e - s).sum::<usize>(),
            n,
            "spans must cover the index space exactly"
        );
        let slots: Vec<UnsafeCell<Option<R>>> = (0..n).map(|_| UnsafeCell::new(None)).collect();
        let out = EpochSlots(&slots);
        let next = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let runner = |ws: &mut DpWorkspace| loop {
            // Fail fast: once any item panicked the epoch's result is a
            // panic regardless, so don't drain the remaining spans just
            // to throw them away.
            if panicked.load(Ordering::Relaxed) {
                return;
            }
            let si = next.fetch_add(1, Ordering::Relaxed);
            if si >= spans.len() {
                break;
            }
            let (start, end) = spans[si];
            for i in start..end {
                match catch_unwind(AssertUnwindSafe(|| f(i, ws))) {
                    Ok(v) => out.write(i, v),
                    Err(_) => {
                        panicked.store(true, Ordering::SeqCst);
                        return;
                    }
                }
            }
        };
        self.execute(threads, &runner);
        if panicked.load(Ordering::SeqCst) {
            panic!("pool worker panicked");
        }
        slots
            .iter()
            .map(|slot| {
                // SAFETY: the epoch's completion latch has passed (every
                // participant decremented under the state mutex), so no
                // other thread holds a reference into the slots.
                slot.with_mut(|p| unsafe { (*p).take() })
                    .expect("index not produced")
            })
            .collect()
    }
}

// ---------------------------------------------------------------------
// Job-queue worker pool (coordinator execution engine)
// ---------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent worker pool with a bounded job queue.
///
/// Bounded submission gives the coordinator backpressure: `submit` blocks
/// when `capacity` jobs are in flight.  Dropping the pool joins all
/// workers after draining the queue.  Panicking jobs are contained: the
/// inflight slot is released via a drop guard (so `wait_idle` cannot
/// hang) and the worker thread survives to take the next job.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, Condvar)>,
    capacity: usize,
}

/// Releases one inflight slot on drop — even when the job unwinds.
struct InflightSlot<'a>(&'a (Mutex<usize>, Condvar));

impl Drop for InflightSlot<'_> {
    fn drop(&mut self) {
        let (count, cv) = self.0;
        let mut n = lock(count);
        *n -= 1;
        cv.notify_all();
    }
}

impl WorkerPool {
    pub fn new(threads: usize, capacity: usize) -> Self {
        assert!(threads > 0 && capacity > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::spawn(move || loop {
                    let job = {
                        let guard = lock(&rx);
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            let _slot = InflightSlot(&inflight);
                            // Contain the panic: the worker must stay
                            // alive for subsequent jobs, and `_slot`
                            // must still decrement on unwind.
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                        Err(_) => break, // channel closed: shut down
                    }
                })
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            handles,
            inflight,
            capacity,
        }
    }

    /// Submit a job, blocking while the queue is at capacity
    /// (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let (count, cv) = &*self.inflight;
        {
            let mut n = lock(count);
            while *n >= self.capacity {
                n = cv.wait(n).unwrap_or_else(|e| e.into_inner());
            }
            *n += 1;
        }
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn inflight(&self) -> usize {
        *lock(&self.inflight.0)
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (count, cv) = &*self.inflight;
        let mut n = lock(count);
        while *n > 0 {
            n = cv.wait(n).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers drain & exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<u64> = (0..257).map(|i| (i as u64) * 3 + 1).collect();
        let parallel = par_map(257, 4, |i| (i as u64) * 3 + 1);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_chunked_matches_serial() {
        let parallel = par_map_chunked(1000, 8, 13, |i| i * i);
        assert_eq!(parallel, (0..1000).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn par_map_ws_hands_out_reusable_workspaces() {
        let out = par_map_ws(100, 4, 3, |i, ws| {
            let (prev, _cur) = ws.rows(8, 0.5);
            prev[0] + i as f64
        });
        let want: Vec<f64> = (0..100).map(|i| 0.5 + i as f64).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn nested_par_map_from_pool_job_does_not_deadlock() {
        let out = par_map(8, 4, |i| {
            // on a pool worker the nested call runs serially on that
            // worker's TLS workspace; on the participating submitter it
            // becomes a (completing) sub-epoch — neither may deadlock
            par_map_ws(4, 4, 1, |j, ws| {
                let (row, _) = ws.rows(2, 0.0);
                row[0] as usize + i * 10 + j
            })
            .iter()
            .sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|i| 4 * (i * 10) + 6).collect();
        assert_eq!(out, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock rendezvous loops are too slow under Miri
    fn concurrent_epochs_overlap_without_submit_lock() {
        // Two epochs submitted from distinct threads rendezvous *inside*
        // their job bodies: epoch A's items block until epoch B has
        // started running and vice versa.  Under the old global submit
        // lock this times out (B cannot start until A finishes); under
        // the concurrent-epoch scheduler both complete.
        let flag_a = Arc::new(AtomicBool::new(false));
        let flag_b = Arc::new(AtomicBool::new(false));
        let wait_for = |flag: &AtomicBool| {
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
            while !flag.load(Ordering::SeqCst) {
                assert!(
                    std::time::Instant::now() < deadline,
                    "epochs did not overlap: global submit serialization is back"
                );
                thread::sleep(std::time::Duration::from_millis(1));
            }
        };
        let (fa, fb) = (Arc::clone(&flag_a), Arc::clone(&flag_b));
        let ta = thread::spawn(move || {
            par_map(2, 2, move |i| {
                fa.store(true, Ordering::SeqCst);
                wait_for(&fb);
                i * 2
            })
        });
        let (fa, fb) = (flag_a, flag_b);
        let tb = thread::spawn(move || {
            par_map(2, 2, move |i| {
                fb.store(true, Ordering::SeqCst);
                wait_for(&fa);
                i * 3
            })
        });
        assert_eq!(ta.join().unwrap(), vec![0, 2]);
        assert_eq!(tb.join().unwrap(), vec![0, 3]);
        assert!(
            pool_stats().peak_concurrent_epochs >= 2,
            "scheduler never held two live epochs"
        );
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn par_map_propagates_job_panics() {
        par_map(64, 4, |i| {
            if i == 33 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn pool_survives_a_panicked_epoch() {
        let poisoned = catch_unwind(AssertUnwindSafe(|| {
            par_map(16, 4, |i| {
                if i == 7 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(poisoned.is_err());
        // the persistent pool must still serve subsequent epochs
        assert_eq!(par_map(16, 4, |i| i * 2), (0..16).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn dedicated_pool_runs_epochs_and_shuts_down() {
        // The bounded-lifetime path the loom models use: a private pool,
        // a few epochs, then shutdown joins every worker.
        let pool = ComputePool::start(2);
        let out = pool.run(9, 3, 2, &|i, _ws: &mut DpWorkspace| i * 7);
        assert_eq!(out, (0..9).map(|i| i * 7).collect::<Vec<_>>());
        let again = pool.run(3, 2, 1, &|i, _ws: &mut DpWorkspace| i + 1);
        assert_eq!(again, vec![1, 2, 3]);
        pool.shutdown();
        // shutdown is idempotent (handles already drained)
        pool.shutdown();
    }

    #[test]
    fn trim_workspaces_leaves_pool_functional() {
        let a = par_map_ws(64, 4, 1, |i, ws| {
            ws.matrix.resize(1024, 0.0); // simulate a learn pass
            i + 1
        });
        trim_workspaces();
        let b = par_map(64, 4, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_stats_observes_started_pool() {
        // spin the pool up, then snapshot it (other tests may be running
        // their own epochs concurrently, so only monotone facts are
        // asserted here)
        par_map(8, 2, |i| i);
        let s = pool_stats();
        assert!(s.workers >= 1);
        assert!(s.peak_concurrent_epochs >= 1);
    }

    #[test]
    fn weighted_spans_cover_exactly_and_balance() {
        let mut rng_state = 0x9e3779b97f4a7c15u64;
        let mut next = || {
            rng_state = rng_state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (rng_state >> 33) as usize
        };
        for n in [1usize, 2, 7, 100, 1000] {
            for threads in [1usize, 2, 8] {
                let weights: Vec<usize> = (0..n).map(|_| next() % 500).collect();
                let spans = weighted_spans(&weights, threads);
                // exact, ordered, gapless coverage of 0..n
                let mut at = 0usize;
                for &(s, e) in &spans {
                    assert_eq!(s, at, "gap or overlap at {s}");
                    assert!(e > s, "empty span");
                    at = e;
                }
                assert_eq!(at, n);
                // each span's weight stays near the target (one item of
                // overshoot allowed — spans close on the crossing item)
                let total: usize = weights.iter().map(|&w| w.max(1)).sum();
                let target = (total / (threads * 4)).max(1);
                let wmax = weights.iter().map(|&w| w.max(1)).max().unwrap();
                for &(s, e) in &spans {
                    let w: usize = weights[s..e].iter().map(|&w| w.max(1)).sum();
                    assert!(w <= target + wmax, "span weight {w} way past target {target}");
                }
            }
        }
        assert!(weighted_spans(&[], 4).is_empty());
    }

    #[test]
    fn par_map_ws_weighted_matches_serial_under_skew() {
        // heavily skewed weights: the schedule changes, the values must not
        let n = 300;
        let weights: Vec<usize> = (0..n).map(|i| if i % 17 == 0 { 10_000 } else { 1 }).collect();
        let out = par_map_ws_weighted(n, 4, &weights, |i, ws| {
            let (row, _) = ws.rows(4, i as f64);
            row[0] * 2.0
        });
        let want: Vec<f64> = (0..n).map(|i| i as f64 * 2.0).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn par_map_ws_weighted_empty_single_and_zero_weights() {
        assert!(par_map_ws_weighted(0, 4, &[], |i, _ws| i).is_empty());
        assert_eq!(par_map_ws_weighted(1, 4, &[0], |i, _ws| i + 9), vec![9]);
        // all-zero weights still cover every index
        let zeros = vec![0usize; 50];
        let out = par_map_ws_weighted(50, 3, &zeros, |i, _ws| i);
        assert_eq!(out, (0..50).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn par_map_ws_weighted_propagates_job_panics() {
        let weights = vec![1usize; 64];
        par_map_ws_weighted(64, 4, &weights, |i, _ws| {
            if i == 21 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn worker_pool_runs_everything_once() {
        let pool = WorkerPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn worker_pool_backpressure_bounds_inflight() {
        let pool = WorkerPool::new(1, 2);
        for _ in 0..10 {
            pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(1)));
            assert!(pool.inflight() <= 2);
        }
        pool.wait_idle();
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn worker_pool_survives_panicking_jobs() {
        // Regression: a panicking job used to unwind past the inflight
        // decrement, killing the worker and hanging wait_idle forever.
        let pool = WorkerPool::new(2, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..20 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                if i % 5 == 0 {
                    panic!("job blew up");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // pre-fix: hung
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert_eq!(pool.inflight(), 0);
        // workers are still alive and accept new jobs
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 17);
    }
}
