//! TCP line-protocol server exposing the coordinator: one JSON object
//! per line in, one JSON object per line out.  Used by the serving demo
//! (`examples/serve_pjrt.rs`) and the runtime integration tests.
//!
//! ## Protocol v2 (envelope)
//!
//! A request carrying `"proto": 2` opts into the versioned envelope:
//!
//! ```json
//! {"proto":2, "id":"req-7", "op":"dist",
//!  "measure":{"kind":"sakoe_chiba","band_pct":10}, "x":[...], "y":[...]}
//! ```
//!
//! * `proto` — protocol version.  Absent or `1` = the legacy bare-op
//!   protocol below; `2` = this envelope; anything else is rejected
//!   with code `unsupported_proto`.
//! * `id` — optional, any JSON value; echoed verbatim in the reply
//!   (success or error), so pipelined clients can match responses.
//! * typed error codes — every error reply is
//!   `{"ok":false,"error":"<human message>","code":"<machine code>"}`.
//!   The code table lives on [`crate::error::Error::code`] (`bad_json`,
//!   `bad_request`, `bad_input`, `unknown_op`, `not_found`,
//!   `unavailable`, `deadline_exceeded`, `internal`), plus one
//!   wire-only code synthesized here in dispatch: `unsupported_proto`
//!   for a `proto` other than 1/2.
//! * `deadline_ms` — optional on any op (both protocol versions): an
//!   integer millisecond budget, 1 ..= 86_400_000.  The request is
//!   bounded end to end — checked before dispatch, again when the
//!   compute pool claims the epoch, and as the bound on the blocking
//!   wait — and exhaustion answers with the typed `deadline_exceeded`
//!   code instead of blocking on.  A front forwards the *remaining*
//!   budget to every shard leg it fans out.
//!
//! The generic v2 ops reach **every measure in the family** through one
//! serializable `measure` object (see `measures::spec` for the JSON
//! shape) or a key previously returned by `register_measure`:
//!
//! ```json
//! {"proto":2,"op":"register_measure","measure":{"kind":"krdtw","nu":0.5}}
//!     // -> {"ok":true,"measure":0,"kernel":true,"name":"Krdtw"}
//! {"proto":2,"op":"dist","measure":{"kind":"dtw"},"x":[...],"y":[...]}
//! {"proto":2,"op":"dist","measure":0,"x":[...],"y":[...]}
//!     // -> {"ok":true,"value":...,"cells":...,"backend":"native"|"pjrt"}
//! {"proto":2,"op":"kernel","measure":{"kind":"kga","nu":0.5},"x":[...],"y":[...]}
//!     // -> {"ok":true,"log_k":...,"cells":...,"backend":...}
//! ```
//!
//! `dist` on a kernel measure returns the normalized-kernel distance;
//! `kernel` on a distance measure is a `bad_request`.  SP measures over
//! a `{"kind":"registered","key":G}` grid keep the PJRT batch routing
//! of the dedicated v1 ops.  v2 `register_index` additionally accepts
//! `"measure"` (a searchable spec: `dtw`, `banded_dtw`, `sakoe_chiba`,
//! or `spdtw`) in place of the v1 `"band"` parameter; when a *named*
//! registration is served from the registry without a rebuild, the
//! reply's `measure_drift` flag says whether the stored index actually
//! evaluates the requested measure family (the payload `content_hash`
//! cannot detect that kind of mismatch).
//!
//! Series values must be finite: any NaN/±inf in `x`, `y`, `series` or
//! `xs` is rejected with code `bad_input` before it can reach a DP
//! kernel (on both protocol versions).
//!
//! ## Protocol v1 (bare ops, served verbatim)
//!
//! Requests without `proto` keep answering exactly as before:
//!
//! ```json
//! {"op":"ping"}
//! {"op":"info"}
//! {"op":"register_grid","t":60,"band":5}            // corridor grid
//! {"op":"spdtw","grid":0,"x":[...],"y":[...]}
//! {"op":"spkrdtw","grid":0,"nu":0.5,"x":[...],"y":[...]}
//! {"op":"register_index","band":5,"series":[[...],...],"labels":[...]}
//!     // optional "name":"cbf" — resolves against the registry first
//!     // (warm-started indexes answer without a rebuild; the reply's
//!     // "loaded_from_disk" says which path served it) and persists
//!     // the build when the coordinator has an index store.  The reply
//!     // always carries "content_hash" (FNV-1a-64 of the registered
//!     // index's payload, hex) and "drift": true when a known name was
//!     // served from the registry but the submitted series/labels hash
//!     // differently than the stored index — the client's signal that
//!     // it would be searching stale data.
//! {"op":"search","index":0,"k":3,"x":[...]}         // optional "cascade":"none"
//! {"op":"batch_search","index":0,"k":3,"xs":[[...],...]}
//!     // one concurrent-epoch request: the whole batch runs as its own
//!     // pool epoch, overlapping with other clients' requests
//! {"op":"metrics"}
//! {"op":"shutdown"}
//! ```
//!
//! Every v1 op is also valid inside a v2 envelope; the per-measure v1
//! ops (`spdtw`, `spkrdtw`) are kept as thin compatibility wrappers
//! over the same submit paths the generic `dist`/`kernel` ops use.
//! The `code` field on error replies and the `id` echo are additive —
//! v1 clients that ignore unknown fields see identical behavior
//! (golden-tested in `rust/tests/integration_protocol.rs`).
//!
//! ## Sharding
//!
//! A server started with a [`ShardRole`](crate::config::ShardRole)
//! (`spdtw shard-serve --shard-id I --shards-total N`) owns one slice
//! of a logical index and additionally serves the fan-out ops below;
//! the topology diagram lives on [`crate::shard`].  The front
//! (`spdtw serve --shards host:port,...`) is the only intended client
//! of these ops, multiplexing any number of in-flight v2 `id`s per
//! connection.
//!
//! | op | extra request fields | reply |
//! |---|---|---|
//! | `info` | — | gains `shard_id`, `shards_total` on shard servers |
//! | `register_index` | `shard` (must equal this server's shard id), `global_ids` (strictly increasing, one per series; names rejected) | gains `shard` |
//! | `shard_search` | `shard`, `index`, `k`, `x` *or* `xs`, optional `cascade` | `neighbors` with `idx` remapped to global index space (`local_idx` keeps the shard-local position) |
//!
//! A `shard` id outside the server's layout — wrong id or `>=
//! shards_total` — is rejected with code `bad_request` before anything
//! is registered or searched, so a mis-routed fan-out can never be
//! silently accepted.  Partial-result semantics live on the front: when
//! a shard stays down after a capped-backoff reconnect, the front's
//! reply is the typed `unavailable` error carrying
//! `shards_ok`/`shards_total` — exact merged results or a typed error,
//! never a silently truncated neighbor list.  The front's `search` /
//! `batch_search` additionally accept `allow_partial: true` to opt into
//! the exact merge over responsive shards; such replies carry a typed
//! `partial: {shards_ok, shards_total, missing}` block (see
//! [`crate::shard::front`]).
//!
//! ## Streaming
//!
//! The `stream_*` op family serves online subsequence k-NN (see
//! [`crate::stream`]): a session pins a [`crate::stream::StreamMonitor`]
//! over a registered index, samples are pushed incrementally, and every
//! completed sliding window is searched with the full exact cascade —
//! per-window results are bit-identical to a batch `search` over the
//! same window.  Passing an `rws` object on open switches the session
//! to the flagged approximate pre-filter (Random Warping Series); the
//! reply's `approx` flag and the audited `recall_at_k` keep the
//! approximation observable, never silent.
//!
//! | op | extra request fields | reply |
//! |---|---|---|
//! | `stream_open` | `index`, optional `k` (default 1), `cascade`, `rws` `{d, len, candidates, seed, audit_every}`, `idle_timeout_ms` | `stream` (session id), `t` (window length), `approx` |
//! | `stream_push` | `stream`, `values` (all-finite, rejected whole otherwise), optional `deadline_ms` | `pushed`, `windows` (completed this push), `ready` |
//! | `stream_matches` | `stream` | `ready`, `approx`, `samples`, `windows`; once ready: `window_start`, `neighbors`, `pruned`, `full_evals`, `dp_cells`, per-window `recall` on audited windows; session-mean `recall_at_k` when audits ran |
//! | `stream_close` | `stream` | `closed`, final `samples`/`windows`, `recall_at_k` when audits ran |
//!
//! Sessions are capped ([`MAX_STREAM_SESSIONS`](super::MAX_STREAM_SESSIONS))
//! and carry an idle budget (default
//! [`DEFAULT_STREAM_IDLE_MS`](super::DEFAULT_STREAM_IDLE_MS)): any
//! `stream_*` call lazily sweeps expired sessions, whose keys then
//! answer with the typed `not_found` code.  A `deadline_ms` on
//! `stream_push` is re-checked between samples; expiry keeps the
//! already-ingested prefix and answers `deadline_exceeded`.
//!
//! ## Fault injection (chaos testing)
//!
//! [`Server::start_with_faults`] serves the identical protocol through
//! a deterministic [`FaultHook`](crate::shard::fault::FaultHook)
//! consulted at the I/O boundary: accepted connections can be refused
//! or capped to N replies, and individual replies delayed, garbled or
//! cut mid-line — the failure modes the front's breaker/partial
//! machinery must absorb.  `spdtw shard-serve --fault-plan plan.json`
//! wires it up; production servers use [`Server::start`], which
//! monomorphizes the hook to the no-op [`NoFaults`] (zero dispatch
//! cost).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use crate::coordinator::request::Deadline;
use crate::coordinator::state::{GridKey, IndexKey, MeasureKey, StreamKey};
use crate::coordinator::Coordinator;
use crate::data::{LabeledSet, TimeSeries};
use crate::error::Result;
use crate::measures::spec::{GridSpec, MeasureSpec};
use crate::search::index::content_hash_of;
use crate::search::{Cascade, Index, Neighbor};
use crate::shard::fault::{ConnectFault, FaultHook, NoFaults, ReplyFault};
use crate::sparse::LocMatrix;
use crate::stream::RwsConfig;
use crate::util::json::Json;

/// A running server; dropping stops accepting (existing connections
/// finish their in-flight line).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn start(coordinator: Arc<Coordinator>, addr: &str) -> Result<Server> {
        Server::start_with_faults(coordinator, addr, Arc::new(NoFaults))
    }

    /// [`Server::start`] with a deterministic fault hook at the I/O
    /// boundary — the chaos-testing entry behind `spdtw shard-serve
    /// --fault-plan`.  Connect-class faults act on accepted
    /// connections (refuse = drop the socket before any reply; close
    /// -after = serve N replies then sever); reply-class faults act per
    /// reply (delay / garble / drop mid-line).  The hook's shard id is
    /// this server's [`ShardRole`](crate::config::ShardRole) id (0 on a
    /// non-shard server).
    pub fn start_with_faults<F: FaultHook>(
        coordinator: Arc<Coordinator>,
        addr: &str,
        faults: Arc<F>,
    ) -> Result<Server> {
        let shard = coordinator.shard_role().map(|r| r.shard_id).unwrap_or(0);
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = thread::Builder::new()
            .name("spdtw-server".into())
            .spawn(move || {
                // Connection threads are detached: joining them here would
                // deadlock `stop()` against clients that keep their socket
                // open (they hold only an Arc<Coordinator> and exit when
                // the peer disconnects or the stop flag is observed).
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // connect-class fault window: refusing here
                            // (after accept) is how a userspace server
                            // can model connection-refused determinis-
                            // tically — the peer sees an immediate EOF
                            let max_replies = match faults.connect_fault(shard) {
                                ConnectFault::Refuse => {
                                    drop(stream);
                                    continue;
                                }
                                ConnectFault::CloseAfterReplies(n) => n,
                                ConnectFault::None => u64::MAX,
                            };
                            let coord = Arc::clone(&coordinator);
                            let stop3 = Arc::clone(&stop2);
                            let hook = Arc::clone(&faults);
                            thread::spawn(move || {
                                let _ = handle_conn(
                                    stream,
                                    &coord,
                                    &stop3,
                                    hook.as_ref(),
                                    shard,
                                    max_replies,
                                );
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Whether the stop flag has fired (the TCP `shutdown` op or
    /// [`Self::stop`]) — lets a CLI serve loop exit cleanly instead of
    /// sleeping forever.
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn<F: FaultHook>(
    stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
    faults: &F,
    shard: usize,
    max_replies: u64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut replies = 0u64;
    for line in reader.lines() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = dispatch(&line, coord, stop);
        let text = reply.to_string();
        match faults.reply_fault(shard) {
            ReplyFault::None => {}
            ReplyFault::Delay(d) => thread::sleep(d),
            ReplyFault::Garble => {
                // a syntactically invalid line: the peer must treat the
                // connection as poisoned, never skip-and-resync
                writer.write_all(b"{\"garbled\" <<injected fault>>\n")?;
                writer.flush()?;
                replies += 1;
                if replies >= max_replies {
                    break;
                }
                continue;
            }
            ReplyFault::DropConnection => {
                // sever mid-reply: flush a prefix of the real bytes so
                // the peer observes a torn line, then hang up
                let half = text.len() / 2;
                writer.write_all(&text.as_bytes()[..half])?;
                writer.flush()?;
                return Ok(());
            }
        }
        writer.write_all(text.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        replies += 1;
        if replies >= max_replies {
            break;
        }
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

pub(crate) fn parse_cascade(req: &Json) -> Result<Cascade> {
    match req.get("cascade").and_then(Json::as_str) {
        Some("none") => Ok(Cascade::none()),
        Some("full") | None => Ok(Cascade::default()),
        Some(other) => Err(crate::error::Error::config(format!(
            "unknown cascade '{other}' (expected 'full' or 'none')"
        ))),
    }
}

fn neighbors_json(out: &crate::coordinator::request::SearchOutcome) -> Json {
    neighbors_json_slice(&out.neighbors)
}

/// The shared neighbor-list shape; streaming window reports carry raw
/// neighbors rather than a ticket outcome, so the slice form is the
/// common denominator.
fn neighbors_json_slice(neighbors: &[Neighbor]) -> Json {
    Json::arr(neighbors.iter().map(|n| {
        Json::obj(vec![
            ("dist", Json::num(n.dist)),
            ("label", Json::num(n.label as f64)),
            ("idx", Json::num(n.train_idx as f64)),
        ])
    }))
}

/// Like [`neighbors_json`] but with `idx` remapped to the global index
/// space through the shard's registered `global_ids`; `local_idx`
/// keeps the shard-local position for debugging.
fn neighbors_json_global(
    out: &crate::coordinator::request::SearchOutcome,
    global_ids: &[usize],
) -> Json {
    Json::arr(out.neighbors.iter().map(|n| {
        Json::obj(vec![
            ("dist", Json::num(n.dist)),
            ("label", Json::num(n.label as f64)),
            ("idx", Json::num(global_ids[n.train_idx] as f64)),
            ("local_idx", Json::num(n.train_idx as f64)),
        ])
    }))
}

fn parse_series(json: &Json, field: &str) -> Result<TimeSeries> {
    let arr = json.req_arr(field)?;
    let values: Option<Vec<f64>> = arr.iter().map(Json::as_f64).collect();
    let values = values
        .ok_or_else(|| crate::error::Error::config(format!("'{field}' must be numbers")))?;
    check_finite(&values, field)?;
    Ok(TimeSeries::new(0, values))
}

/// NaN/±inf values would flow straight into the DP kernels (and poison
/// every distance they touch); reject them at the wire with the typed
/// `bad_input` class instead.
pub(crate) fn check_finite(values: &[f64], field: &str) -> Result<()> {
    if values.iter().all(|v| v.is_finite()) {
        Ok(())
    } else {
        Err(crate::error::Error::data(format!(
            "'{field}' contains non-finite values (NaN/inf are not valid series values)"
        )))
    }
}

/// The optional `deadline_ms` request field: an integer millisecond
/// budget, 1 ..= 86_400_000 (24 h).  Anything else — non-numeric,
/// fractional, zero, negative, non-finite or absurdly large — is a
/// typed `bad_request`, never silently clamped: a client that mistyped
/// its budget must not get an effectively unbounded (or instantly
/// expiring) request.  Shared by the single-server dispatch and the
/// shard front.
pub(crate) fn parse_deadline(req: &Json) -> Result<Option<Deadline>> {
    const MAX_DEADLINE_MS: f64 = 86_400_000.0;
    match req.get("deadline_ms") {
        None => Ok(None),
        Some(v) => {
            let ms = v
                .as_f64()
                .filter(|m| m.is_finite() && m.fract() == 0.0 && *m >= 1.0 && *m <= MAX_DEADLINE_MS)
                .ok_or_else(|| {
                    crate::error::Error::config(
                        "'deadline_ms' must be an integer between 1 and 86400000",
                    )
                })?;
            Ok(Some(Deadline::in_ms(ms as u64)))
        }
    }
}

/// The optional `rws` parameter on `stream_open`: absent = the exact
/// streaming default; an object opts the session into the approximate
/// RWS pre-filter, with any omitted knob taking its
/// [`RwsConfig::default`] value.  Validation of the resulting config
/// (non-zero `d`/`candidates`) happens in the monitor constructor, so
/// the wire and the library agree on what is rejected.
fn parse_rws(req: &Json) -> Result<Option<RwsConfig>> {
    let obj = match req.get("rws") {
        None => return Ok(None),
        Some(o @ Json::Obj(_)) => o,
        Some(_) => {
            return Err(crate::error::Error::config(
                "'rws' must be an object ({d, len, candidates, seed, audit_every})",
            ))
        }
    };
    let get_usize = |name: &'static str| -> Result<Option<usize>> {
        match obj.get(name) {
            None => Ok(None),
            Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                crate::error::Error::config(format!(
                    "'rws.{name}' must be a non-negative integer"
                ))
            }),
        }
    };
    let mut cfg = RwsConfig::default();
    if let Some(d) = get_usize("d")? {
        cfg.d = d;
    }
    if let Some(len) = get_usize("len")? {
        cfg.len = len;
    }
    if let Some(c) = get_usize("candidates")? {
        cfg.candidates = c;
    }
    if let Some(s) = get_usize("seed")? {
        cfg.seed = s as u64;
    }
    if let Some(a) = get_usize("audit_every")? {
        cfg.audit_every = a as u64;
    }
    Ok(Some(cfg))
}

/// The v2 `measure` parameter: an inline spec object or a key returned
/// by `register_measure`.
enum MeasureSel {
    Spec(MeasureSpec),
    Key(MeasureKey),
}

fn parse_measure_sel(req: &Json) -> Result<MeasureSel> {
    match req.get("measure") {
        Some(obj @ Json::Obj(_)) => Ok(MeasureSel::Spec(MeasureSpec::from_json(obj)?)),
        Some(Json::Num(_)) => Ok(MeasureSel::Key(MeasureKey(req.req_usize("measure")? as u64))),
        _ => Err(crate::error::Error::config(
            "missing 'measure' (a spec object or a register_measure key)",
        )),
    }
}

/// Build an error reply: `{"ok":false,"error":...,"code":...}` plus the
/// echoed `id` when the request carried one.  The typed partial-result
/// error additionally carries `shards_ok`/`shards_total` so a client
/// can tell a degraded fleet from a plain outage.
pub(crate) fn error_reply(e: &crate::error::Error, id: Option<&Json>) -> Json {
    let mut reply = Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(e.to_string())),
        ("code", Json::str(e.code())),
    ]);
    if let crate::error::Error::ShardUnavailable {
        shards_ok,
        shards_total,
        ..
    } = e
    {
        if let Json::Obj(fields) = &mut reply {
            fields.insert("shards_ok".to_string(), Json::num(*shards_ok as f64));
            fields.insert("shards_total".to_string(), Json::num(*shards_total as f64));
        }
    }
    // deadline_exceeded replies carry the original budget so a front
    // relaying a shard's expiry can surface the same typed error
    if let crate::error::Error::DeadlineExceeded { budget_ms } = e {
        if let Json::Obj(fields) = &mut reply {
            fields.insert("budget_ms".to_string(), Json::num(*budget_ms as f64));
        }
    }
    attach_id(&mut reply, id);
    reply
}

pub(crate) fn attach_id(reply: &mut Json, id: Option<&Json>) {
    if let (Json::Obj(fields), Some(id)) = (reply, id) {
        fields.insert("id".to_string(), id.clone());
    }
}

/// Parse one request line and serve it, on either protocol version.
/// Always produces a reply object — malformed lines get a typed error
/// reply, never a disconnect.
fn dispatch(line: &str, coord: &Coordinator, stop: &AtomicBool) -> Json {
    let req = match Json::parse(line) {
        Ok(r) => r,
        Err(e) => return error_reply(&e, None),
    };
    let id = req.get("id").cloned();
    match req.get("proto").map(|p| (p.as_usize(), p)) {
        None => {}
        Some((Some(1), _)) => {}
        Some((Some(2), _)) => coord.note_v2_request(),
        Some((_, p)) => {
            let shown = p.to_string();
            let mut reply = Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::str(format!(
                        "unsupported protocol version {shown} (this server speaks 1 and 2)"
                    )),
                ),
                ("code", Json::str("unsupported_proto")),
            ]);
            attach_id(&mut reply, id.as_ref());
            return reply;
        }
    }
    let mut reply = match handle_op(&req, coord, stop) {
        Ok(json) => json,
        Err(e) => {
            if matches!(e, crate::error::Error::DeadlineExceeded { .. }) {
                coord.note_deadline_exceeded();
            }
            return error_reply(&e, id.as_ref());
        }
    };
    attach_id(&mut reply, id.as_ref());
    reply
}

/// Serve one protocol line against a coordinator with no socket in the
/// way — byte-identical dispatch to what a TCP connection performs
/// (same parser, same envelope handling, same typed error replies).
///
/// This is the transport-free entry the correctness tooling drives:
/// the `fuzz_wire` fuzz target feeds it arbitrary lines, and the
/// malformed-envelope matrix in `tests/integration_protocol.rs` (which
/// also runs under Miri, where TCP is unavailable) asserts stable v2
/// error codes through it.  A `shutdown` op is answered `ok` but only
/// sets a throwaway flag — there is no serve loop to stop.
pub fn dispatch_line(line: &str, coord: &Coordinator) -> Json {
    let stop = AtomicBool::new(false);
    dispatch(line, coord, &stop)
}

fn handle_op(req: &Json, coord: &Coordinator, stop: &AtomicBool) -> Result<Json> {
    let op = req.req_str("op")?;
    // Pre-dispatch deadline check: a request that arrives already past
    // its budget is rejected before any parsing or compute.
    let deadline = parse_deadline(req)?;
    if let Some(d) = deadline {
        if d.expired() {
            return Err(d.error());
        }
    }
    match op {
        "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])),
        "info" => {
            let snap = coord.metrics();
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("workers", Json::num(coord.config().workers as f64)),
                ("batch_size", Json::num(coord.config().batch_size as f64)),
                ("prefer_pjrt", Json::Bool(coord.config().prefer_pjrt)),
                ("completed", Json::num(snap.completed as f64)),
            ];
            // the shard front verifies fleet topology against these
            if let Some(role) = coord.shard_role() {
                fields.push(("shard_id", Json::num(role.shard_id as f64)));
                fields.push(("shards_total", Json::num(role.shards_total as f64)));
            }
            Ok(Json::obj(fields))
        }
        "register_grid" => {
            let t = req.req_usize("t")?;
            // Route the size check through the same inline-grid cap as
            // the v2 spec path: a wire-supplied `t` must not materialize
            // an arbitrarily large LOC matrix (`full(t)` is O(t²) cells
            // — a fuzz_wire-shaped allocation DoS before this check).
            let spec = match req.get("band").and_then(Json::as_usize) {
                Some(band) => GridSpec::Corridor { t, band },
                None => GridSpec::Full { t },
            };
            spec.validate()?;
            let loc = match spec {
                GridSpec::Corridor { t, band } => LocMatrix::corridor(t, band),
                _ => LocMatrix::full(t),
            };
            let key = coord.register_grid(loc)?;
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("grid", Json::num(key.0 as f64))]))
        }
        "spdtw" => {
            let key = GridKey(req.req_usize("grid")? as u64);
            let x = parse_series(req, "x")?;
            let y = parse_series(req, "y")?;
            let r = coord.submit_spdtw(key, &x, &y)?;
            coord.flush();
            let out = r.wait_deadline(deadline)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("value", Json::num(out.value)),
                ("cells", Json::num(out.visited_cells as f64)),
                ("backend", Json::str(out.backend.as_str())),
            ]))
        }
        "spkrdtw" => {
            let key = GridKey(req.req_usize("grid")? as u64);
            let nu = req.req_f64("nu")?;
            let x = parse_series(req, "x")?;
            let y = parse_series(req, "y")?;
            let r = coord.submit_spkrdtw(key, nu, &x, &y)?;
            coord.flush();
            let out = r.wait_deadline(deadline)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("log_k", Json::num(out.value)),
                ("cells", Json::num(out.visited_cells as f64)),
                ("backend", Json::str(out.backend.as_str())),
            ]))
        }
        "register_index" => {
            let name = req.get("name").and_then(Json::as_str);
            if let Some(name) = name {
                // reject bad names before any parsing or O(n·T) build
                super::validate_index_name(name)?;
            }
            // Sharded registrations (issued by a shard front) carry the
            // target shard id and the global-index map.  A shard id
            // outside this server's layout — wrong id, or no role at
            // all — is a typed bad_request *before* anything is parsed
            // or built: accepting it would mis-route every later
            // shard_search.
            let shard = match req.get("shard") {
                None => None,
                Some(v) => Some(v.as_usize().ok_or_else(|| {
                    crate::error::Error::config("'shard' must be a non-negative integer")
                })?),
            };
            if let Some(sid) = shard {
                let role = coord.shard_role().ok_or_else(|| {
                    crate::error::Error::config(
                        "sharded registration on a non-shard server \
                         (start it with `spdtw shard-serve`)",
                    )
                })?;
                if sid >= role.shards_total {
                    return Err(crate::error::Error::config(format!(
                        "shard id {sid} outside the layout (shards_total {})",
                        role.shards_total
                    )));
                }
                if sid != role.shard_id {
                    return Err(crate::error::Error::config(format!(
                        "shard id {sid} mis-routed: this server is shard {} of {}",
                        role.shard_id, role.shards_total
                    )));
                }
                if name.is_some() {
                    return Err(crate::error::Error::config(
                        "sharded registrations are anonymous (the front owns \
                         naming via the shard manifest)",
                    ));
                }
                if req.get("global_ids").is_none() {
                    return Err(crate::error::Error::config(
                        "sharded registration requires 'global_ids'",
                    ));
                }
            } else if req.get("global_ids").is_some() {
                return Err(crate::error::Error::config(
                    "'global_ids' requires 'shard'",
                ));
            }
            // parse + validate the optional v2 measure spec up front so
            // an invalid spec is rejected even on the named shortcut
            let mspec = match req.get("measure") {
                Some(mjson) => Some(MeasureSpec::from_json(mjson)?),
                None => None,
            };
            let band = req.get("band").and_then(Json::as_usize).unwrap_or(usize::MAX);
            let arr = req.req_arr("series")?;
            if arr.is_empty() {
                return Err(crate::error::Error::config("'series' must be non-empty"));
            }
            let labels: Vec<usize> = match req.get("labels").and_then(Json::as_arr) {
                Some(ls) => {
                    let parsed: Option<Vec<usize>> = ls.iter().map(Json::as_usize).collect();
                    parsed.ok_or_else(|| {
                        crate::error::Error::config(
                            "'labels' must be non-negative integers",
                        )
                    })?
                }
                None => vec![0; arr.len()],
            };
            if labels.len() != arr.len() {
                return Err(crate::error::Error::config(
                    "'labels' length must match 'series'",
                ));
            }
            let mut series = Vec::with_capacity(arr.len());
            for (i, row) in arr.iter().enumerate() {
                let vals: Option<Vec<f64>> = row
                    .as_arr()
                    .map(|r| r.iter().map(Json::as_f64).collect())
                    .unwrap_or(None);
                let vals = vals.ok_or_else(|| {
                    crate::error::Error::config("'series' must be arrays of numbers")
                })?;
                check_finite(&vals, "series")?;
                series.push(TimeSeries::new(labels[i], vals));
            }
            let t0 = series[0].len();
            if t0 == 0 || series.iter().any(|s| s.len() != t0) {
                return Err(crate::error::Error::config(
                    "'series' must be equal-length and non-empty",
                ));
            }
            // Strictly increasing global ids make the engine's local
            // tie-break equal the global one — the exactness
            // precondition for the front's merge (see crate::shard).
            let global_ids: Option<Vec<usize>> = match req.get("global_ids") {
                None => None,
                Some(v) => {
                    let arr = v.as_arr().ok_or_else(|| {
                        crate::error::Error::config("'global_ids' must be an array")
                    })?;
                    let parsed: Option<Vec<usize>> = arr.iter().map(Json::as_usize).collect();
                    let ids = parsed.ok_or_else(|| {
                        crate::error::Error::config(
                            "'global_ids' must be non-negative integers",
                        )
                    })?;
                    if ids.len() != series.len() {
                        return Err(crate::error::Error::config(
                            "'global_ids' length must match 'series'",
                        ));
                    }
                    if ids.windows(2).any(|w| w[0] >= w[1]) {
                        return Err(crate::error::Error::config(
                            "'global_ids' must be strictly increasing (per-shard \
                             tie-breaks must equal global tie-breaks)",
                        ));
                    }
                    Some(ids)
                }
            };
            // A named registration hits the registry first: a
            // warm-started (or earlier in-session) index under the name
            // answers without rebuilding — but the submitted payload is
            // still hashed and diffed against the registered index, so
            // a client whose train set changed sees `drift:true`
            // instead of silently searching a stale index (the reply's
            // `content_hash` is always the *registered* index's hash).
            if let Some(name) = name {
                if let Some((key, loaded)) = coord.lookup_index_named(name) {
                    let stored = coord.index(key)?;
                    let submitted = content_hash_of(
                        t0,
                        &labels,
                        series.iter().map(|s| s.values.as_slice()),
                    );
                    let stored_hash = stored.content_hash();
                    let mut fields = vec![
                        ("ok", Json::Bool(true)),
                        ("index", Json::num(key.0 as f64)),
                        ("memory_bytes", Json::num(stored.memory_bytes() as f64)),
                        ("loaded_from_disk", Json::Bool(loaded)),
                        ("content_hash", Json::str(format!("{stored_hash:016x}"))),
                        ("drift", Json::Bool(stored_hash != submitted)),
                    ];
                    // content_hash only covers the payload — a request
                    // naming a *different measure family* than the
                    // stored index needs its own drift signal
                    if let Some(spec) = &mspec {
                        fields.push((
                            "measure_drift",
                            Json::Bool(!coord.index_matches_spec(&stored, spec)?),
                        ));
                    }
                    return Ok(Json::obj(fields));
                }
            }
            let train = LabeledSet::new(series);
            // v2: an optional "measure" spec picks the index family
            // (dtw / banded_dtw / sakoe_chiba / spdtw over any grid
            // reference); the v1 "band" parameter stays the default.
            let index = match &mspec {
                Some(spec) => coord.build_index_from_spec(&train, spec)?,
                None => Index::build(&train, band, coord.config().workers),
            };
            let bytes = index.memory_bytes();
            let hash = index.content_hash();
            let key = if let Some(ids) = global_ids {
                coord.register_index_sharded(index, ids)
            } else {
                match name {
                    Some(name) => coord.register_index_persistent(name, index)?,
                    None => coord.register_index(index),
                }
            };
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("index", Json::num(key.0 as f64)),
                ("memory_bytes", Json::num(bytes as f64)),
                ("loaded_from_disk", Json::Bool(false)),
                ("content_hash", Json::str(format!("{hash:016x}"))),
                ("drift", Json::Bool(false)),
            ];
            if let Some(sid) = shard {
                fields.push(("shard", Json::num(sid as f64)));
            }
            Ok(Json::obj(fields))
        }
        "search" => {
            let key = IndexKey(req.req_usize("index")? as u64);
            let k = req.get("k").and_then(Json::as_usize).unwrap_or(1);
            let x = parse_series(req, "x")?;
            let cascade = parse_cascade(req)?;
            let out = coord
                .submit_search_deadline(key, &x, k, cascade, deadline)?
                .wait_deadline(deadline)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("neighbors", neighbors_json(&out)),
                ("candidates", Json::num(out.stats.candidates as f64)),
                ("pruned", Json::num(out.stats.pruned() as f64)),
                ("full_evals", Json::num(out.stats.full_evals as f64)),
                ("dp_cells", Json::num(out.stats.dp_cells as f64)),
            ]))
        }
        "batch_search" => {
            // one request = one concurrent-epoch batch: the whole `xs`
            // array fans out on the compute pool, overlapping with any
            // other client's in-flight request
            let key = IndexKey(req.req_usize("index")? as u64);
            let k = req.get("k").and_then(Json::as_usize).unwrap_or(1);
            let cascade = parse_cascade(req)?;
            let arr = req.req_arr("xs")?;
            let mut queries = Vec::with_capacity(arr.len());
            for row in arr {
                let vals: Option<Vec<f64>> = row
                    .as_arr()
                    .map(|r| r.iter().map(Json::as_f64).collect())
                    .unwrap_or(None);
                let vals = vals.ok_or_else(|| {
                    crate::error::Error::config("'xs' must be arrays of numbers")
                })?;
                check_finite(&vals, "xs")?;
                queries.push(TimeSeries::new(0, vals));
            }
            let outs = coord
                .submit_batch_search_deadline(key, &queries, k, cascade, deadline)?
                .wait_deadline(deadline)?;
            let results = Json::arr(outs.iter().map(|out| {
                Json::obj(vec![
                    ("neighbors", neighbors_json(out)),
                    ("pruned", Json::num(out.stats.pruned() as f64)),
                    ("full_evals", Json::num(out.stats.full_evals as f64)),
                ])
            }));
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("queries", Json::num(outs.len() as f64)),
                ("results", results),
            ]))
        }
        "shard_search" => {
            // One fan-out leg from the shard front: run the full local
            // cascade + early-abandon engine and reply in *global*
            // index space.  Only shard servers answer, and only for
            // their own shard id — anything else is a bad_request, so a
            // mis-routed leg can never produce a silently wrong merge.
            let role = coord.shard_role().ok_or_else(|| {
                crate::error::Error::config(
                    "shard_search on a non-shard server (start it with `spdtw shard-serve`)",
                )
            })?;
            let sid = req.req_usize("shard")?;
            if sid != role.shard_id {
                return Err(crate::error::Error::config(format!(
                    "shard_search mis-routed: request targets shard {sid}, this server \
                     is shard {} of {}",
                    role.shard_id, role.shards_total
                )));
            }
            coord.note_shard_search();
            let key = IndexKey(req.req_usize("index")? as u64);
            let global_ids = coord.index_global_ids(key)?.ok_or_else(|| {
                crate::error::Error::config(
                    "index was not registered with 'global_ids' (register it through \
                     the shard front)",
                )
            })?;
            let k = req.get("k").and_then(Json::as_usize).unwrap_or(1);
            let cascade = parse_cascade(req)?;
            if req.get("xs").is_some() {
                // batched leg: the whole query set runs as one
                // concurrent-epoch batch, like batch_search
                let arr = req.req_arr("xs")?;
                let mut queries = Vec::with_capacity(arr.len());
                for row in arr {
                    let vals: Option<Vec<f64>> = row
                        .as_arr()
                        .map(|r| r.iter().map(Json::as_f64).collect())
                        .unwrap_or(None);
                    let vals = vals.ok_or_else(|| {
                        crate::error::Error::config("'xs' must be arrays of numbers")
                    })?;
                    check_finite(&vals, "xs")?;
                    queries.push(TimeSeries::new(0, vals));
                }
                let outs = coord
                    .submit_batch_search_deadline(key, &queries, k, cascade, deadline)?
                    .wait_deadline(deadline)?;
                let results = Json::arr(outs.iter().map(|out| {
                    Json::obj(vec![("neighbors", neighbors_json_global(out, &global_ids))])
                }));
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("shard", Json::num(sid as f64)),
                    ("queries", Json::num(outs.len() as f64)),
                    ("results", results),
                ]))
            } else {
                let x = parse_series(req, "x")?;
                let out = coord
                    .submit_search_deadline(key, &x, k, cascade, deadline)?
                    .wait_deadline(deadline)?;
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("shard", Json::num(sid as f64)),
                    ("neighbors", neighbors_json_global(&out, &global_ids)),
                    ("pruned", Json::num(out.stats.pruned() as f64)),
                    ("full_evals", Json::num(out.stats.full_evals as f64)),
                ]))
            }
        }
        "stream_open" => {
            // open an online-monitor session over a registered index;
            // the session id in the reply addresses every later
            // stream_* op.  Absent `rws` = the exact path (the
            // default); an `rws` object opts into the flagged
            // approximate pre-filter.
            let key = IndexKey(req.req_usize("index")? as u64);
            let k = req.get("k").and_then(Json::as_usize).unwrap_or(1);
            let cascade = parse_cascade(req)?;
            let rws = parse_rws(req)?;
            let idle = match req.get("idle_timeout_ms") {
                None => None,
                Some(v) => Some(v.as_usize().ok_or_else(|| {
                    crate::error::Error::config(
                        "'idle_timeout_ms' must be a non-negative integer",
                    )
                })? as u64),
            };
            let approx = rws.is_some();
            let skey = coord.stream_open(key, k, cascade, rws, idle)?;
            let t = coord.stream_window_len(skey)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("stream", Json::num(skey.0 as f64)),
                ("t", Json::num(t as f64)),
                ("approx", Json::Bool(approx)),
            ]))
        }
        "stream_push" => {
            // ingest samples; completed windows run the cascade inline.
            // The whole array is finite-checked before any sample is
            // ingested, so a wire push is all-or-nothing.
            let skey = StreamKey(req.req_usize("stream")? as u64);
            let arr = req.req_arr("values")?;
            let values: Option<Vec<f64>> = arr.iter().map(Json::as_f64).collect();
            let values = values.ok_or_else(|| {
                crate::error::Error::config("'values' must be numbers")
            })?;
            check_finite(&values, "values")?;
            let out = coord.stream_push(skey, &values, deadline)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pushed", Json::num(out.pushed as f64)),
                ("windows", Json::num(out.windows as f64)),
                ("ready", Json::Bool(out.ready)),
            ]))
        }
        "stream_matches" => {
            let skey = StreamKey(req.req_usize("stream")? as u64);
            let m = coord.stream_matches(skey)?;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("ready", Json::Bool(m.report.is_some())),
                ("approx", Json::Bool(m.approx)),
                ("samples", Json::num(m.stats.samples as f64)),
                ("windows", Json::num(m.stats.windows as f64)),
            ];
            if let Some(rep) = &m.report {
                fields.push(("window_start", Json::num(rep.window_start as f64)));
                fields.push(("neighbors", neighbors_json_slice(&rep.neighbors)));
                fields.push(("pruned", Json::num(rep.stats.pruned() as f64)));
                fields.push(("full_evals", Json::num(rep.stats.full_evals as f64)));
                fields.push(("dp_cells", Json::num(rep.stats.dp_cells as f64)));
                // per-window recall is only present on audited windows
                if let Some(r) = rep.recall {
                    fields.push(("recall", Json::num(r)));
                }
            }
            // session-level measured recall: mean over audited windows
            if let Some(r) = m.stats.recall() {
                fields.push(("recall_at_k", Json::num(r)));
            }
            Ok(Json::obj(fields))
        }
        "stream_close" => {
            let skey = StreamKey(req.req_usize("stream")? as u64);
            let stats = coord.stream_close(skey)?;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("closed", Json::Bool(true)),
                ("samples", Json::num(stats.samples as f64)),
                ("windows", Json::num(stats.windows as f64)),
            ];
            if let Some(r) = stats.recall() {
                fields.push(("recall_at_k", Json::num(r)));
            }
            Ok(Json::obj(fields))
        }
        "register_measure" => {
            // bind once at the boundary: parameters validated, grids
            // resolved; later dist/kernel ops reference the key
            let mspec = match parse_measure_sel(req)? {
                MeasureSel::Spec(spec) => spec,
                MeasureSel::Key(_) => {
                    return Err(crate::error::Error::config(
                        "'measure' must be a spec object here, not a key",
                    ))
                }
            };
            let key = coord.register_measure(&mspec)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("measure", Json::num(key.0 as f64)),
                ("kernel", Json::Bool(mspec.is_kernel())),
                ("name", Json::str(mspec.name())),
            ]))
        }
        "dist" => {
            // the generic pairwise op: any measure in the family, as an
            // inline spec or a registered key; kernel measures answer
            // with the normalized-kernel distance
            let x = parse_series(req, "x")?;
            let y = parse_series(req, "y")?;
            let ticket = match parse_measure_sel(req)? {
                MeasureSel::Spec(spec) => coord.submit_dist_spec(&spec, &x, &y)?,
                MeasureSel::Key(key) => coord.submit_dist_key(key, &x, &y)?,
            };
            coord.flush(); // PJRT-routed specs sit in a partial batch
            let out = ticket.wait_deadline(deadline)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("value", Json::num(out.value)),
                ("cells", Json::num(out.visited_cells as f64)),
                ("backend", Json::str(out.backend.as_str())),
            ]))
        }
        "kernel" => {
            // log K(x, y) under any kernel measure; distance-only
            // measures are a bad_request
            let x = parse_series(req, "x")?;
            let y = parse_series(req, "y")?;
            let ticket = match parse_measure_sel(req)? {
                MeasureSel::Spec(spec) => coord.submit_kernel_spec(&spec, &x, &y)?,
                MeasureSel::Key(key) => coord.submit_kernel_key(key, &x, &y)?,
            };
            coord.flush();
            let out = ticket.wait_deadline(deadline)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("log_k", Json::num(out.value)),
                ("cells", Json::num(out.visited_cells as f64)),
                ("backend", Json::str(out.backend.as_str())),
            ]))
        }
        "metrics" => {
            let s = coord.metrics();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("submitted", Json::num(s.submitted as f64)),
                ("completed", Json::num(s.completed as f64)),
                ("failed", Json::num(s.failed as f64)),
                ("native", Json::num(s.native_jobs as f64)),
                ("pjrt", Json::num(s.pjrt_jobs as f64)),
                ("batches", Json::num(s.batches as f64)),
                ("padded", Json::num(s.padded_slots as f64)),
                ("search_batches", Json::num(s.search_batches as f64)),
                ("requests_inflight", Json::num(s.requests_inflight as f64)),
                (
                    "peak_concurrent_requests",
                    Json::num(s.peak_concurrent_requests as f64),
                ),
                ("pool_epochs_live", Json::num(s.pool.active_epochs as f64)),
                (
                    "pool_peak_epochs",
                    Json::num(s.pool.peak_concurrent_epochs as f64),
                ),
                ("native_queue_depth", Json::num(s.native_queue_depth as f64)),
                ("index_evictions", Json::num(s.index_evictions as f64)),
                (
                    "measures_registered",
                    Json::num(s.measures_registered as f64),
                ),
                ("proto_v2_requests", Json::num(s.proto_v2_requests as f64)),
                ("shard_searches", Json::num(s.shard_searches as f64)),
                ("deadlines_exceeded", Json::num(s.deadlines_exceeded as f64)),
                ("measures_loaded", Json::num(s.measures_loaded as f64)),
                (
                    "measure_load_failures",
                    Json::num(s.measure_load_failures as f64),
                ),
                ("mean_latency_us", Json::num(s.mean_latency_us)),
                ("streams_opened", Json::num(s.streams_opened as f64)),
                ("streams_closed", Json::num(s.streams_closed as f64)),
                ("streams_evicted", Json::num(s.streams_evicted as f64)),
                ("stream_samples", Json::num(s.stream_samples as f64)),
                ("stream_windows", Json::num(s.stream_windows as f64)),
            ]))
        }
        "shutdown" => {
            stop.store(true, Ordering::Relaxed);
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(crate::error::Error::Unknown {
            kind: "op",
            name: other.to_string(),
        }),
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CoordinatorConfig;

    #[test]
    fn malformed_requests_get_error_replies_not_disconnects() {
        use std::io::{BufRead, BufReader, Write};
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
        let stream = std::net::TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        for bad in [
            "not json at all",
            r#"{"no_op": 1}"#,
            r#"{"op":"spdtw"}"#,                           // missing fields
            r#"{"op":"spdtw","grid":99,"x":[1],"y":[1]}"#, // unknown grid
            r#"{"op":"register_grid"}"#,                   // missing t
            r#"{"op":"spdtw","grid":0,"x":["a"],"y":[1]}"#, // non-numeric
            r#"{"op":"nosuchop"}"#,
        ] {
            writer.write_all(bad.as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
            writer.flush().unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let reply = Json::parse(line.trim()).expect("reply must be valid JSON");
            assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{bad}");
        }
        // connection still alive after every failure
        writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"));
        server.stop();
    }

    #[test]
    fn deadline_ms_is_validated_not_clamped() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        for bad in [
            r#"{"op":"ping","deadline_ms":0}"#,
            r#"{"op":"ping","deadline_ms":-5}"#,
            r#"{"op":"ping","deadline_ms":1.5}"#,
            r#"{"op":"ping","deadline_ms":"fast"}"#,
            r#"{"op":"ping","deadline_ms":86400001}"#,
        ] {
            let rep = dispatch_line(bad, &coord);
            assert_eq!(rep.get("ok"), Some(&Json::Bool(false)), "{bad}");
            assert_eq!(
                rep.get("code"),
                Some(&Json::str("bad_request")),
                "{bad} -> {rep:?}"
            );
        }
        // a generous budget passes straight through to the op
        let ok = dispatch_line(r#"{"op":"ping","deadline_ms":60000}"#, &coord);
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)), "{ok:?}");
        let m = dispatch_line(r#"{"op":"metrics"}"#, &coord);
        assert_eq!(m.req_f64("deadlines_exceeded").unwrap(), 0.0);
    }

    #[test]
    fn register_index_and_search_roundtrip() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        let reg = client
            .call(
                &Json::parse(
                    concat!(
                        r#"{"op":"register_index","band":1,"#,
                        r#""series":[[0,0,0],[5,5,5],[0.1,0.1,0.1]],"labels":[0,1,0]}"#
                    ),
                )
                .unwrap(),
            )
            .unwrap();
        assert_eq!(reg.get("ok"), Some(&Json::Bool(true)), "{reg:?}");
        let idx = reg.req_usize("index").unwrap();

        let r = client
            .call(
                &Json::parse(&format!(
                    r#"{{"op":"search","index":{idx},"k":2,"x":[0,0,0]}}"#
                ))
                .unwrap(),
            )
            .unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        let ns = r.req_arr("neighbors").unwrap();
        assert_eq!(ns.len(), 2);
        assert_eq!(ns[0].req_f64("dist").unwrap(), 0.0);
        assert_eq!(ns[0].req_usize("label").unwrap(), 0);
        assert!(r.req_f64("candidates").unwrap() == 3.0);

        for bad in [
            r#"{"op":"search","index":99,"k":1,"x":[0,0,0]}"#, // unknown index
            r#"{"op":"search","index":0,"k":1,"x":[0,0]}"#,    // wrong length
            r#"{"op":"search","index":0,"k":1,"x":[0,0,0],"cascade":"off"}"#, // bad cascade
            r#"{"op":"register_index","series":[]}"#,          // empty
            r#"{"op":"register_index","series":[[1,2],[1]]}"#, // ragged
            r#"{"op":"register_index","series":[[1,2]],"labels":["a"]}"#, // bad label
        ] {
            let rep = client.call(&Json::parse(bad).unwrap()).unwrap();
            assert_eq!(rep.get("ok"), Some(&Json::Bool(false)), "{bad}");
        }
        server.stop();
    }

    #[test]
    fn named_register_index_reports_loaded_from_disk() {
        let store =
            std::env::temp_dir().join(format!("spdtw_srv_store_{}", std::process::id()));
        std::fs::remove_dir_all(&store).ok();
        let mut ccfg = CoordinatorConfig::default();
        ccfg.index_store = Some(store.clone());

        let reg_req = Json::parse(
            concat!(
                r#"{"op":"register_index","name":"tiny","band":1,"#,
                r#""series":[[0,0,0],[5,5,5]],"labels":[0,1]}"#
            ),
        )
        .unwrap();

        // session 1: cold build, persisted
        {
            let coord =
                Arc::new(Coordinator::start(ccfg.clone(), None).unwrap());
            let mut server = Server::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
            let mut client = Client::connect(&server.addr).unwrap();
            let r = client.call(&reg_req).unwrap();
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
            assert_eq!(r.get("loaded_from_disk"), Some(&Json::Bool(false)));
            // same name again: deduped, still not from disk
            let r2 = client.call(&reg_req).unwrap();
            assert_eq!(r2.get("loaded_from_disk"), Some(&Json::Bool(false)));
            assert_eq!(r2.req_usize("index").unwrap(), r.req_usize("index").unwrap());
            // bad names are rejected, not written
            let bad = client
                .call(
                    &Json::parse(r#"{"op":"register_index","name":"../x","series":[[1,2]]}"#)
                        .unwrap(),
                )
                .unwrap();
            assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
            server.stop();
        }

        // session 2: warm start serves the persisted index from disk
        let coord = Arc::new(Coordinator::start(ccfg, None).unwrap());
        let mut server = Server::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let r = client.call(&reg_req).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        assert_eq!(r.get("loaded_from_disk"), Some(&Json::Bool(true)));
        let idx = r.req_usize("index").unwrap();
        let s = client
            .call(
                &Json::parse(&format!(r#"{{"op":"search","index":{idx},"k":1,"x":[0,0,0]}}"#))
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(s.get("ok"), Some(&Json::Bool(true)), "{s:?}");
        assert_eq!(s.req_arr("neighbors").unwrap()[0].req_f64("dist").unwrap(), 0.0);
        server.stop();
        std::fs::remove_dir_all(&store).ok();
    }

    #[test]
    fn batch_search_roundtrip_matches_singles() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let reg = client
            .call(
                &Json::parse(
                    concat!(
                        r#"{"op":"register_index","band":1,"#,
                        r#""series":[[0,0,0],[5,5,5],[0.1,0.1,0.1]],"labels":[0,1,0]}"#
                    ),
                )
                .unwrap(),
            )
            .unwrap();
        let idx = reg.req_usize("index").unwrap();

        let b = client
            .call(
                &Json::parse(&format!(
                    r#"{{"op":"batch_search","index":{idx},"k":1,"xs":[[0,0,0],[5,5,4]]}}"#
                ))
                .unwrap(),
            )
            .unwrap();
        assert_eq!(b.get("ok"), Some(&Json::Bool(true)), "{b:?}");
        assert_eq!(b.req_usize("queries").unwrap(), 2);
        let results = b.req_arr("results").unwrap();
        assert_eq!(results.len(), 2);
        for (i, x) in ["[0,0,0]", "[5,5,4]"].iter().enumerate() {
            let single = client
                .call(
                    &Json::parse(&format!(r#"{{"op":"search","index":{idx},"k":1,"x":{x}}}"#))
                        .unwrap(),
                )
                .unwrap();
            let want = &single.req_arr("neighbors").unwrap()[0];
            let got = &results[i].req_arr("neighbors").unwrap()[0];
            assert_eq!(got.req_f64("dist").unwrap(), want.req_f64("dist").unwrap());
            assert_eq!(got.req_usize("idx").unwrap(), want.req_usize("idx").unwrap());
        }

        for bad in [
            format!(r#"{{"op":"batch_search","index":{idx},"k":1,"xs":[]}}"#),
            format!(r#"{{"op":"batch_search","index":{idx},"k":1,"xs":[[0,0]]}}"#),
            format!(r#"{{"op":"batch_search","index":{idx},"k":1,"xs":[["a",0,0]]}}"#),
            r#"{"op":"batch_search","index":77,"k":1,"xs":[[0,0,0]]}"#.to_string(),
        ] {
            let rep = client.call(&Json::parse(&bad).unwrap()).unwrap();
            assert_eq!(rep.get("ok"), Some(&Json::Bool(false)), "{bad}");
        }

        let m = client.call(&Json::parse(r#"{"op":"metrics"}"#).unwrap()).unwrap();
        assert_eq!(m.req_f64("search_batches").unwrap(), 1.0);
        assert!(m.req_f64("peak_concurrent_requests").unwrap() >= 1.0);
        server.stop();
    }

    #[test]
    fn stream_ops_roundtrip_and_match_batch_search() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let reg = dispatch_line(
            concat!(
                r#"{"op":"register_index","band":1,"#,
                r#""series":[[0,0,0],[5,5,5],[0.1,0.1,0.1]],"labels":[0,1,0]}"#
            ),
            &coord,
        );
        assert_eq!(reg.get("ok"), Some(&Json::Bool(true)), "{reg:?}");
        let idx = reg.req_usize("index").unwrap();

        let open = dispatch_line(
            &format!(r#"{{"op":"stream_open","index":{idx},"k":2}}"#),
            &coord,
        );
        assert_eq!(open.get("ok"), Some(&Json::Bool(true)), "{open:?}");
        assert_eq!(open.req_usize("t").unwrap(), 3);
        assert_eq!(open.get("approx"), Some(&Json::Bool(false)));
        let sid = open.req_usize("stream").unwrap();

        let push = dispatch_line(
            &format!(r#"{{"op":"stream_push","stream":{sid},"values":[0,0,0]}}"#),
            &coord,
        );
        assert_eq!(push.get("ok"), Some(&Json::Bool(true)), "{push:?}");
        assert_eq!(push.req_usize("windows").unwrap(), 1);
        assert_eq!(push.get("ready"), Some(&Json::Bool(true)));

        // the served window must answer exactly like the batch search op
        let m = dispatch_line(&format!(r#"{{"op":"stream_matches","stream":{sid}}}"#), &coord);
        assert_eq!(m.get("ok"), Some(&Json::Bool(true)), "{m:?}");
        assert_eq!(m.get("approx"), Some(&Json::Bool(false)));
        assert_eq!(m.req_usize("window_start").unwrap(), 0);
        let want = dispatch_line(
            &format!(r#"{{"op":"search","index":{idx},"k":2,"x":[0,0,0]}}"#),
            &coord,
        );
        let got = m.req_arr("neighbors").unwrap();
        let exp = want.req_arr("neighbors").unwrap();
        assert_eq!(got.len(), exp.len());
        for (g, e) in got.iter().zip(exp) {
            assert_eq!(g.req_f64("dist").unwrap().to_bits(), e.req_f64("dist").unwrap().to_bits());
            assert_eq!(g.req_usize("idx").unwrap(), e.req_usize("idx").unwrap());
        }

        // sliding one sample forward evaluates exactly one more window
        let push2 = dispatch_line(
            &format!(r#"{{"op":"stream_push","stream":{sid},"values":[5]}}"#),
            &coord,
        );
        assert_eq!(push2.req_usize("windows").unwrap(), 1);

        let close = dispatch_line(&format!(r#"{{"op":"stream_close","stream":{sid}}}"#), &coord);
        assert_eq!(close.get("ok"), Some(&Json::Bool(true)), "{close:?}");
        assert_eq!(close.req_usize("samples").unwrap(), 4);
        assert_eq!(close.req_usize("windows").unwrap(), 2);

        // error matrix: typed codes, session gone after close
        for (bad, code) in [
            (format!(r#"{{"op":"stream_push","stream":{sid},"values":[1]}}"#), "not_found"),
            (r#"{"op":"stream_open","index":99,"k":1}"#.to_string(), "not_found"),
            (
                format!(r#"{{"op":"stream_open","index":{idx},"k":0}}"#),
                "bad_request",
            ),
            (
                format!(r#"{{"op":"stream_open","index":{idx},"rws":7}}"#),
                "bad_request",
            ),
            (
                format!(r#"{{"op":"stream_open","index":{idx},"rws":{{"d":0}}}}"#),
                "bad_request",
            ),
        ] {
            let rep = dispatch_line(&bad, &coord);
            assert_eq!(rep.get("ok"), Some(&Json::Bool(false)), "{bad}");
            assert_eq!(rep.get("code"), Some(&Json::str(code)), "{bad} -> {rep:?}");
        }

        let metrics = dispatch_line(r#"{"op":"metrics"}"#, &coord);
        assert_eq!(metrics.req_f64("streams_opened").unwrap(), 1.0);
        assert_eq!(metrics.req_f64("streams_closed").unwrap(), 1.0);
        assert_eq!(metrics.req_f64("stream_samples").unwrap(), 4.0);
        assert_eq!(metrics.req_f64("stream_windows").unwrap(), 2.0);
    }

    #[test]
    fn stream_push_rejects_non_finite_whole_array() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let reg = dispatch_line(
            r#"{"op":"register_index","band":1,"series":[[0,0,0],[5,5,5]]}"#,
            &coord,
        );
        let idx = reg.req_usize("index").unwrap();
        let open = dispatch_line(&format!(r#"{{"op":"stream_open","index":{idx}}}"#), &coord);
        let sid = open.req_usize("stream").unwrap();
        // wire pushes are all-or-nothing: one bad value rejects the
        // array before any sample reaches the monitor
        let rep = dispatch_line(
            &format!(r#"{{"op":"stream_push","stream":{sid},"values":[1,2,1e999]}}"#),
            &coord,
        );
        assert_eq!(rep.get("ok"), Some(&Json::Bool(false)), "{rep:?}");
        assert_eq!(rep.get("code"), Some(&Json::str("bad_input")), "{rep:?}");
        let rep = dispatch_line(
            &format!(r#"{{"op":"stream_push","stream":{sid},"values":[1,2,"x"]}}"#),
            &coord,
        );
        assert_eq!(rep.get("ok"), Some(&Json::Bool(false)), "{rep:?}");
        assert_eq!(rep.get("code"), Some(&Json::str("bad_request")), "{rep:?}");
        let m = dispatch_line(&format!(r#"{{"op":"stream_matches","stream":{sid}}}"#), &coord);
        assert_eq!(m.req_usize("samples").unwrap(), 0);
    }

    #[test]
    fn named_register_index_detects_content_drift() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        let reg = |series: &str| {
            format!(
                r#"{{"op":"register_index","name":"drifty","band":1,"series":{series},"labels":[0,1]}}"#
            )
        };
        let r1 = client.call(&Json::parse(&reg("[[0,0,0],[5,5,5]]")).unwrap()).unwrap();
        assert_eq!(r1.get("ok"), Some(&Json::Bool(true)), "{r1:?}");
        assert_eq!(r1.get("drift"), Some(&Json::Bool(false)));
        let h1 = r1.req_str("content_hash").unwrap().to_string();
        assert_eq!(h1.len(), 16);

        // identical payload: served from the registry, no drift
        let r2 = client.call(&Json::parse(&reg("[[0,0,0],[5,5,5]]")).unwrap()).unwrap();
        assert_eq!(r2.get("drift"), Some(&Json::Bool(false)));
        assert_eq!(r2.req_str("content_hash").unwrap(), h1);
        assert_eq!(r2.req_usize("index").unwrap(), r1.req_usize("index").unwrap());

        // changed payload under the same name: still served (the client
        // decides), but flagged, and the hash is the STORED index's
        let r3 = client.call(&Json::parse(&reg("[[0,0,0],[9,9,9]]")).unwrap()).unwrap();
        assert_eq!(r3.get("ok"), Some(&Json::Bool(true)), "{r3:?}");
        assert_eq!(r3.get("drift"), Some(&Json::Bool(true)));
        assert_eq!(r3.req_str("content_hash").unwrap(), h1);
        assert_eq!(r3.req_usize("index").unwrap(), r1.req_usize("index").unwrap());
        server.stop();
    }

    #[test]
    fn ping_register_dist_metrics() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        let pong = client.call(&Json::parse(r#"{"op":"ping"}"#).unwrap()).unwrap();
        assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));

        let reg = client
            .call(&Json::parse(r#"{"op":"register_grid","t":4,"band":1}"#).unwrap())
            .unwrap();
        let gid = reg.req_usize("grid").unwrap();

        let d = client
            .call(
                &Json::parse(&format!(
                    r#"{{"op":"spdtw","grid":{gid},"x":[0,1,2,3],"y":[0,1,2,3]}}"#
                ))
                .unwrap(),
            )
            .unwrap();
        assert_eq!(d.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(d.req_f64("value").unwrap(), 0.0);
        assert_eq!(d.req_str("backend").unwrap(), "native");

        let m = client.call(&Json::parse(r#"{"op":"metrics"}"#).unwrap()).unwrap();
        assert!(m.req_f64("completed").unwrap() >= 1.0);

        let bad = client.call(&Json::parse(r#"{"op":"nope"}"#).unwrap()).unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

        server.stop();
    }
}
