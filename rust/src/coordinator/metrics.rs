//! Coordinator metrics: lock-free counters plus a coarse log-scale
//! latency histogram; snapshots feed the CLI, the TCP `info` op and the
//! §Perf benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::search::PruneStats;

/// Log2-bucketed latency histogram, 1µs .. ~1s.
const LAT_BUCKETS: usize = 22;

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub native_jobs: AtomicU64,
    pub pjrt_jobs: AtomicU64,
    pub batches: AtomicU64,
    /// Wasted slots from padding partial batches.
    pub padded_slots: AtomicU64,
    /// Flushes triggered by the timeout rather than a full batch.
    pub timeout_flushes: AtomicU64,
    pub visited_cells: AtomicU64,
    // ---- search-cascade counters (per-stage exits, `search` subsystem) ----
    pub search_queries: AtomicU64,
    pub search_candidates: AtomicU64,
    pub lb_kim_skips: AtomicU64,
    pub lb_keogh_skips: AtomicU64,
    pub lb_rev_skips: AtomicU64,
    pub early_abandons: AtomicU64,
    pub full_dp_evals: AtomicU64,
    // ---- index-store counters (persistence / warm start) ----
    /// Indexes written to the on-disk store this session.
    pub indexes_saved: AtomicU64,
    /// Indexes reloaded from the store at boot (warm start).
    pub indexes_loaded: AtomicU64,
    /// Store files rejected at boot (corrupt/stale — skipped, not served).
    pub index_load_failures: AtomicU64,
    /// Store files LRU-evicted to honor `index_store_max_bytes`.
    pub index_evictions: AtomicU64,
    // ---- measure registry / protocol v2 ----
    /// Measures bound via `register_measure` (TCP v2 or the API).
    pub measures_registered: AtomicU64,
    /// Measures replayed from the persisted `measures.json` at boot.
    pub measures_loaded: AtomicU64,
    /// Persisted measures that failed to re-bind at boot (skipped; their
    /// keys stay dead rather than resolving to a different measure).
    pub measure_load_failures: AtomicU64,
    /// Requests that arrived in a protocol-v2 envelope (`proto: 2`).
    pub proto_v2_requests: AtomicU64,
    /// `shard_search` ops served by this process (shard-server role).
    pub shard_searches: AtomicU64,
    // ---- concurrency (multi-client execution over the compute pool) ----
    /// Batch search requests (each runs as its own pool epoch).
    pub search_batches: AtomicU64,
    /// Gram-matrix requests (each runs as its own set of pool epochs).
    pub gram_requests: AtomicU64,
    /// Jobs sitting in partial PJRT batches (gauge, published by the
    /// dispatcher after every event).
    pub batcher_queue_depth: AtomicU64,
    /// Search/gram requests currently executing (gauge).
    pub requests_inflight: AtomicU64,
    /// High-water mark of simultaneously executing requests — `>= 2`
    /// means two clients' requests actually overlapped.
    pub peak_concurrent_requests: AtomicU64,
    /// Wire requests answered with the typed `deadline_exceeded` code
    /// (budget exhausted pre-dispatch, at epoch claim, or mid-wait) —
    /// counted once per request at the server's dispatch choke point.
    pub deadlines_exceeded: AtomicU64,
    // ---- streaming sessions (`stream_*` op family) ----
    /// Stream sessions opened via `stream_open`.
    pub streams_opened: AtomicU64,
    /// Stream sessions closed by the client (`stream_close`).
    pub streams_closed: AtomicU64,
    /// Stream sessions reclaimed by the idle-timeout sweep.
    pub streams_evicted: AtomicU64,
    /// Samples ingested across all stream sessions.
    pub stream_samples: AtomicU64,
    /// Windows evaluated across all stream sessions (each also folds
    /// its cascade counters into the search totals above).
    pub stream_windows: AtomicU64,
    lat: [AtomicU64; LAT_BUCKETS],
    lat_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.lat[bucket].fetch_add(1, Ordering::Relaxed);
        self.lat_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Enter a search/gram request: bump the inflight gauge and the
    /// concurrency high-water mark, returning a guard that decrements
    /// on drop.  RAII so a panicking request body (contained by the
    /// `WorkerPool`) cannot leak the gauge — the same drop-guard lesson
    /// as `InflightSlot` in `pool`.
    pub fn request_begin(&self) -> RequestGauge<'_> {
        let now = self.requests_inflight.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak_concurrent_requests.fetch_max(now, Ordering::SeqCst);
        RequestGauge(self)
    }

    /// Fold one query's cascade counters into the service totals.
    pub fn record_search(&self, s: &PruneStats) {
        self.search_queries.fetch_add(s.queries, Ordering::Relaxed);
        self.search_candidates.fetch_add(s.candidates, Ordering::Relaxed);
        self.lb_kim_skips.fetch_add(s.kim_pruned, Ordering::Relaxed);
        self.lb_keogh_skips.fetch_add(s.keogh_pruned, Ordering::Relaxed);
        self.lb_rev_skips.fetch_add(s.rev_pruned, Ordering::Relaxed);
        self.early_abandons.fetch_add(s.abandoned, Ordering::Relaxed);
        self.full_dp_evals.fetch_add(s.full_evals, Ordering::Relaxed);
        self.visited_cells.fetch_add(s.total_cells(), Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let lat: Vec<u64> = self.lat.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            native_jobs: self.native_jobs.load(Ordering::Relaxed),
            pjrt_jobs: self.pjrt_jobs.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            timeout_flushes: self.timeout_flushes.load(Ordering::Relaxed),
            visited_cells: self.visited_cells.load(Ordering::Relaxed),
            search_queries: self.search_queries.load(Ordering::Relaxed),
            search_candidates: self.search_candidates.load(Ordering::Relaxed),
            lb_kim_skips: self.lb_kim_skips.load(Ordering::Relaxed),
            lb_keogh_skips: self.lb_keogh_skips.load(Ordering::Relaxed),
            lb_rev_skips: self.lb_rev_skips.load(Ordering::Relaxed),
            early_abandons: self.early_abandons.load(Ordering::Relaxed),
            full_dp_evals: self.full_dp_evals.load(Ordering::Relaxed),
            indexes_saved: self.indexes_saved.load(Ordering::Relaxed),
            indexes_loaded: self.indexes_loaded.load(Ordering::Relaxed),
            index_load_failures: self.index_load_failures.load(Ordering::Relaxed),
            index_evictions: self.index_evictions.load(Ordering::Relaxed),
            measures_registered: self.measures_registered.load(Ordering::Relaxed),
            measures_loaded: self.measures_loaded.load(Ordering::Relaxed),
            measure_load_failures: self.measure_load_failures.load(Ordering::Relaxed),
            proto_v2_requests: self.proto_v2_requests.load(Ordering::Relaxed),
            shard_searches: self.shard_searches.load(Ordering::Relaxed),
            search_batches: self.search_batches.load(Ordering::Relaxed),
            gram_requests: self.gram_requests.load(Ordering::Relaxed),
            batcher_queue_depth: self.batcher_queue_depth.load(Ordering::Relaxed),
            requests_inflight: self.requests_inflight.load(Ordering::SeqCst),
            peak_concurrent_requests: self.peak_concurrent_requests.load(Ordering::SeqCst),
            deadlines_exceeded: self.deadlines_exceeded.load(Ordering::Relaxed),
            streams_opened: self.streams_opened.load(Ordering::Relaxed),
            streams_closed: self.streams_closed.load(Ordering::Relaxed),
            streams_evicted: self.streams_evicted.load(Ordering::Relaxed),
            stream_samples: self.stream_samples.load(Ordering::Relaxed),
            stream_windows: self.stream_windows.load(Ordering::Relaxed),
            pool: crate::pool::pool_stats(),
            native_queue_depth: 0,
            mean_latency_us: if completed > 0 {
                self.lat_sum_us.load(Ordering::Relaxed) as f64 / completed as f64
            } else {
                0.0
            },
            latency_hist: lat,
        }
    }
}

/// Releases one slot of the request-inflight gauge on drop — even when
/// the request body unwinds.
#[must_use = "dropping the guard immediately ends the request's inflight window"]
pub struct RequestGauge<'a>(&'a Metrics);

impl Drop for RequestGauge<'_> {
    fn drop(&mut self) {
        self.0.requests_inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A point-in-time copy of every counter.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub native_jobs: u64,
    pub pjrt_jobs: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub timeout_flushes: u64,
    pub visited_cells: u64,
    pub search_queries: u64,
    pub search_candidates: u64,
    pub lb_kim_skips: u64,
    pub lb_keogh_skips: u64,
    pub lb_rev_skips: u64,
    pub early_abandons: u64,
    pub full_dp_evals: u64,
    pub indexes_saved: u64,
    pub indexes_loaded: u64,
    pub index_load_failures: u64,
    pub index_evictions: u64,
    /// Measures bound via `register_measure`.
    pub measures_registered: u64,
    /// Measures replayed from the persisted store at boot.
    pub measures_loaded: u64,
    /// Persisted measures skipped at boot (could not re-bind).
    pub measure_load_failures: u64,
    /// Requests served from a protocol-v2 envelope.
    pub proto_v2_requests: u64,
    /// `shard_search` ops served (shard-server role).
    pub shard_searches: u64,
    pub search_batches: u64,
    pub gram_requests: u64,
    /// Jobs in partial PJRT batches at snapshot time (gauge).
    pub batcher_queue_depth: u64,
    /// Requests executing at snapshot time (gauge).
    pub requests_inflight: u64,
    /// Most requests ever executing simultaneously.
    pub peak_concurrent_requests: u64,
    /// Requests whose `deadline_ms` budget drained before completion.
    pub deadlines_exceeded: u64,
    /// Stream sessions opened / client-closed / idle-evicted.
    pub streams_opened: u64,
    pub streams_closed: u64,
    pub streams_evicted: u64,
    /// Samples ingested and windows evaluated across stream sessions.
    pub stream_samples: u64,
    pub stream_windows: u64,
    /// Compute-pool scheduler state at snapshot time (live/peak epoch
    /// counts prove multi-client overlap — see `pool::PoolStats`).
    pub pool: crate::pool::PoolStats,
    /// Native `WorkerPool` jobs submitted but unfinished at snapshot
    /// time (filled by `Coordinator::metrics`; 0 from a bare
    /// `Metrics::snapshot`).
    pub native_queue_depth: u64,
    pub mean_latency_us: f64,
    pub latency_hist: Vec<u64>,
}

impl Snapshot {
    /// Approximate latency percentile from the log2 histogram (upper
    /// bucket bound, µs).
    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        let total: u64 = self.latency_hist.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.latency_hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (1u64 << i) as f64;
            }
        }
        (1u64 << (self.latency_hist.len() - 1)) as f64
    }

    /// Fraction of search candidates resolved without a completed full
    /// DP (skipped by a bound or abandoned mid-DP).
    pub fn search_prune_ratio(&self) -> f64 {
        if self.search_candidates == 0 {
            0.0
        } else {
            let pruned = self.lb_kim_skips
                + self.lb_keogh_skips
                + self.lb_rev_skips
                + self.early_abandons;
            pruned as f64 / self.search_candidates as f64
        }
    }

    pub fn report(&self) -> String {
        format!(
            "jobs: {} submitted, {} completed ({} native / {} pjrt), {} failed\n\
             batches: {} ({} padded slots, {} timeout flushes)\n\
             cells: {}\n\
             search: {} queries, {} candidates -> {} kim / {} keogh / {} rev skips, \
             {} abandons, {} full DPs ({:.1}% pruned)\n\
             index store: {} saved, {} warm-loaded, {} rejected, {} evicted\n\
             protocol: {} measures registered ({} replayed, {} replay failures), \
             {} v2 requests, {} shard searches\n\
             concurrency: {} batch / {} gram requests, {} inflight (peak {}), \
             pool {} epochs live (peak {}), native queue {}\n\
             deadlines: {} exceeded\n\
             streams: {} opened ({} closed, {} idle-evicted), \
             {} samples, {} windows\n\
             latency: mean {:.1} µs, p50 ≤ {:.0} µs, p99 ≤ {:.0} µs",
            self.submitted,
            self.completed,
            self.native_jobs,
            self.pjrt_jobs,
            self.failed,
            self.batches,
            self.padded_slots,
            self.timeout_flushes,
            self.visited_cells,
            self.search_queries,
            self.search_candidates,
            self.lb_kim_skips,
            self.lb_keogh_skips,
            self.lb_rev_skips,
            self.early_abandons,
            self.full_dp_evals,
            100.0 * self.search_prune_ratio(),
            self.indexes_saved,
            self.indexes_loaded,
            self.index_load_failures,
            self.index_evictions,
            self.measures_registered,
            self.measures_loaded,
            self.measure_load_failures,
            self.proto_v2_requests,
            self.shard_searches,
            self.search_batches,
            self.gram_requests,
            self.requests_inflight,
            self.peak_concurrent_requests,
            self.pool.active_epochs,
            self.pool.peak_concurrent_epochs,
            self.native_queue_depth,
            self.deadlines_exceeded,
            self.streams_opened,
            self.streams_closed,
            self.streams_evicted,
            self.stream_samples,
            self.stream_windows,
            self.mean_latency_us,
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(100));
        m.record_latency(Duration::from_micros(300));
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert!(s.mean_latency_us > 0.0);
        assert!(s.latency_percentile_us(50.0) >= 64.0);
    }

    #[test]
    fn percentile_monotone() {
        let m = Metrics::new();
        for us in [1u64, 10, 100, 1000, 10000] {
            m.record_latency(Duration::from_micros(us));
        }
        m.completed.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.latency_percentile_us(99.0) >= s.latency_percentile_us(50.0));
    }

    #[test]
    fn report_contains_sections() {
        let s = Metrics::new().snapshot();
        let r = s.report();
        assert!(r.contains("jobs:") && r.contains("batches:") && r.contains("latency:"));
        assert!(r.contains("search:"));
        assert!(r.contains("index store:"));
        assert!(r.contains("concurrency:"));
        assert!(r.contains("streams:"));
    }

    #[test]
    fn request_gauges_track_inflight_and_peak() {
        let m = Metrics::new();
        let a = m.request_begin();
        let b = m.request_begin();
        let c = m.request_begin();
        drop(c);
        let s = m.snapshot();
        assert_eq!(s.requests_inflight, 2);
        assert_eq!(s.peak_concurrent_requests, 3);
        drop(a);
        drop(b);
        assert_eq!(m.snapshot().requests_inflight, 0);
        assert_eq!(m.snapshot().peak_concurrent_requests, 3);
    }

    #[test]
    fn request_gauge_released_on_unwind() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let m = Metrics::new();
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.request_begin();
            panic!("request body blew up");
        }));
        assert_eq!(m.snapshot().requests_inflight, 0, "gauge leaked on unwind");
        assert_eq!(m.snapshot().peak_concurrent_requests, 1);
    }

    #[test]
    fn search_counters_fold_prune_stats() {
        let m = Metrics::new();
        let s = PruneStats {
            queries: 2,
            candidates: 20,
            kim_pruned: 5,
            keogh_pruned: 4,
            rev_pruned: 2,
            abandoned: 3,
            full_evals: 6,
            dp_cells: 500,
            lb_cells: 120,
        };
        m.record_search(&s);
        m.record_search(&s);
        let snap = m.snapshot();
        assert_eq!(snap.search_queries, 4);
        assert_eq!(snap.search_candidates, 40);
        assert_eq!(snap.lb_kim_skips, 10);
        assert_eq!(snap.early_abandons, 6);
        assert_eq!(snap.full_dp_evals, 12);
        assert_eq!(snap.visited_cells, 1240);
        assert!((snap.search_prune_ratio() - 0.7).abs() < 1e-12);
    }
}
