//! Coordinator metrics: lock-free counters plus a coarse log-scale
//! latency histogram; snapshots feed the CLI, the TCP `info` op and the
//! §Perf benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log2-bucketed latency histogram, 1µs .. ~1s.
const LAT_BUCKETS: usize = 22;

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub native_jobs: AtomicU64,
    pub pjrt_jobs: AtomicU64,
    pub batches: AtomicU64,
    /// Wasted slots from padding partial batches.
    pub padded_slots: AtomicU64,
    /// Flushes triggered by the timeout rather than a full batch.
    pub timeout_flushes: AtomicU64,
    pub visited_cells: AtomicU64,
    lat: [AtomicU64; LAT_BUCKETS],
    lat_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.lat[bucket].fetch_add(1, Ordering::Relaxed);
        self.lat_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let lat: Vec<u64> = self.lat.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            native_jobs: self.native_jobs.load(Ordering::Relaxed),
            pjrt_jobs: self.pjrt_jobs.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            timeout_flushes: self.timeout_flushes.load(Ordering::Relaxed),
            visited_cells: self.visited_cells.load(Ordering::Relaxed),
            mean_latency_us: if completed > 0 {
                self.lat_sum_us.load(Ordering::Relaxed) as f64 / completed as f64
            } else {
                0.0
            },
            latency_hist: lat,
        }
    }
}

/// A point-in-time copy of every counter.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub native_jobs: u64,
    pub pjrt_jobs: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub timeout_flushes: u64,
    pub visited_cells: u64,
    pub mean_latency_us: f64,
    pub latency_hist: Vec<u64>,
}

impl Snapshot {
    /// Approximate latency percentile from the log2 histogram (upper
    /// bucket bound, µs).
    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        let total: u64 = self.latency_hist.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.latency_hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (1u64 << i) as f64;
            }
        }
        (1u64 << (self.latency_hist.len() - 1)) as f64
    }

    pub fn report(&self) -> String {
        format!(
            "jobs: {} submitted, {} completed ({} native / {} pjrt), {} failed\n\
             batches: {} ({} padded slots, {} timeout flushes)\n\
             cells: {}\n\
             latency: mean {:.1} µs, p50 ≤ {:.0} µs, p99 ≤ {:.0} µs",
            self.submitted,
            self.completed,
            self.native_jobs,
            self.pjrt_jobs,
            self.failed,
            self.batches,
            self.padded_slots,
            self.timeout_flushes,
            self.visited_cells,
            self.mean_latency_us,
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(100));
        m.record_latency(Duration::from_micros(300));
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert!(s.mean_latency_us > 0.0);
        assert!(s.latency_percentile_us(50.0) >= 64.0);
    }

    #[test]
    fn percentile_monotone() {
        let m = Metrics::new();
        for us in [1u64, 10, 100, 1000, 10000] {
            m.record_latency(Duration::from_micros(us));
        }
        m.completed.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.latency_percentile_us(99.0) >= s.latency_percentile_us(50.0));
    }

    #[test]
    fn report_contains_sections() {
        let s = Metrics::new().snapshot();
        let r = s.report();
        assert!(r.contains("jobs:") && r.contains("batches:") && r.contains("latency:"));
    }
}
