//! Model store: learned LOC grids and search indexes registered with
//! the coordinator.  Each gets a stable key; when a PJRT engine is
//! attached, a grid's weight (f32, SP-DTW) and mask (f64, SP-K_rdtw)
//! planes are uploaded once at registration time and stay
//! device-resident.  Search indexes are always host-resident (the
//! cascade is branchy, pointer-light CPU work).

use std::collections::HashMap;
use std::sync::Arc;

use crate::search::Index;
use crate::sparse::LocMatrix;

/// Opaque registered-grid identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridKey(pub u64);

pub struct GridEntry {
    pub loc: Arc<LocMatrix>,
    /// Whether the planes were uploaded to the PJRT engine.
    pub on_device: bool,
}

#[derive(Default)]
pub struct GridRegistry {
    next: u64,
    grids: HashMap<u64, GridEntry>,
}

impl GridRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, loc: Arc<LocMatrix>, on_device: bool) -> GridKey {
        let key = self.next;
        self.next += 1;
        self.grids.insert(key, GridEntry { loc, on_device });
        GridKey(key)
    }

    pub fn get(&self, key: GridKey) -> Option<&GridEntry> {
        self.grids.get(&key.0)
    }

    pub fn set_on_device(&mut self, key: GridKey) {
        if let Some(e) = self.grids.get_mut(&key.0) {
            e.on_device = true;
        }
    }

    pub fn len(&self) -> usize {
        self.grids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grids.is_empty()
    }
}

/// Opaque registered-search-index identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct IndexKey(pub u64);

/// Registry of prebuilt [`Index`]es served by `submit_search`.
#[derive(Default)]
pub struct IndexRegistry {
    next: u64,
    indexes: HashMap<u64, Arc<Index>>,
}

impl IndexRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, index: Arc<Index>) -> IndexKey {
        let key = self.next;
        self.next += 1;
        self.indexes.insert(key, index);
        IndexKey(key)
    }

    pub fn get(&self, key: IndexKey) -> Option<Arc<Index>> {
        self.indexes.get(&key.0).map(Arc::clone)
    }

    pub fn len(&self) -> usize {
        self.indexes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indexes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_keys_are_unique_and_resolvable() {
        use crate::data::splits::from_pairs;
        let train = from_pairs(vec![(0, vec![0.0, 1.0]), (1, vec![1.0, 0.0])]);
        let mut r = IndexRegistry::new();
        let a = r.insert(Arc::new(Index::build(&train, 1, 1)));
        let b = r.insert(Arc::new(Index::build(&train, 2, 1)));
        assert_ne!(a, b);
        assert_eq!(r.get(a).unwrap().radius, 1);
        assert_eq!(r.len(), 2);
        assert!(r.get(IndexKey(17)).is_none());
    }

    #[test]
    fn keys_are_unique_and_resolvable() {
        let mut r = GridRegistry::new();
        let a = r.insert(Arc::new(LocMatrix::full(4)), false);
        let b = r.insert(Arc::new(LocMatrix::corridor(4, 1)), true);
        assert_ne!(a, b);
        assert_eq!(r.get(a).unwrap().loc.nnz(), 16);
        assert!(r.get(b).unwrap().on_device);
        assert_eq!(r.len(), 2);
        assert!(r.get(GridKey(99)).is_none());
    }
}
