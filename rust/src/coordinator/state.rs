//! Model store: learned LOC grids, search indexes and bound measures
//! registered with the coordinator.  Each gets a stable key; when a
//! PJRT engine is attached, a grid's weight (f32, SP-DTW) and mask
//! (f64, SP-K_rdtw) planes are uploaded once at registration time and
//! stay device-resident.  Search indexes and measures are always
//! host-resident (the cascade is branchy, pointer-light CPU work).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::measures::spec::MeasureSpec;
use crate::measures::{KernelMeasure, Measure};
use crate::search::Index;
use crate::sparse::LocMatrix;
use crate::stream::StreamMonitor;

/// Opaque registered-grid identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridKey(pub u64);

pub struct GridEntry {
    pub loc: Arc<LocMatrix>,
    /// Whether the planes were uploaded to the PJRT engine.
    pub on_device: bool,
}

#[derive(Default)]
pub struct GridRegistry {
    next: u64,
    grids: HashMap<u64, GridEntry>,
}

impl GridRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, loc: Arc<LocMatrix>, on_device: bool) -> GridKey {
        let key = self.next;
        self.next += 1;
        self.grids.insert(key, GridEntry { loc, on_device });
        GridKey(key)
    }

    pub fn get(&self, key: GridKey) -> Option<&GridEntry> {
        self.grids.get(&key.0)
    }

    pub fn set_on_device(&mut self, key: GridKey) {
        if let Some(e) = self.grids.get_mut(&key.0) {
            e.on_device = true;
        }
    }

    pub fn len(&self) -> usize {
        self.grids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grids.is_empty()
    }
}

/// Opaque registered-search-index identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct IndexKey(pub u64);

/// A registered [`Index`] plus its provenance.
pub struct IndexEntry {
    pub index: Arc<Index>,
    /// Stable registry name (persisted indexes only; anonymous
    /// registrations have none and never touch the store).
    pub name: Option<String>,
    /// Whether this entry was reloaded from the on-disk store at boot
    /// rather than built in this process — surfaced in the TCP
    /// `register_index` reply so clients can tell a warm hit from a
    /// cold build.
    pub loaded_from_disk: bool,
    /// Local→global train-index map for sharded registrations
    /// (strictly increasing; see `crate::shard` for why).  `None` for
    /// ordinary single-node indexes — `shard_search` refuses those.
    pub global_ids: Option<Arc<Vec<usize>>>,
}

/// Registry of prebuilt [`Index`]es served by `submit_search`.
#[derive(Default)]
pub struct IndexRegistry {
    next: u64,
    indexes: HashMap<u64, IndexEntry>,
    by_name: HashMap<String, u64>,
    /// Named-entry recency, least-recently-used first.  Touched on
    /// registration and named lookup; drives the index-store LRU
    /// eviction (`CoordinatorConfig::index_store_max_bytes`).
    recency: Vec<String>,
}

impl IndexRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an anonymous (in-memory only) index.
    pub fn insert(&mut self, index: Arc<Index>) -> IndexKey {
        self.insert_entry(IndexEntry {
            index,
            name: None,
            loaded_from_disk: false,
            global_ids: None,
        })
    }

    /// Register an anonymous shard slice with its local→global index
    /// map (one global id per train series, strictly increasing —
    /// validated at the wire before this is called).
    pub fn insert_sharded(&mut self, index: Arc<Index>, global_ids: Vec<usize>) -> IndexKey {
        // The exact-merge argument in `crate::shard` needs local order
        // to agree with global order; the wire validator enforces it,
        // this re-checks any future non-wire caller.
        debug_assert!(
            global_ids.windows(2).all(|w| w[0] < w[1]),
            "sharded global_ids must be strictly increasing"
        );
        self.insert_entry(IndexEntry {
            index,
            name: None,
            loaded_from_disk: false,
            global_ids: Some(Arc::new(global_ids)),
        })
    }

    /// Register under a stable name (replacing any previous holder of
    /// that name — the newest build wins, mirroring the on-disk store).
    pub fn insert_named(
        &mut self,
        name: &str,
        index: Arc<Index>,
        loaded_from_disk: bool,
    ) -> IndexKey {
        let key = self.insert_entry(IndexEntry {
            index,
            name: Some(name.to_string()),
            loaded_from_disk,
            global_ids: None,
        });
        if let Some(old) = self.by_name.insert(name.to_string(), key.0) {
            self.indexes.remove(&old);
        }
        self.touch(name);
        key
    }

    /// Mark `name` most-recently-used (no-op for unknown names).
    pub fn touch(&mut self, name: &str) {
        self.recency.retain(|n| n != name);
        if self.by_name.contains_key(name) {
            self.recency.push(name.to_string());
        }
    }

    /// Named entries, least-recently-used first.
    pub fn lru_names(&self) -> &[String] {
        &self.recency
    }

    /// Forget a name's recency record (store eviction bookkeeping; the
    /// in-memory entry itself stays registered and servable).
    pub fn forget_recency(&mut self, name: &str) {
        self.recency.retain(|n| n != name);
    }

    fn insert_entry(&mut self, entry: IndexEntry) -> IndexKey {
        let key = self.next;
        self.next += 1;
        self.indexes.insert(key, entry);
        IndexKey(key)
    }

    pub fn get(&self, key: IndexKey) -> Option<Arc<Index>> {
        self.indexes.get(&key.0).map(|e| Arc::clone(&e.index))
    }

    pub fn get_entry(&self, key: IndexKey) -> Option<&IndexEntry> {
        self.indexes.get(&key.0)
    }

    pub fn key_by_name(&self, name: &str) -> Option<IndexKey> {
        self.by_name.get(name).copied().map(IndexKey)
    }

    pub fn len(&self) -> usize {
        self.indexes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indexes.is_empty()
    }
}

/// Opaque registered-measure identifier (the wire's `register_measure`
/// reply; referenced by number in later `dist`/`kernel` ops).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MeasureKey(pub u64);

/// What a [`MeasureSpec`] bound to: a distance or a kernel object with
/// its grids resolved once at registration time.
pub enum BuiltMeasure {
    Dist(Arc<dyn Measure>),
    Kernel(Arc<dyn KernelMeasure>),
}

/// A registered measure: the originating spec (kept for routing — an
/// SP-DTW spec over a registered grid still goes through the PJRT
/// path) plus the pre-bound object and its operand-length requirement.
pub struct MeasureEntry {
    pub spec: MeasureSpec,
    pub built: BuiltMeasure,
    /// Required operand length (grid-bound measures); `None` = any
    /// length the measure itself accepts.
    pub required_len: Option<usize>,
}

/// Registry of measures bound via `register_measure`.
#[derive(Default)]
pub struct MeasureRegistry {
    next: u64,
    entries: HashMap<u64, Arc<MeasureEntry>>,
}

impl MeasureRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, entry: MeasureEntry) -> MeasureKey {
        let key = self.next;
        self.next += 1;
        self.entries.insert(key, Arc::new(entry));
        MeasureKey(key)
    }

    /// Insert at a specific key — the warm-start replay path, which
    /// must keep the keys clients registered before the restart.  The
    /// next sequential key is bumped past `key` so later live
    /// registrations never collide with replayed ones.
    pub fn insert_at(&mut self, key: MeasureKey, entry: MeasureEntry) {
        self.entries.insert(key.0, Arc::new(entry));
        self.reserve_past(key);
    }

    /// Reserve past `key` without inserting — used when a persisted
    /// measure fails to re-bind at boot: its key must stay dead rather
    /// than be handed out again to the next live registration (a stale
    /// client would silently get a different measure).
    pub fn reserve_past(&mut self, key: MeasureKey) {
        self.next = self.next.max(key.0 + 1);
    }

    pub fn get(&self, key: MeasureKey) -> Option<Arc<MeasureEntry>> {
        self.entries.get(&key.0).map(Arc::clone)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Opaque stream-session identifier (the wire's `stream_open` reply;
/// referenced by number in later `stream_push`/`stream_matches`/
/// `stream_close` ops).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StreamKey(pub u64);

/// One open streaming session: the monitor plus its idle-eviction
/// bookkeeping.  Lives behind `Arc<Mutex<..>>` so the registry lock is
/// held only for lookup, never across a window evaluation.
pub struct StreamSession {
    pub monitor: StreamMonitor,
    /// Last wire activity — refreshed by every `stream_*` op that
    /// resolves the session.
    pub last_active: Instant,
    /// Idle budget before the sweep reclaims the session.
    pub idle_timeout: Duration,
}

impl StreamSession {
    pub fn new(monitor: StreamMonitor, idle_timeout: Duration) -> StreamSession {
        StreamSession {
            monitor,
            last_active: Instant::now(),
            idle_timeout,
        }
    }

    pub fn touch(&mut self) {
        self.last_active = Instant::now();
    }

    pub fn idle_expired(&self, now: Instant) -> bool {
        now.saturating_duration_since(self.last_active) >= self.idle_timeout
    }
}

/// Registry of open streaming sessions.
#[derive(Default)]
pub struct StreamRegistry {
    next: u64,
    entries: HashMap<u64, Arc<Mutex<StreamSession>>>,
}

impl StreamRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, session: StreamSession) -> StreamKey {
        let key = self.next;
        self.next += 1;
        self.entries.insert(key, Arc::new(Mutex::new(session)));
        StreamKey(key)
    }

    pub fn get(&self, key: StreamKey) -> Option<Arc<Mutex<StreamSession>>> {
        self.entries.get(&key.0).map(Arc::clone)
    }

    pub fn remove(&mut self, key: StreamKey) -> Option<Arc<Mutex<StreamSession>>> {
        self.entries.remove(&key.0)
    }

    /// Reclaim sessions idle past their budget; returns how many were
    /// evicted.  A session whose mutex is currently held is mid-request
    /// — by definition not idle — and is skipped rather than awaited,
    /// so the sweep never blocks behind a long window evaluation.
    pub fn sweep_idle(&mut self, now: Instant) -> usize {
        let before = self.entries.len();
        self.entries.retain(|_, s| match s.try_lock() {
            Ok(sess) => !sess.idle_expired(now),
            Err(_) => true,
        });
        before - self.entries.len()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_keys_are_unique_and_resolvable() {
        let mut r = MeasureRegistry::new();
        let a = r.insert(MeasureEntry {
            spec: MeasureSpec::Dtw,
            built: BuiltMeasure::Dist(Arc::new(crate::measures::dtw::Dtw)),
            required_len: None,
        });
        let b = r.insert(MeasureEntry {
            spec: MeasureSpec::Krdtw { nu: 1.0, band_cells: None },
            built: BuiltMeasure::Kernel(Arc::new(crate::measures::krdtw::Krdtw::new(1.0))),
            required_len: Some(16),
        });
        assert_ne!(a, b);
        assert_eq!(r.len(), 2);
        assert_eq!(r.get(a).unwrap().spec, MeasureSpec::Dtw);
        assert_eq!(r.get(b).unwrap().required_len, Some(16));
        assert!(r.get(MeasureKey(99)).is_none());
    }

    #[test]
    fn index_keys_are_unique_and_resolvable() {
        use crate::data::splits::from_pairs;
        let train = from_pairs(vec![(0, vec![0.0, 1.0]), (1, vec![1.0, 0.0])]);
        let mut r = IndexRegistry::new();
        let a = r.insert(Arc::new(Index::build(&train, 1, 1)));
        let b = r.insert(Arc::new(Index::build(&train, 2, 1)));
        assert_ne!(a, b);
        assert_eq!(r.get(a).unwrap().radius, 1);
        assert_eq!(r.len(), 2);
        assert!(r.get(IndexKey(17)).is_none());
    }

    #[test]
    fn named_entries_resolve_and_replace() {
        use crate::data::splits::from_pairs;
        let train = from_pairs(vec![(0, vec![0.0, 1.0]), (1, vec![1.0, 0.0])]);
        let mut r = IndexRegistry::new();
        let a = r.insert_named("cbf", Arc::new(Index::build(&train, 1, 1)), true);
        assert_eq!(r.key_by_name("cbf"), Some(a));
        assert!(r.get_entry(a).unwrap().loaded_from_disk);
        assert_eq!(r.get_entry(a).unwrap().name.as_deref(), Some("cbf"));

        // re-registering the name replaces the old entry
        let b = r.insert_named("cbf", Arc::new(Index::build(&train, 2, 1)), false);
        assert_ne!(a, b);
        assert_eq!(r.key_by_name("cbf"), Some(b));
        assert!(r.get(a).is_none(), "stale key must not resolve");
        assert!(!r.get_entry(b).unwrap().loaded_from_disk);
        assert_eq!(r.len(), 1);
        assert_eq!(r.key_by_name("other"), None);
    }

    #[test]
    fn recency_tracks_lru_order() {
        use crate::data::splits::from_pairs;
        let train = from_pairs(vec![(0, vec![0.0, 1.0]), (1, vec![1.0, 0.0])]);
        let idx = || Arc::new(Index::build(&train, 1, 1));
        let lru = |r: &IndexRegistry| -> Vec<String> { r.lru_names().to_vec() };
        let mut r = IndexRegistry::new();
        r.insert_named("a", idx(), false);
        r.insert_named("b", idx(), false);
        r.insert_named("c", idx(), false);
        assert_eq!(lru(&r), ["a", "b", "c"]);
        // touching moves to most-recent; unknown names are ignored
        r.touch("a");
        r.touch("ghost");
        assert_eq!(lru(&r), ["b", "c", "a"]);
        // re-registration also refreshes recency
        r.insert_named("b", idx(), false);
        assert_eq!(lru(&r), ["c", "a", "b"]);
        r.forget_recency("a");
        assert_eq!(lru(&r), ["c", "b"]);
        // forgetting recency does not unregister the entry
        assert!(r.key_by_name("a").is_some());
    }

    #[test]
    fn stream_sessions_register_resolve_and_sweep() {
        use crate::data::splits::from_pairs;
        use crate::search::{Cascade, SearchEngine};
        let train = from_pairs(vec![(0, vec![0.0, 1.0, 2.0]), (1, vec![2.0, 1.0, 0.0])]);
        let engine = SearchEngine::new(Arc::new(Index::build(&train, 1, 1)), Cascade::default());
        let mk = |timeout: Duration| {
            StreamSession::new(
                StreamMonitor::new(engine.clone(), 1, None).unwrap(),
                timeout,
            )
        };
        let mut r = StreamRegistry::new();
        let a = r.insert(mk(Duration::from_secs(3600)));
        let b = r.insert(mk(Duration::ZERO));
        assert_ne!(a, b);
        assert_eq!(r.len(), 2);
        assert!(r.get(a).is_some());
        assert!(r.get(StreamKey(99)).is_none());

        // only the zero-budget session is idle-expired
        assert_eq!(r.sweep_idle(Instant::now()), 1);
        assert!(r.get(b).is_none(), "expired session must be reclaimed");
        assert!(r.get(a).is_some());

        // a locked (mid-request) session is never swept
        let held = r.get(a).unwrap();
        let guard = held.lock().unwrap();
        assert_eq!(r.sweep_idle(Instant::now() + Duration::from_secs(7200)), 0);
        drop(guard);
        assert_eq!(r.sweep_idle(Instant::now() + Duration::from_secs(7200)), 1);
        assert!(r.is_empty());

        // removal resolves to the session and frees the key
        let mut r2 = StreamRegistry::new();
        let k = r2.insert(mk(Duration::from_secs(1)));
        assert!(r2.remove(k).is_some());
        assert!(r2.remove(k).is_none());
    }

    #[test]
    fn keys_are_unique_and_resolvable() {
        let mut r = GridRegistry::new();
        let a = r.insert(Arc::new(LocMatrix::full(4)), false);
        let b = r.insert(Arc::new(LocMatrix::corridor(4, 1)), true);
        assert_ne!(a, b);
        assert_eq!(r.get(a).unwrap().loc.nnz(), 16);
        assert!(r.get(b).unwrap().on_device);
        assert_eq!(r.len(), 2);
        assert!(r.get(GridKey(99)).is_none());
    }
}
