//! Model store: learned LOC grids registered with the coordinator.
//! Each grid gets a stable key; when a PJRT engine is attached, its
//! weight (f32, SP-DTW) and mask (f64, SP-K_rdtw) planes are uploaded
//! once at registration time and stay device-resident.

use std::collections::HashMap;
use std::sync::Arc;

use crate::sparse::LocMatrix;

/// Opaque registered-grid identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridKey(pub u64);

pub struct GridEntry {
    pub loc: Arc<LocMatrix>,
    /// Whether the planes were uploaded to the PJRT engine.
    pub on_device: bool,
}

#[derive(Default)]
pub struct GridRegistry {
    next: u64,
    grids: HashMap<u64, GridEntry>,
}

impl GridRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, loc: Arc<LocMatrix>, on_device: bool) -> GridKey {
        let key = self.next;
        self.next += 1;
        self.grids.insert(key, GridEntry { loc, on_device });
        GridKey(key)
    }

    pub fn get(&self, key: GridKey) -> Option<&GridEntry> {
        self.grids.get(&key.0)
    }

    pub fn set_on_device(&mut self, key: GridKey) {
        if let Some(e) = self.grids.get_mut(&key.0) {
            e.on_device = true;
        }
    }

    pub fn len(&self) -> usize {
        self.grids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_unique_and_resolvable() {
        let mut r = GridRegistry::new();
        let a = r.insert(Arc::new(LocMatrix::full(4)), false);
        let b = r.insert(Arc::new(LocMatrix::corridor(4, 1)), true);
        assert_ne!(a, b);
        assert_eq!(r.get(a).unwrap().loc.nnz(), 16);
        assert!(r.get(b).unwrap().on_device);
        assert_eq!(r.len(), 2);
        assert!(r.get(GridKey(99)).is_none());
    }
}
