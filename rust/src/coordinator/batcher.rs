//! Length-bucket dynamic batcher (pure logic; the dispatcher thread in
//! `mod.rs` drives it).  Jobs accumulate per [`BucketKey`]; a bucket is
//! flushed when it reaches the artifact batch size or when its oldest
//! job exceeds the flush timeout.  Partial batches are padded by
//! repeating the last pair (the executable has a fixed B); padded slots
//! are dropped on unpack and counted in the metrics.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::coordinator::request::{BucketKey, PjrtJob};

/// A batch ready for the PJRT runner.
pub(crate) struct ReadyBatch {
    pub bucket: BucketKey,
    /// Row-major (B, T) in f64 (cast at the runtime boundary).
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    /// Real (unpadded) jobs; `xs` may contain `batch_size` rows.
    pub jobs: Vec<PjrtJob>,
    pub padded: usize,
    pub by_timeout: bool,
}

struct Pending {
    jobs: Vec<PjrtJob>,
    oldest: Instant,
}

/// Accumulates jobs into per-bucket buffers.
pub(crate) struct Batcher {
    batch_size_of: Box<dyn Fn(&BucketKey) -> usize + Send>,
    flush_after: Duration,
    pending: HashMap<BucketKey, Pending>,
}

impl Batcher {
    pub fn new(
        batch_size_of: Box<dyn Fn(&BucketKey) -> usize + Send>,
        flush_after: Duration,
    ) -> Self {
        Batcher {
            batch_size_of,
            flush_after,
            pending: HashMap::new(),
        }
    }

    /// Add a job; returns a full batch if the bucket reached its size.
    pub fn push(&mut self, job: PjrtJob, now: Instant) -> Option<ReadyBatch> {
        let bucket = job.bucket;
        let entry = self.pending.entry(bucket).or_insert_with(|| Pending {
            jobs: Vec::new(),
            oldest: now,
        });
        if entry.jobs.is_empty() {
            entry.oldest = now;
        }
        entry.jobs.push(job);
        let cap = (self.batch_size_of)(&bucket);
        if entry.jobs.len() >= cap {
            let pending = self.pending.remove(&bucket).unwrap();
            Some(Self::materialize(bucket, pending.jobs, cap, false))
        } else {
            None
        }
    }

    /// Flush buckets whose oldest job is older than the timeout.
    pub fn flush_stale(&mut self, now: Instant) -> Vec<ReadyBatch> {
        let stale: Vec<BucketKey> = self
            .pending
            .iter()
            .filter(|(_, p)| !p.jobs.is_empty() && now.duration_since(p.oldest) >= self.flush_after)
            .map(|(k, _)| *k)
            .collect();
        stale
            .into_iter()
            .map(|k| {
                let p = self.pending.remove(&k).unwrap();
                let cap = (self.batch_size_of)(&k);
                Self::materialize(k, p.jobs, cap, true)
            })
            .collect()
    }

    /// Flush everything (shutdown path).
    pub fn flush_all(&mut self) -> Vec<ReadyBatch> {
        let keys: Vec<BucketKey> = self.pending.keys().copied().collect();
        keys.into_iter()
            .filter_map(|k| {
                let p = self.pending.remove(&k)?;
                if p.jobs.is_empty() {
                    return None;
                }
                let cap = (self.batch_size_of)(&k);
                Some(Self::materialize(k, p.jobs, cap, true))
            })
            .collect()
    }

    /// Time until the next stale flush is due (for the dispatcher's
    /// recv_timeout), if any bucket is pending.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending
            .values()
            .filter(|p| !p.jobs.is_empty())
            .map(|p| {
                self.flush_after
                    .checked_sub(now.duration_since(p.oldest))
                    .unwrap_or(Duration::ZERO)
            })
            .min()
    }

    /// Jobs accumulated but not yet flushed, across all buckets — the
    /// dispatcher publishes this as the `batcher_queue_depth` gauge
    /// after every event, so the metrics snapshot exposes how much
    /// work sits in partial batches at any instant.
    pub fn pending_jobs(&self) -> usize {
        self.pending.values().map(|p| p.jobs.len()).sum()
    }

    fn materialize(
        bucket: BucketKey,
        jobs: Vec<PjrtJob>,
        cap: usize,
        by_timeout: bool,
    ) -> ReadyBatch {
        let t = bucket.t;
        let n = jobs.len();
        assert!(n >= 1 && n <= cap);
        let mut xs = Vec::with_capacity(cap * t);
        let mut ys = Vec::with_capacity(cap * t);
        for j in &jobs {
            debug_assert_eq!(j.x.len(), t);
            debug_assert_eq!(j.y.len(), t);
            xs.extend_from_slice(&j.x);
            ys.extend_from_slice(&j.y);
        }
        // pad by repeating the last pair
        let padded = cap - n;
        for _ in 0..padded {
            let last = &jobs[n - 1];
            xs.extend_from_slice(&last.x);
            ys.extend_from_slice(&last.y);
        }
        ReadyBatch {
            bucket,
            xs,
            ys,
            jobs,
            padded,
            by_timeout,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::KernelKind;
    use std::sync::mpsc;

    fn job(t: usize, key: u64, v: f64) -> PjrtJob {
        let (tx, _rx) = mpsc::channel();
        // keep rx alive via leak-free: tests that need responses build
        // their own channels; here the sender is enough.
        std::mem::forget(_rx);
        PjrtJob {
            bucket: BucketKey {
                kind: KernelKind::Dtw,
                t,
                plane_key: key,
                nu_bits: 0,
            },
            x: vec![v; t],
            y: vec![-v; t],
            cells: 1,
            resp: tx,
        }
    }

    fn batcher(cap: usize) -> Batcher {
        Batcher::new(Box::new(move |_| cap), Duration::from_millis(5))
    }

    #[test]
    fn full_bucket_flushes_exactly_at_cap() {
        let mut b = batcher(3);
        let now = Instant::now();
        assert!(b.push(job(4, 1, 1.0), now).is_none());
        assert!(b.push(job(4, 1, 2.0), now).is_none());
        let ready = b.push(job(4, 1, 3.0), now).expect("flush at cap");
        assert_eq!(ready.jobs.len(), 3);
        assert_eq!(ready.padded, 0);
        assert!(!ready.by_timeout);
        assert_eq!(ready.xs.len(), 3 * 4);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn buckets_never_mix() {
        let mut b = batcher(2);
        let now = Instant::now();
        assert!(b.push(job(4, 1, 1.0), now).is_none());
        assert!(b.push(job(4, 2, 2.0), now).is_none()); // different plane
        assert!(b.push(job(8, 1, 3.0), now).is_none()); // different T
        assert_eq!(b.pending_jobs(), 3);
        let ready = b.push(job(4, 1, 4.0), now).unwrap();
        assert!(ready.jobs.iter().all(|j| j.bucket.plane_key == 1 && j.bucket.t == 4));
    }

    #[test]
    fn stale_flush_pads() {
        let mut b = batcher(4);
        let t0 = Instant::now();
        assert!(b.push(job(4, 1, 1.0), t0).is_none());
        let later = t0 + Duration::from_millis(10);
        let ready = b.flush_stale(later);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].jobs.len(), 1);
        assert_eq!(ready[0].padded, 3);
        assert!(ready[0].by_timeout);
        // padded rows replicate the last pair
        assert_eq!(ready[0].xs, vec![1.0; 16]);
    }

    #[test]
    fn not_stale_before_deadline() {
        let mut b = batcher(4);
        let t0 = Instant::now();
        b.push(job(4, 1, 1.0), t0);
        assert!(b.flush_stale(t0 + Duration::from_millis(1)).is_empty());
        assert!(b.next_deadline(t0).unwrap() <= Duration::from_millis(5));
    }

    #[test]
    fn flush_all_drains() {
        let mut b = batcher(4);
        let now = Instant::now();
        b.push(job(4, 1, 1.0), now);
        b.push(job(8, 2, 2.0), now);
        let all = b.flush_all();
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending_jobs(), 0);
    }
}
