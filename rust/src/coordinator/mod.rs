//! The L3 coordinator: a batched distance-computation service.
//!
//! Architecture (no tokio in the vendored set — std threads + channels,
//! DESIGN.md §2):
//!
//! ```text
//!  submit_*()              dispatcher thread            pjrt runner
//!  ────────► dispatch ──► Batcher (per-bucket) ──► queue ──► PjrtHandle
//!      │                        │ full/stale flush                    (executor thread)
//!      │                        ▼
//!      └──────► native WorkerPool (backpressured)  ──► response channels
//! ```
//!
//! * Jobs are routed per (kernel, T) by [`router::Router`] — PJRT when an
//!   artifact bucket exists and `prefer_pjrt` is set, native otherwise.
//! * k-NN `Search` requests resolve against a registered
//!   [`crate::search::Index`] on the native pool, with per-stage prune
//!   counters exported through [`metrics`].
//! * Batch requests (`submit_batch_search`, `submit_train_gram`) each
//!   fan out as their own compute-pool **epoch**: the concurrent-epoch
//!   scheduler in [`crate::pool`] lets N clients' requests overlap on
//!   the shared worker set instead of serializing behind a global
//!   submit lock.  Queue depth and request concurrency are exported in
//!   the metrics snapshot (`requests_inflight`,
//!   `peak_concurrent_requests`, `pool`, `native_queue_depth`).
//! * PJRT jobs accumulate in per-[`BucketKey`] buffers; flushed at the
//!   artifact batch size or after `flush_us` of inactivity (padded).
//! * The bounded runner queue (`queue_cap`) provides backpressure.
//! * Every submitted job is answered exactly once (property-tested in
//!   `rust/tests/prop_invariants.rs`).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod state;

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::classify::gram::train_gram;
use crate::config::{CoordinatorConfig, ShardRole};
use crate::data::{LabeledSet, TimeSeries};
use crate::error::{Error, Result};
use crate::measures::spdtw::SpDtw;
use crate::measures::spec::{self, GridResolver, GridSpec, KernelDist, MeasureSpec};
use crate::measures::spkrdtw::SpKrdtw;
use crate::measures::{KernelMeasure, Measure};
use crate::pool::WorkerPool;
use crate::runtime::{
    load_measure_specs, record_index_artifact, record_measure_spec, remove_index_artifact,
    touch_index_artifact, DtwBatch, KernelKind, KrdtwBatch, Manifest, PjrtHandle,
};
use crate::search::{persist, Cascade, Index, SearchEngine};
use crate::sparse::LocMatrix;
use crate::stream::{MatchReport, RwsConfig, StreamMonitor, StreamStats};

use batcher::{Batcher, ReadyBatch};
use metrics::{Metrics, Snapshot};
use request::{
    Backend, BatchSearchTicket, BucketKey, Deadline, GramTicket, JobTicket, PairResult, PjrtJob,
    SearchOutcome, SearchTicket,
};
use router::Router;
use state::{
    BuiltMeasure, GridKey, GridRegistry, IndexKey, IndexRegistry, MeasureEntry, MeasureKey,
    MeasureRegistry, StreamKey, StreamRegistry, StreamSession,
};

enum DispatchMsg {
    Job(Box<PjrtJob>, Instant),
    Drain(mpsc::Sender<()>),
}

/// Upper bound on `register_measure` entries: registered measures are
/// never evicted (their keys must stay resolvable), and each may pin a
/// resolved LOC grid — without a cap, a wire client looping
/// `register_measure` accumulates unbounded memory.  Far above any
/// legitimate working set; inline specs in `dist`/`kernel` ops remain
/// unlimited (they bind per request and are dropped after it).
pub const MAX_REGISTERED_MEASURES: usize = 1024;

/// Upper bound on simultaneously open streaming sessions: each pins a
/// [`StreamMonitor`] (DP workspace + optional RWS embedding of the
/// whole corpus), so an unbounded registry would let a looping
/// `stream_open` client accumulate unbounded memory.  Idle sessions are
/// reclaimed by the sweep; well below the measure cap because sessions
/// are per-client state, not shared models.
pub const MAX_STREAM_SESSIONS: usize = 64;

/// Idle budget applied to streaming sessions whose `stream_open` did
/// not set one: five minutes without any `stream_*` op reclaims the
/// session.
pub const DEFAULT_STREAM_IDLE_MS: u64 = 300_000;

/// What one [`Coordinator::stream_push`] ingested.
#[derive(Clone, Copy, Debug)]
pub struct StreamPushOutcome {
    /// Samples accepted.  On a deadline or bad-sample error the prefix
    /// before the failure stays ingested (the session is consistent up
    /// to it) but the call reports the error instead of this outcome.
    pub pushed: u64,
    /// Windows that completed — and were searched — during this push.
    pub windows: u64,
    /// Whether the session has seen at least one full window.
    pub ready: bool,
}

/// Snapshot returned by [`Coordinator::stream_matches`].
#[derive(Clone, Debug)]
pub struct StreamMatchesOutcome {
    /// Latest per-window report (`None` until the first full window).
    pub report: Option<MatchReport>,
    /// Whether the session routes through the RWS approximate
    /// pre-filter (the flag is session-level: an approximate session
    /// can never be mistaken for the exact default).
    pub approx: bool,
    /// Cumulative session statistics.
    pub stats: StreamStats,
}

/// The coordinator service.  Create with [`Coordinator::start`]; dropped
/// coordinators drain and join all threads.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
    native_pool: WorkerPool,
    dispatch_tx: Option<mpsc::Sender<DispatchMsg>>,
    dispatcher: Option<thread::JoinHandle<()>>,
    runner: Option<thread::JoinHandle<()>>,
    router: Router,
    grids: Mutex<GridRegistry>,
    indexes: Mutex<IndexRegistry>,
    measures: Mutex<MeasureRegistry>,
    streams: Mutex<StreamRegistry>,
    pjrt: Option<PjrtHandle>,
}

/// [`GridResolver`] over the coordinator's grid registry: `registered`
/// references resolve against [`Coordinator::register_grid`] keys,
/// inline `full`/`corridor` grids materialize directly, and `learned`
/// grids are rejected (the wire has no train set to learn from).
struct CoordinatorGrids<'a>(&'a Coordinator);

impl GridResolver for CoordinatorGrids<'_> {
    fn resolve(&self, grid: &GridSpec) -> Result<Arc<LocMatrix>> {
        if let Some(loc) = spec::materialize_inline(grid)? {
            return Ok(loc);
        }
        match grid {
            GridSpec::Registered { key } => self.0.grid(GridKey(*key)),
            GridSpec::Learned { .. } => Err(Error::config(
                "learned grids need a train set; learn the LOC grid client-side and \
                 register it (or send an inline grid)",
            )),
            _ => unreachable!("inline kinds handled above"),
        }
    }
}

impl Coordinator {
    /// Start the service.  `pjrt` is optional: without it every job runs
    /// on the native backend.
    pub fn start(cfg: CoordinatorConfig, pjrt: Option<PjrtHandle>) -> Result<Coordinator> {
        cfg.validate()?;
        let metrics = Arc::new(Metrics::new());
        let info = match &pjrt {
            Some(h) => Some(h.info()?),
            None => None,
        };
        let router = Router::new(info, cfg.prefer_pjrt);
        let native_pool = WorkerPool::new(cfg.workers, cfg.queue_cap.max(cfg.workers) * 4);

        // ---- warm start: reload persisted indexes from the store -------
        let mut index_reg = IndexRegistry::new();
        if cfg.warm_start {
            if let Some(dir) = &cfg.index_store {
                warm_start_indexes(dir, &mut index_reg, &metrics);
            }
        }

        // dispatcher -> runner bounded queue (backpressure on batches)
        let (batch_tx, batch_rx) = mpsc::sync_channel::<ReadyBatch>(cfg.queue_cap);
        let (dispatch_tx, dispatch_rx) = mpsc::channel::<DispatchMsg>();

        // ---- pjrt runner thread -----------------------------------------
        let runner = match &pjrt {
            Some(handle) => {
                let handle = handle.clone();
                let metrics2 = Arc::clone(&metrics);
                Some(
                    thread::Builder::new()
                        .name("spdtw-pjrt-runner".into())
                        .spawn(move || {
                            while let Ok(batch) = batch_rx.recv() {
                                run_batch(&handle, batch, &metrics2);
                            }
                        })?,
                )
            }
            None => {
                drop(batch_rx);
                None
            }
        };

        // ---- dispatcher thread -------------------------------------------
        let dispatcher = {
            let flush = Duration::from_micros(cfg.flush_us);
            let router2 = router.clone();
            let metrics2 = Arc::clone(&metrics);
            let batch_tx = batch_tx;
            Some(
                thread::Builder::new()
                    .name("spdtw-dispatcher".into())
                    .spawn(move || {
                        let mut batcher = Batcher::new(
                            Box::new(move |k: &BucketKey| {
                                router2.batch_size(k.kind, k.t).unwrap_or(1)
                            }),
                            flush,
                        );
                        loop {
                            // publish the partial-batch queue depth so
                            // snapshots see dispatcher backlog live
                            metrics2
                                .batcher_queue_depth
                                .store(batcher.pending_jobs() as u64, Ordering::Relaxed);
                            let now = Instant::now();
                            let timeout = batcher.next_deadline(now).unwrap_or(flush);
                            match dispatch_rx.recv_timeout(timeout) {
                                Ok(DispatchMsg::Job(job, at)) => {
                                    if let Some(ready) = batcher.push(*job, at) {
                                        metrics2.batches.fetch_add(1, Ordering::Relaxed);
                                        metrics2
                                            .padded_slots
                                            .fetch_add(ready.padded as u64, Ordering::Relaxed);
                                        if batch_tx.send(ready).is_err() {
                                            break;
                                        }
                                    }
                                }
                                Ok(DispatchMsg::Drain(ack)) => {
                                    for ready in batcher.flush_all() {
                                        metrics2.batches.fetch_add(1, Ordering::Relaxed);
                                        metrics2
                                            .padded_slots
                                            .fetch_add(ready.padded as u64, Ordering::Relaxed);
                                        if ready.by_timeout {
                                            metrics2
                                                .timeout_flushes
                                                .fetch_add(1, Ordering::Relaxed);
                                        }
                                        if batch_tx.send(ready).is_err() {
                                            break;
                                        }
                                    }
                                    let _ = ack.send(());
                                }
                                Err(mpsc::RecvTimeoutError::Timeout) => {
                                    for ready in batcher.flush_stale(Instant::now()) {
                                        metrics2.batches.fetch_add(1, Ordering::Relaxed);
                                        metrics2
                                            .padded_slots
                                            .fetch_add(ready.padded as u64, Ordering::Relaxed);
                                        if ready.by_timeout {
                                            metrics2
                                                .timeout_flushes
                                                .fetch_add(1, Ordering::Relaxed);
                                        }
                                        if batch_tx.send(ready).is_err() {
                                            break;
                                        }
                                    }
                                }
                                Err(mpsc::RecvTimeoutError::Disconnected) => {
                                    // drain leftovers, then stop
                                    for ready in batcher.flush_all() {
                                        let _ = batch_tx.send(ready);
                                    }
                                    break;
                                }
                            }
                        }
                    })?,
            )
        };

        let coord = Coordinator {
            cfg,
            metrics,
            native_pool,
            dispatch_tx: Some(dispatch_tx),
            dispatcher,
            runner,
            router,
            grids: Mutex::new(GridRegistry::new()),
            indexes: Mutex::new(index_reg),
            measures: Mutex::new(MeasureRegistry::new()),
            streams: Mutex::new(StreamRegistry::new()),
            pjrt,
        };
        // Measures replay after construction (binding needs the grid
        // resolver, i.e. a &Coordinator), alongside the index warm start.
        if coord.cfg.warm_start {
            if let Some(dir) = coord.cfg.index_store.clone() {
                coord.replay_measures(&dir);
            }
        }
        Ok(coord)
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Register a learned LOC grid.  Uploads its planes to the PJRT
    /// engine when one is attached and an artifact bucket exists for its
    /// length.
    pub fn register_grid(&self, loc: LocMatrix) -> Result<GridKey> {
        let loc = Arc::new(loc);
        let t = loc.t;
        let mut on_device = false;
        // Reserve the key first so plane keys match the grid key.
        let key = {
            let mut reg = self.grids.lock().unwrap();
            reg.insert(Arc::clone(&loc), false)
        };
        if let Some(h) = &self.pjrt {
            if self.router.has_bucket(KernelKind::Dtw, t) {
                h.register_plane_f32(key.0, t, loc.pack_weight_plane_f32())?;
                on_device = true;
            }
            if self.router.has_bucket(KernelKind::Krdtw, t) {
                h.register_plane_f64(key.0, t, loc.pack_mask_plane_f64())?;
                on_device = true;
            }
        }
        if on_device {
            self.grids.lock().unwrap().set_on_device(key);
        }
        Ok(key)
    }

    fn grid(&self, key: GridKey) -> Result<Arc<LocMatrix>> {
        self.grids
            .lock()
            .unwrap()
            .get(key)
            .map(|e| Arc::clone(&e.loc))
            .ok_or_else(|| Error::not_found("grid key", key.0.to_string()))
    }

    /// Bind a [`MeasureSpec`] once — parameters validated, grids
    /// resolved against the registry — and register it under a stable
    /// key for later [`Self::submit_dist_key`] / [`Self::submit_kernel_key`]
    /// calls (the TCP `register_measure` op).
    pub fn register_measure(&self, mspec: &MeasureSpec) -> Result<MeasureKey> {
        let (built, required_len) = self.bind_measure(mspec)?;
        // cap check and insert under ONE guard (the expensive binding
        // above stays outside the lock): entries are never evicted, so
        // without this bound a wire client looping register_measure
        // over large inline grids accumulates unbounded memory — and a
        // check-then-insert across two lock acquisitions would let
        // concurrent registrations overshoot the cap
        let mut reg = self.measures.lock().unwrap();
        if reg.len() >= MAX_REGISTERED_MEASURES {
            return Err(Error::config(format!(
                "measure registry full ({MAX_REGISTERED_MEASURES} entries); \
                 reuse registered keys or send inline specs"
            )));
        }
        let key = reg.insert(MeasureEntry {
            spec: mspec.clone(),
            built,
            required_len,
        });
        // Persist the spec next to the index store (its own
        // `measures.json` — the index manifest has its own lock
        // discipline) so a warm-started coordinator replays the entry
        // at this same key.  Still under the registry guard, which
        // serializes the file's read-modify-write.  Best-effort: a
        // failed write only costs restart persistence.
        if let Some(dir) = &self.cfg.index_store {
            if let Err(e) = record_measure_spec(dir, key.0, mspec) {
                eprintln!("warning: could not persist measure {}: {e}", key.0);
            }
        }
        drop(reg);
        self.metrics
            .measures_registered
            .fetch_add(1, Ordering::Relaxed);
        Ok(key)
    }

    /// Validate and bind a [`MeasureSpec`] into a runnable entry, with
    /// any grid reference resolved against the registry exactly once —
    /// shared by live registration and boot-time replay.
    fn bind_measure(&self, mspec: &MeasureSpec) -> Result<(BuiltMeasure, Option<usize>)> {
        mspec.validate()?;
        let loc = match mspec.grid() {
            Some(g) => Some(CoordinatorGrids(self).resolve(g)?),
            None => None,
        };
        let required_len = loc.as_ref().map(|l| l.t);
        let built = match &loc {
            Some(l) => {
                let fixed = spec::FixedGrid(Arc::clone(l));
                if mspec.is_kernel() {
                    BuiltMeasure::Kernel(mspec.build_kernel(&fixed)?)
                } else {
                    BuiltMeasure::Dist(mspec.build_measure(&fixed)?)
                }
            }
            None if mspec.is_kernel() => {
                BuiltMeasure::Kernel(mspec.build_kernel(&spec::InlineGrids)?)
            }
            None => BuiltMeasure::Dist(mspec.build_measure(&spec::InlineGrids)?),
        };
        Ok((built, required_len))
    }

    /// Boot-time measure replay: re-bind every persisted
    /// `register_measure` entry at its original key.  Specs that no
    /// longer bind — notably grid references (`registered` keys point
    /// into the previous process's grid registry, which does not
    /// persist) — are skipped with a warning, and their keys stay dead
    /// so a stale client never silently resolves a different measure.
    fn replay_measures(&self, dir: &std::path::Path) {
        let specs = match load_measure_specs(dir) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("warning: measure store unreadable ({e}); measures cold start");
                return;
            }
        };
        for (key, mspec) in specs {
            match self.bind_measure(&mspec) {
                Ok((built, required_len)) => {
                    self.measures.lock().unwrap().insert_at(
                        MeasureKey(key),
                        MeasureEntry {
                            spec: mspec,
                            built,
                            required_len,
                        },
                    );
                    self.metrics.measures_loaded.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    eprintln!(
                        "warning: skipping persisted measure {key} ('{}'): {e}",
                        mspec.name()
                    );
                    self.measures.lock().unwrap().reserve_past(MeasureKey(key));
                    self.metrics
                        .measure_load_failures
                        .fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Resolve a registered measure.
    pub fn measure(&self, key: MeasureKey) -> Result<Arc<MeasureEntry>> {
        self.measures
            .lock()
            .unwrap()
            .get(key)
            .ok_or_else(|| Error::not_found("measure key", key.0.to_string()))
    }

    /// Submit a distance evaluation described by a [`MeasureSpec`]
    /// (the generic TCP v2 `dist` op).  SP-DTW over a *registered*
    /// grid keeps the PJRT routing of [`Self::submit_spdtw`]; every
    /// other spec binds and runs on the native pool.  Operand shapes
    /// are rejected here, before anything reaches a DP kernel's
    /// asserts.
    pub fn submit_dist_spec(
        &self,
        mspec: &MeasureSpec,
        x: &TimeSeries,
        y: &TimeSeries,
    ) -> Result<JobTicket> {
        mspec.validate()?;
        mspec.check_operands(x.len(), y.len())?;
        match mspec {
            MeasureSpec::SpDtw { grid: GridSpec::Registered { key } } => {
                self.submit_spdtw(GridKey(*key), x, y)
            }
            MeasureSpec::SpDtw { grid } => {
                let loc = CoordinatorGrids(self).resolve(grid)?;
                check_grid_len(&loc, x.len())?;
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                let sp = SpDtw::from_arc(loc);
                let (xs, ys) = (x.values.clone(), y.values.clone());
                Ok(self.submit_native_closure(move || {
                    let d = sp.eval(&xs, &ys);
                    (d.value, d.visited_cells)
                }))
            }
            MeasureSpec::SpKrdtw { nu, grid } => {
                let loc = CoordinatorGrids(self).resolve(grid)?;
                check_grid_len(&loc, x.len())?;
                let kernel: Arc<dyn KernelMeasure> = Arc::new(SpKrdtw::from_arc(loc, *nu));
                Ok(self.submit_native(Arc::new(KernelDist::new(kernel)), x, y))
            }
            _ if mspec.is_kernel() => {
                let kernel = mspec.build_kernel(&CoordinatorGrids(self))?;
                Ok(self.submit_native(Arc::new(KernelDist::new(kernel)), x, y))
            }
            _ => {
                let m = mspec.build_measure(&CoordinatorGrids(self))?;
                Ok(self.submit_native(m, x, y))
            }
        }
    }

    /// Submit a log-kernel evaluation described by a [`MeasureSpec`]
    /// (the generic TCP v2 `kernel` op).  SP-K_rdtw over a registered
    /// grid keeps the PJRT routing of [`Self::submit_spkrdtw`];
    /// distance-only specs are a typed error.
    pub fn submit_kernel_spec(
        &self,
        mspec: &MeasureSpec,
        x: &TimeSeries,
        y: &TimeSeries,
    ) -> Result<JobTicket> {
        mspec.validate()?;
        mspec.check_operands(x.len(), y.len())?;
        match mspec {
            MeasureSpec::SpKrdtw { nu, grid: GridSpec::Registered { key } } => {
                self.submit_spkrdtw(GridKey(*key), *nu, x, y)
            }
            MeasureSpec::SpKrdtw { nu, grid } => {
                let loc = CoordinatorGrids(self).resolve(grid)?;
                check_grid_len(&loc, x.len())?;
                self.submit_native_kernel(Arc::new(SpKrdtw::from_arc(loc, *nu)), x, y)
            }
            _ if mspec.is_kernel() => {
                let kernel = mspec.build_kernel(&CoordinatorGrids(self))?;
                self.submit_native_kernel(kernel, x, y)
            }
            other => Err(Error::config(format!(
                "measure '{}' is a distance, not a kernel (use op \"dist\")",
                other.name()
            ))),
        }
    }

    /// [`Self::submit_dist_spec`] against a measure registered with
    /// [`Self::register_measure`]: no re-binding — the stored object
    /// runs directly (except registered-grid SP-DTW, which keeps its
    /// PJRT routing via the stored spec).
    pub fn submit_dist_key(
        &self,
        key: MeasureKey,
        x: &TimeSeries,
        y: &TimeSeries,
    ) -> Result<JobTicket> {
        let entry = self.measure(key)?;
        entry.spec.check_operands(x.len(), y.len())?;
        check_required_len(&entry, x.len())?;
        if let MeasureSpec::SpDtw { grid: GridSpec::Registered { key } } = &entry.spec {
            return self.submit_spdtw(GridKey(*key), x, y);
        }
        match &entry.built {
            BuiltMeasure::Dist(m) => Ok(self.submit_native(Arc::clone(m), x, y)),
            BuiltMeasure::Kernel(k) => {
                Ok(self.submit_native(Arc::new(KernelDist::new(Arc::clone(k))), x, y))
            }
        }
    }

    /// [`Self::submit_kernel_spec`] against a registered measure.
    pub fn submit_kernel_key(
        &self,
        key: MeasureKey,
        x: &TimeSeries,
        y: &TimeSeries,
    ) -> Result<JobTicket> {
        let entry = self.measure(key)?;
        entry.spec.check_operands(x.len(), y.len())?;
        check_required_len(&entry, x.len())?;
        if let MeasureSpec::SpKrdtw { nu, grid: GridSpec::Registered { key } } = &entry.spec {
            return self.submit_spkrdtw(GridKey(*key), *nu, x, y);
        }
        match &entry.built {
            BuiltMeasure::Kernel(k) => self.submit_native_kernel(Arc::clone(k), x, y),
            BuiltMeasure::Dist(_) => Err(Error::config(format!(
                "registered measure '{}' is a distance, not a kernel (use op \"dist\")",
                entry.spec.name()
            ))),
        }
    }

    /// Submit an arbitrary native kernel evaluation (log K value).
    fn submit_native_kernel(
        &self,
        kernel: Arc<dyn KernelMeasure>,
        x: &TimeSeries,
        y: &TimeSeries,
    ) -> Result<JobTicket> {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let xs = x.clone();
        let ys = y.clone();
        Ok(self.submit_native_closure(move || {
            let d = kernel.log_k(&xs, &ys);
            (d.value, d.visited_cells)
        }))
    }

    /// Build a search [`Index`] for a spec, resolving grid references
    /// against this coordinator's registry (the TCP v2 `register_index`
    /// op's `"measure"` parameter).
    pub fn build_index_from_spec(
        &self,
        train: &LabeledSet,
        mspec: &MeasureSpec,
    ) -> Result<Index> {
        Index::build_from_spec(train, mspec, false, &CoordinatorGrids(self), self.cfg.workers)
    }

    /// Whether a registered index evaluates the measure family `mspec`
    /// describes — the v2 `register_index` named-shortcut check: the
    /// payload `content_hash` only covers series/labels, so a client
    /// re-registering a known name under a *different* measure needs
    /// this signal (`measure_drift` in the reply) to know the served
    /// index would search the wrong family.
    pub fn index_matches_spec(&self, index: &Index, mspec: &MeasureSpec) -> Result<bool> {
        use crate::measures::sakoe_chiba::SakoeChibaDtw;
        // a z-normalized index (CLI `index save --znorm`, warm-started
        // here) evaluates normalized series — never what a plain spec
        // asks for (wire registrations themselves never z-normalize)
        let plain_banded = index.loc.is_none() && !index.znormalized;
        Ok(match mspec {
            MeasureSpec::Dtw => plain_banded && index.band == usize::MAX,
            MeasureSpec::BandedDtw { band_cells } => plain_banded && index.band == *band_cells,
            MeasureSpec::SakoeChiba { band_pct } => {
                plain_banded && index.band == SakoeChibaDtw::new(*band_pct).band_for(index.t)
            }
            MeasureSpec::SpDtw { grid } => match &index.loc {
                Some(stored) => {
                    let want = CoordinatorGrids(self).resolve(grid)?;
                    **stored == *want
                }
                None => false,
            },
            // not a searchable family: can never match an index
            _ => false,
        })
    }

    /// Register a prebuilt similarity-search [`Index`] and get a stable
    /// key for [`Self::submit_search`].  Anonymous registrations stay
    /// in-memory; use [`Self::register_index_persistent`] to also write
    /// the index to the on-disk store.
    pub fn register_index(&self, index: Index) -> IndexKey {
        self.indexes.lock().unwrap().insert(Arc::new(index))
    }

    /// This process's shard identity, when configured as a shard server
    /// (`CoordinatorConfig::shard`); `None` on ordinary single-node
    /// coordinators.
    pub fn shard_role(&self) -> Option<ShardRole> {
        self.cfg.shard
    }

    /// Count a `shard_search` op (called by the TCP server).
    pub(crate) fn note_shard_search(&self) {
        self.metrics.shard_searches.fetch_add(1, Ordering::Relaxed);
    }

    /// Register a shard slice with its local→global train-index map
    /// (the TCP `register_index` `global_ids` path; see
    /// [`crate::shard`]).  Sharded registrations are anonymous and
    /// in-memory only: the *front* owns naming and topology persistence
    /// (the shard manifest), and a warm-started named index would come
    /// back without its global map and silently mis-serve.
    pub fn register_index_sharded(&self, index: Index, global_ids: Vec<usize>) -> IndexKey {
        self.indexes
            .lock()
            .unwrap()
            .insert_sharded(Arc::new(index), global_ids)
    }

    /// The local→global map a sharded registration carried; `Ok(None)`
    /// for ordinary indexes (`shard_search` refuses those as
    /// mis-routed) and `Err(not_found)` for unknown keys.
    pub fn index_global_ids(&self, key: IndexKey) -> Result<Option<Arc<Vec<usize>>>> {
        let reg = self.indexes.lock().unwrap();
        let entry = reg
            .get_entry(key)
            .ok_or_else(|| Error::not_found("index key", key.0.to_string()))?;
        Ok(entry.global_ids.as_ref().map(Arc::clone))
    }

    /// Register `index` under a stable `name`, saving it into the
    /// configured index store (a `.spix` file plus a manifest entry) so
    /// the next warm-started coordinator serves it without rebuilding.
    /// Without a configured store this degrades to a named in-memory
    /// registration.  A previous holder of the name is replaced.  When
    /// `index_store_max_bytes` is set, least-recently-used store files
    /// are evicted after the save until the store fits the budget (the
    /// index just written is never evicted).
    pub fn register_index_persistent(&self, name: &str, index: Index) -> Result<IndexKey> {
        validate_index_name(name)?;
        let t = index.t;
        let n = index.len();
        let index = Arc::new(index);
        // The registry lock also serializes the store writes: without
        // it, two concurrent registrations would race the manifest's
        // read-modify-write (one detached TCP thread each) and the
        // loser's entry would vanish from the next warm start.
        let mut reg = self.indexes.lock().unwrap();
        if let Some(dir) = &self.cfg.index_store {
            let file = format!("{name}.spix");
            persist::save_index(&index, &dir.join(&file))?;
            record_index_artifact(dir, name, &file, t, n)?;
            self.metrics.indexes_saved.fetch_add(1, Ordering::Relaxed);
        }
        let key = reg.insert_named(name, index, false);
        if let (Some(dir), Some(budget)) = (&self.cfg.index_store, self.cfg.index_store_max_bytes)
        {
            enforce_store_budget(dir, budget, name, &mut reg, &self.metrics);
        }
        Ok(key)
    }

    /// Resolve a named index to `(key, loaded_from_disk)` — the cheap
    /// pre-check that lets `register_index` callers skip a rebuild when
    /// a warm-started (or earlier in-session) index already holds the
    /// name.  Also refreshes the name's LRU recency — in memory and,
    /// when a store is configured, in the store manifest, so the
    /// eviction order survives a coordinator restart.
    pub fn lookup_index_named(&self, name: &str) -> Option<(IndexKey, bool)> {
        let mut reg = self.indexes.lock().unwrap();
        let key = reg.key_by_name(name)?;
        let loaded = reg
            .get_entry(key)
            .map(|e| e.loaded_from_disk)
            .unwrap_or(false);
        // If the name is already most-recently-used the touch changes
        // nothing, in memory or on disk — skip the manifest rewrite
        // entirely (the common case of a hot index being looked up
        // repeatedly; every actual reorder is mirrored to disk, so the
        // two orders stay in lockstep).
        let already_mru = reg.lru_names().last().map(String::as_str) == Some(name);
        reg.touch(name);
        // Persist the recency bump (registry lock serializes the
        // manifest read-modify-write, like the save path).  A failed
        // touch only costs restart-recency — warn, don't fail the
        // lookup.
        if !already_mru {
            if let Some(dir) = &self.cfg.index_store {
                if let Err(e) = touch_index_artifact(dir, name) {
                    eprintln!("warning: could not persist LRU recency for '{name}': {e}");
                }
            }
        }
        Some((key, loaded))
    }

    fn index(&self, key: IndexKey) -> Result<Arc<Index>> {
        self.indexes
            .lock()
            .unwrap()
            .get(key)
            .ok_or_else(|| Error::not_found("index key", key.0.to_string()))
    }

    /// Submit a k-NN search against a registered index.  Runs on the
    /// native pool (the cascade is CPU work); per-stage prune counters
    /// are folded into the service metrics.
    pub fn submit_search(
        &self,
        key: IndexKey,
        query: &TimeSeries,
        k: usize,
        cascade: Cascade,
    ) -> Result<SearchTicket> {
        self.submit_search_deadline(key, query, k, cascade, None)
    }

    /// [`Self::submit_search`] with an optional deadline, checked again
    /// at epoch claim time: a request whose budget drained while queued
    /// behind other epochs resolves to the typed `deadline_exceeded`
    /// error without ever running the cascade.
    pub fn submit_search_deadline(
        &self,
        key: IndexKey,
        query: &TimeSeries,
        k: usize,
        cascade: Cascade,
        deadline: Option<Deadline>,
    ) -> Result<SearchTicket> {
        let index = self.index(key)?;
        if query.len() != index.t {
            return Err(Error::config(format!(
                "query length {} != indexed length {}",
                query.len(),
                index.t
            )));
        }
        if k == 0 {
            return Err(Error::config("search k must be >= 1"));
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::clone(&self.metrics);
        let values = query.values.clone();
        let start = Instant::now();
        self.native_pool.submit(move || {
            let _req = metrics.request_begin(); // gauge released on drop, even on unwind
            // epoch-claim deadline check: queued past the budget means
            // the cascade never runs (deadlines_exceeded is counted
            // once per request at the server's dispatch choke point)
            if let Some(d) = deadline {
                if d.expired() {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Err(d.error()));
                    return;
                }
            }
            let engine = SearchEngine::new(index, cascade);
            let r = engine.knn_values(&values, k);
            metrics.native_jobs.fetch_add(1, Ordering::Relaxed);
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics.record_search(&r.stats);
            metrics.record_latency(start.elapsed());
            let _ = tx.send(Ok(SearchOutcome {
                neighbors: r.neighbors,
                stats: r.stats,
            }));
        });
        Ok(SearchTicket { rx })
    }

    /// Submit a whole batch of k-NN queries as ONE request with its own
    /// completion handle.  The batch fans out as its own compute-pool
    /// epoch, so N clients' batches overlap on the shared worker set
    /// instead of serializing — the multi-client throughput path
    /// (`bench_coordinator` measures aggregate QPS at 1/2/4/8
    /// submitters).  Queries are answered in submission order.
    pub fn submit_batch_search(
        &self,
        key: IndexKey,
        queries: &[TimeSeries],
        k: usize,
        cascade: Cascade,
    ) -> Result<BatchSearchTicket> {
        self.submit_batch_search_deadline(key, queries, k, cascade, None)
    }

    /// [`Self::submit_batch_search`] with an optional deadline, checked
    /// again at epoch claim time (see
    /// [`Self::submit_search_deadline`]).  The whole batch is one
    /// request: an expired budget fails it whole, never a silent prefix
    /// of answered queries.
    pub fn submit_batch_search_deadline(
        &self,
        key: IndexKey,
        queries: &[TimeSeries],
        k: usize,
        cascade: Cascade,
        deadline: Option<Deadline>,
    ) -> Result<BatchSearchTicket> {
        let index = self.index(key)?;
        if queries.is_empty() {
            return Err(Error::config("batch search needs >= 1 query"));
        }
        if k == 0 {
            return Err(Error::config("search k must be >= 1"));
        }
        for q in queries {
            if q.len() != index.t {
                return Err(Error::config(format!(
                    "query length {} != indexed length {}",
                    q.len(),
                    index.t
                )));
            }
        }
        self.metrics
            .submitted
            .fetch_add(queries.len() as u64, Ordering::Relaxed);
        self.metrics.search_batches.fetch_add(1, Ordering::Relaxed);
        let vals: Vec<Vec<f64>> = queries.iter().map(|q| q.values.clone()).collect();
        let threads = self.cfg.workers;
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::clone(&self.metrics);
        let start = Instant::now();
        self.native_pool.submit(move || {
            let _req = metrics.request_begin(); // gauge released on drop, even on unwind
            // epoch-claim deadline check: queued past the budget means
            // the cascade never runs (deadlines_exceeded is counted
            // once per request at the server's dispatch choke point)
            if let Some(d) = deadline {
                if d.expired() {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Err(d.error()));
                    return;
                }
            }
            let engine = SearchEngine::new(index, cascade);
            let results = engine.batch_knn_values(&vals, k, threads);
            let outcomes: Vec<SearchOutcome> = results
                .into_iter()
                .map(|r| {
                    metrics.native_jobs.fetch_add(1, Ordering::Relaxed);
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    metrics.record_search(&r.stats);
                    metrics.record_latency(start.elapsed());
                    SearchOutcome {
                        neighbors: r.neighbors,
                        stats: r.stats,
                    }
                })
                .collect();
            let _ = tx.send(Ok(outcomes));
        });
        Ok(BatchSearchTicket { rx })
    }

    /// Submit a normalized train-Gram computation (`classify::gram`)
    /// over a kernel measure.  The N self-kernels and N(N-1)/2 pair
    /// kernels fan out as this request's own pool epochs, overlapping
    /// with concurrent search/gram requests — previously every Gram
    /// would serialize the whole compute pool behind one submit lock.
    pub fn submit_train_gram(
        &self,
        kernel: Arc<dyn KernelMeasure>,
        set: &LabeledSet,
    ) -> Result<GramTicket> {
        if set.is_empty() {
            return Err(Error::config("gram needs a non-empty train set"));
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.metrics.gram_requests.fetch_add(1, Ordering::Relaxed);
        let set = set.clone();
        let threads = self.cfg.workers;
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::clone(&self.metrics);
        let start = Instant::now();
        self.native_pool.submit(move || {
            let _req = metrics.request_begin(); // gauge released on drop, even on unwind
            let g = train_gram(&*kernel, &set, threads);
            metrics.native_jobs.fetch_add(1, Ordering::Relaxed);
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics
                .visited_cells
                .fetch_add(g.visited_cells, Ordering::Relaxed);
            metrics.record_latency(start.elapsed());
            let _ = tx.send(Ok(g));
        });
        Ok(GramTicket { rx })
    }

    /// Submit an SP-DTW pair (routed native or PJRT).
    pub fn submit_spdtw(&self, key: GridKey, x: &TimeSeries, y: &TimeSeries) -> Result<JobTicket> {
        let loc = self.grid(key)?;
        let t = loc.t;
        if x.len() != t || y.len() != t {
            return Err(Error::config(format!(
                "series length {}/{} != grid T={t}",
                x.len(),
                y.len()
            )));
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match self.router.route(KernelKind::Dtw, t) {
            Backend::Pjrt => self.submit_pjrt_job(
                BucketKey {
                    kind: KernelKind::Dtw,
                    t,
                    plane_key: key.0,
                    nu_bits: 0,
                },
                x.values.clone(),
                y.values.clone(),
                loc.nnz() as u64,
            ),
            Backend::Native => {
                let sp = SpDtw::from_arc(loc);
                let xs = x.values.clone();
                let ys = y.values.clone();
                Ok(self.submit_native_closure(move || {
                    let d = sp.eval(&xs, &ys);
                    (d.value, d.visited_cells)
                }))
            }
        }
    }

    /// Submit an SP-K_rdtw pair (returns log K(x, y); routed).
    pub fn submit_spkrdtw(
        &self,
        key: GridKey,
        nu: f64,
        x: &TimeSeries,
        y: &TimeSeries,
    ) -> Result<JobTicket> {
        let loc = self.grid(key)?;
        let t = loc.t;
        if x.len() != t || y.len() != t {
            return Err(Error::config("series length != grid T"));
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match self.router.route(KernelKind::Krdtw, t) {
            Backend::Pjrt => self.submit_pjrt_job(
                BucketKey {
                    kind: KernelKind::Krdtw,
                    t,
                    plane_key: key.0,
                    nu_bits: nu.to_bits(),
                },
                x.values.clone(),
                y.values.clone(),
                loc.nnz() as u64,
            ),
            Backend::Native => {
                let sp = SpKrdtw::from_arc(loc, nu);
                let xs = TimeSeries::new(0, x.values.clone());
                let ys = TimeSeries::new(0, y.values.clone());
                Ok(self.submit_native_closure(move || {
                    let d = sp.log_k(&xs, &ys);
                    (d.value, d.visited_cells)
                }))
            }
        }
    }

    /// Submit an arbitrary native measure evaluation.
    pub fn submit_native(
        &self,
        measure: Arc<dyn Measure>,
        x: &TimeSeries,
        y: &TimeSeries,
    ) -> JobTicket {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let xs = x.clone();
        let ys = y.clone();
        self.submit_native_closure(move || {
            let d = measure.dist(&xs, &ys);
            (d.value, d.visited_cells)
        })
    }

    fn submit_native_closure(
        &self,
        f: impl FnOnce() -> (f64, u64) + Send + 'static,
    ) -> JobTicket {
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::clone(&self.metrics);
        let start = Instant::now();
        self.native_pool.submit(move || {
            let (value, cells) = f();
            metrics.native_jobs.fetch_add(1, Ordering::Relaxed);
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics.visited_cells.fetch_add(cells, Ordering::Relaxed);
            metrics.record_latency(start.elapsed());
            let _ = tx.send(Ok(PairResult {
                value,
                visited_cells: cells,
                backend: Backend::Native,
            }));
        });
        JobTicket { rx }
    }

    fn submit_pjrt_job(
        &self,
        bucket: BucketKey,
        x: Vec<f64>,
        y: Vec<f64>,
        cells: u64,
    ) -> Result<JobTicket> {
        let (tx, rx) = mpsc::channel();
        let job = PjrtJob {
            bucket,
            x,
            y,
            cells,
            resp: tx,
        };
        self.dispatch_tx
            .as_ref()
            .ok_or_else(|| Error::coordinator("coordinator shut down"))?
            .send(DispatchMsg::Job(Box::new(job), Instant::now()))
            .map_err(|_| Error::coordinator("dispatcher gone"))?;
        Ok(JobTicket { rx })
    }

    /// SP-DTW distance matrix rows×cols (convenience bulk API used by
    /// the serving demo and the backend-parity tests).
    pub fn spdtw_matrix(
        &self,
        key: GridKey,
        rows: &[TimeSeries],
        cols: &[TimeSeries],
    ) -> Result<Vec<f64>> {
        let tickets: Vec<JobTicket> = rows
            .iter()
            .flat_map(|x| cols.iter().map(move |y| (x, y)))
            .map(|(x, y)| self.submit_spdtw(key, x, y))
            .collect::<Result<_>>()?;
        self.flush();
        tickets.into_iter().map(|t| t.wait().map(|r| r.value)).collect()
    }

    /// Force pending partial batches out (blocks until the dispatcher
    /// acknowledges the drain).
    pub fn flush(&self) {
        if let Some(tx) = &self.dispatch_tx {
            let (ack_tx, ack_rx) = mpsc::channel();
            if tx.send(DispatchMsg::Drain(ack_tx)).is_ok() {
                let _ = ack_rx.recv();
            }
        }
    }

    pub fn metrics(&self) -> Snapshot {
        let mut snap = self.metrics.snapshot();
        snap.native_queue_depth = self.native_pool.inflight() as u64;
        snap
    }

    /// Count a protocol-v2 envelope (called by the TCP server).
    pub(crate) fn note_v2_request(&self) {
        self.metrics.proto_v2_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one wire request answered with the typed
    /// `deadline_exceeded` code (called once per error reply by the TCP
    /// server's dispatch — the single choke point, so a budget that
    /// expires both at epoch claim and at the wait is still one
    /// request, one count).
    pub(crate) fn note_deadline_exceeded(&self) {
        self.metrics.deadlines_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    // ---- streaming sessions (`stream_*` op family) -------------------

    /// Open a streaming session: pins a [`StreamMonitor`] over the
    /// resolved index.  `idle_timeout_ms: None` applies
    /// [`DEFAULT_STREAM_IDLE_MS`]; sessions idle past their budget are
    /// reclaimed lazily by the next `stream_*` call (any session).
    pub fn stream_open(
        &self,
        key: IndexKey,
        k: usize,
        cascade: Cascade,
        rws: Option<RwsConfig>,
        idle_timeout_ms: Option<u64>,
    ) -> Result<StreamKey> {
        let index = self.index(key)?;
        let engine = SearchEngine::new(index, cascade);
        let monitor = StreamMonitor::new(engine, k, rws)?;
        let idle = Duration::from_millis(idle_timeout_ms.unwrap_or(DEFAULT_STREAM_IDLE_MS));
        let mut reg = self.streams.lock().unwrap();
        // Lazy reclamation under the same guard as the cap check: a
        // registry full of abandoned sessions must not lock out a live
        // client.
        let evicted = reg.sweep_idle(Instant::now());
        if evicted > 0 {
            self.metrics
                .streams_evicted
                .fetch_add(evicted as u64, Ordering::Relaxed);
        }
        if reg.len() >= MAX_STREAM_SESSIONS {
            return Err(Error::config(format!(
                "stream session limit reached ({MAX_STREAM_SESSIONS}); \
                 close sessions or let idle ones expire"
            )));
        }
        let skey = reg.insert(StreamSession::new(monitor, idle));
        drop(reg);
        self.metrics.streams_opened.fetch_add(1, Ordering::Relaxed);
        Ok(skey)
    }

    /// Resolve a live session, sweeping idle ones first so an expired
    /// key answers with the typed `not_found` — never a stale session.
    fn stream_session(&self, key: StreamKey) -> Result<Arc<Mutex<StreamSession>>> {
        let mut reg = self.streams.lock().unwrap();
        let evicted = reg.sweep_idle(Instant::now());
        if evicted > 0 {
            self.metrics
                .streams_evicted
                .fetch_add(evicted as u64, Ordering::Relaxed);
        }
        reg.get(key)
            .ok_or_else(|| Error::not_found("stream", key.0.to_string()))
    }

    /// Ingest samples into a session.  Each completed window runs the
    /// exact cascade (or the flagged approximate pre-filter) inline on
    /// the calling thread — streaming latency is per-sample, so windows
    /// never queue behind batch epochs.  The deadline is re-checked
    /// between samples: expiry keeps the already-ingested prefix (the
    /// session stays consistent) and returns the typed error.
    pub fn stream_push(
        &self,
        key: StreamKey,
        values: &[f64],
        deadline: Option<Deadline>,
    ) -> Result<StreamPushOutcome> {
        let session = self.stream_session(key)?;
        let mut s = session.lock().unwrap();
        s.touch();
        let mut pushed = 0u64;
        let mut windows = 0u64;
        let mut failure = None;
        for &v in values {
            if let Some(d) = &deadline {
                if d.expired() {
                    failure = Some(d.error());
                    break;
                }
            }
            match s.monitor.push(v) {
                Ok(report) => {
                    pushed += 1;
                    if let Some(report) = report {
                        windows += 1;
                        // each window's prune counters fold into the
                        // service metrics as one search
                        let stats = report.stats;
                        self.metrics.record_search(&stats);
                    }
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        let ready = s.monitor.ready();
        s.touch();
        drop(s);
        self.metrics
            .stream_samples
            .fetch_add(pushed, Ordering::Relaxed);
        self.metrics
            .stream_windows
            .fetch_add(windows, Ordering::Relaxed);
        match failure {
            Some(e) => Err(e),
            None => Ok(StreamPushOutcome {
                pushed,
                windows,
                ready,
            }),
        }
    }

    /// The registered window length (= indexed `T`) of a live session.
    pub fn stream_window_len(&self, key: StreamKey) -> Result<usize> {
        let session = self.stream_session(key)?;
        let s = session.lock().unwrap();
        Ok(s.monitor.window_len())
    }

    /// Snapshot the latest per-window match report plus cumulative
    /// session statistics.
    pub fn stream_matches(&self, key: StreamKey) -> Result<StreamMatchesOutcome> {
        let session = self.stream_session(key)?;
        let mut s = session.lock().unwrap();
        s.touch();
        Ok(StreamMatchesOutcome {
            report: s.monitor.last().cloned(),
            approx: s.monitor.is_approx(),
            stats: *s.monitor.stats(),
        })
    }

    /// Close a session, returning its final cumulative statistics.
    pub fn stream_close(&self, key: StreamKey) -> Result<StreamStats> {
        let session = self
            .streams
            .lock()
            .unwrap()
            .remove(key)
            .ok_or_else(|| Error::not_found("stream", key.0.to_string()))?;
        self.metrics.streams_closed.fetch_add(1, Ordering::Relaxed);
        let s = session.lock().unwrap();
        Ok(*s.monitor.stats())
    }

    /// Open streaming sessions right now (idle ones not yet swept
    /// count until any `stream_*` call reclaims them).
    pub fn stream_count(&self) -> usize {
        self.streams.lock().unwrap().len()
    }

    /// Wait for every native job to finish (tests / clean shutdown).
    pub fn wait_native_idle(&self) {
        self.native_pool.wait_idle();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.flush();
        self.dispatch_tx.take(); // closes dispatcher channel
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        if let Some(r) = self.runner.take() {
            let _ = r.join();
        }
        self.native_pool.wait_idle();
    }
}

/// Operand length vs a resolved grid (SP measures assert on this; the
/// boundary must reject instead).
fn check_grid_len(loc: &LocMatrix, len: usize) -> Result<()> {
    if len != loc.t {
        Err(Error::config(format!(
            "series length {len} != grid T={}",
            loc.t
        )))
    } else {
        Ok(())
    }
}

/// Operand length vs a registered measure's requirement.
fn check_required_len(entry: &MeasureEntry, len: usize) -> Result<()> {
    match entry.required_len {
        Some(t) if len != t => Err(Error::config(format!(
            "series length {len} != measure '{}' grid T={t}",
            entry.spec.name()
        ))),
        _ => Ok(()),
    }
}

/// Store names become file names: keep them to a safe charset so a
/// wire-supplied name can never escape the store directory.  `pub(crate)`
/// because the shard front applies the same rule before fanning a named
/// registration out to the fleet.
pub(crate) fn validate_index_name(name: &str) -> Result<()> {
    let ok = !name.is_empty()
        && name.len() <= 64
        && !name.starts_with('.')
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    if ok {
        Ok(())
    } else {
        // a request defect, not a lifecycle failure: the wire must map
        // this to `bad_request`, not the retryable `unavailable`
        Err(Error::config(format!(
            "invalid index name '{name}' (use 1-64 chars of [A-Za-z0-9._-], not starting with '.')"
        )))
    }
}

/// Enforce the index-store byte budget: total usage comes from the
/// manifest's `indexes` entries (the on-disk source of truth — a stale
/// file skipped at warm start still counts and is still evictable),
/// swept least-recently-used first.  Entries the in-memory registry has
/// no recency for (never registered this session) are treated as oldest.
/// `keep` (the index just written) is never evicted, even when it alone
/// exceeds the budget.  Evictions touch only the disk store: an
/// in-memory registration keeps serving, it just won't survive a
/// restart.  Called with the registry lock held (serializes the
/// manifest read-modify-write).
fn enforce_store_budget(
    dir: &std::path::Path,
    budget: u64,
    keep: &str,
    reg: &mut IndexRegistry,
    metrics: &Metrics,
) {
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("warning: store budget not enforced (manifest unreadable: {e})");
            return;
        }
    };
    let size_of = |path: &std::path::Path| {
        std::fs::metadata(path).map(|m| m.len()).unwrap_or(0)
    };
    // (name, path, bytes), least-recently-used first; recency-less
    // entries sort before everything the registry has seen
    let recency = reg.lru_names().to_vec();
    let rank = |name: &str| {
        recency
            .iter()
            .position(|n| n == name)
            .map_or(-1, |i| i as i64)
    };
    let mut entries: Vec<(String, std::path::PathBuf, u64)> = manifest
        .indexes
        .iter()
        .map(|e| (e.name.clone(), e.path.clone(), size_of(&e.path)))
        .collect();
    entries.sort_by_key(|(name, _, _)| rank(name));
    let mut total: u64 = entries.iter().map(|(_, _, sz)| sz).sum();
    for (name, path, sz) in entries {
        if total <= budget {
            break;
        }
        if name == keep || sz == 0 {
            continue;
        }
        match std::fs::remove_file(&path) {
            Ok(()) => {
                if let Err(e) = remove_index_artifact(dir, &name) {
                    eprintln!("warning: evicted '{name}' but manifest rewrite failed: {e}");
                }
                reg.forget_recency(&name);
                metrics.index_evictions.fetch_add(1, Ordering::Relaxed);
                total = total.saturating_sub(sz);
            }
            Err(e) => {
                // Surface the stuck state: the budget stays violated
                // until the operator intervenes, so say so every sweep.
                eprintln!(
                    "warning: store budget exceeded but cannot evict '{name}' \
                     ({}): {e}",
                    path.display()
                );
            }
        }
    }
}

/// Boot-time warm start: re-register every index the store manifest
/// lists.  Files that fail validation (truncated, corrupt checksum,
/// version skew, dimension mismatch vs the manifest) are skipped with a
/// warning and counted — a bad file must never be served.
///
/// Entries are registered in ascending `last_used` order (the recency
/// the previous process persisted into the manifest), so the in-memory
/// LRU order — and therefore the store's eviction order — survives the
/// restart instead of resetting to manifest file order.
fn warm_start_indexes(dir: &std::path::Path, reg: &mut IndexRegistry, metrics: &Metrics) {
    if !dir.join("manifest.json").exists() {
        return; // fresh store: nothing persisted yet
    }
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("warning: index store manifest unreadable ({e}); cold start");
            return;
        }
    };
    let mut ordered: Vec<&crate::runtime::IndexArtifact> = manifest.indexes.iter().collect();
    // stable: entries without a recency stamp keep manifest order
    ordered.sort_by_key(|e| e.last_used);
    for entry in ordered {
        match persist::load_index(&entry.path) {
            Ok(index) if index.t == entry.length && index.len() == entry.count => {
                reg.insert_named(&entry.name, Arc::new(index), true);
                metrics.indexes_loaded.fetch_add(1, Ordering::Relaxed);
            }
            Ok(index) => {
                eprintln!(
                    "warning: skipping stale index '{}': file is T={} n={}, \
                     manifest says T={} n={}",
                    entry.name,
                    index.t,
                    index.len(),
                    entry.length,
                    entry.count
                );
                metrics.index_load_failures.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                eprintln!("warning: skipping index '{}' from store: {e}", entry.name);
                metrics.index_load_failures.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Execute one ready batch on the PJRT handle and fan results out.
fn run_batch(handle: &PjrtHandle, batch: ReadyBatch, metrics: &Metrics) {
    let start = Instant::now();
    let n = batch.jobs.len();
    let t = batch.bucket.t;
    let outcome: Result<Vec<f64>> = match batch.bucket.kind {
        KernelKind::Dtw => {
            let x32: Vec<f32> = batch.xs.iter().map(|&v| v as f32).collect();
            let y32: Vec<f32> = batch.ys.iter().map(|&v| v as f32).collect();
            handle
                .run_dtw(DtwBatch {
                    t,
                    x: x32,
                    y: y32,
                    plane_key: batch.bucket.plane_key,
                })
                .map(|v| v.into_iter().map(|f| f as f64).collect())
        }
        KernelKind::Krdtw => handle.run_krdtw(KrdtwBatch {
            t,
            x: batch.xs.clone(),
            y: batch.ys.clone(),
            plane_key: batch.bucket.plane_key,
            nu: f64::from_bits(batch.bucket.nu_bits),
        }),
        // Lane-batched kernels carry one query plus a candidate-major
        // (T, L) block, not the pairwise x/y streams this batcher
        // accumulates — they are driven directly through
        // `PjrtHandle::run_lb_keogh`/`run_spdtw` by the search engine's
        // lane groups, never enqueued here.
        KernelKind::LbKeogh | KernelKind::Spdtw => Err(Error::runtime(
            "lane-batched kernels are not pair-batched; use run_lb_keogh/run_spdtw",
        )),
    };
    match outcome {
        Ok(values) => {
            for (i, job) in batch.jobs.into_iter().enumerate() {
                metrics.pjrt_jobs.fetch_add(1, Ordering::Relaxed);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.visited_cells.fetch_add(job.cells, Ordering::Relaxed);
                metrics.record_latency(start.elapsed());
                let _ = job.resp.send(Ok(PairResult {
                    value: values[i],
                    visited_cells: job.cells,
                    backend: Backend::Pjrt,
                }));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for job in batch.jobs {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = job.resp.send(Err(Error::runtime(msg.clone())));
            }
        }
    }
    let _ = n;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::measures::euclidean::Euclidean;

    fn coord() -> Coordinator {
        Coordinator::start(CoordinatorConfig::default(), None).unwrap()
    }

    #[test]
    fn native_submit_roundtrip() {
        let c = coord();
        let set = from_pairs(vec![(0, vec![0.0, 0.0]), (1, vec![3.0, 4.0])]);
        let t = c.submit_native(Arc::new(Euclidean), &set.series[0], &set.series[1]);
        let r = t.wait().unwrap();
        assert!((r.value - 5.0).abs() < 1e-12);
        assert_eq!(r.backend, Backend::Native);
        let snap = c.metrics();
        assert_eq!(snap.submitted, 1);
    }

    #[test]
    fn spdtw_native_matches_direct_eval() {
        let c = coord();
        let loc = LocMatrix::corridor(8, 2);
        let key = c.register_grid(loc.clone()).unwrap();
        let x = TimeSeries::new(0, (0..8).map(|i| i as f64).collect());
        let y = TimeSeries::new(0, (0..8).map(|i| (i as f64) * 0.5).collect());
        let got = c.submit_spdtw(key, &x, &y).unwrap().wait().unwrap();
        let direct = SpDtw::new(loc).dist(&x, &y);
        assert!((got.value - direct.value).abs() < 1e-12);
        assert_eq!(got.visited_cells, direct.visited_cells);
    }

    #[test]
    fn search_submit_roundtrip_updates_metrics() {
        use crate::data::synthetic;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 3, 10, 4).unwrap();
        let key = c.register_index(Index::build(&ds.train, 4, 2));
        let probe = &ds.test.series[0];
        let out = c
            .submit_search(key, probe, 3, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out.neighbors.len(), 3);
        assert!(out.neighbors[0].dist <= out.neighbors[1].dist);
        assert_eq!(out.stats.candidates, ds.train.len() as u64);
        c.wait_native_idle();
        let snap = c.metrics();
        assert_eq!(snap.search_queries, 1);
        assert_eq!(snap.search_candidates, ds.train.len() as u64);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn stream_session_lifecycle_updates_metrics() {
        use crate::data::synthetic;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 3, 10, 4).unwrap();
        let t = ds.train.series_len();
        let key = c.register_index(Index::build(&ds.train, 4, 2));
        let skey = c.stream_open(key, 3, Cascade::default(), None, None).unwrap();
        assert_eq!(c.stream_count(), 1);

        // first window completes exactly at t samples
        let first = c
            .stream_push(skey, &ds.test.series[0].values, None)
            .unwrap();
        assert_eq!(first.pushed, t as u64);
        assert_eq!(first.windows, 1);
        assert!(first.ready);
        // ten more samples slide ten more windows
        let second = c
            .stream_push(skey, &ds.test.series[1].values[..10], None)
            .unwrap();
        assert_eq!(second.windows, 10);

        // the served report is the exact cascade over the latest window
        let m = c.stream_matches(skey).unwrap();
        assert!(!m.approx);
        let rep = m.report.expect("ready session has a report");
        assert_eq!(rep.neighbors.len(), 3);
        assert!(rep.recall.is_none());
        let mut window = ds.test.series[0].values.clone();
        window.extend_from_slice(&ds.test.series[1].values[..10]);
        let window = &window[window.len() - t..];
        let engine = SearchEngine::new(Arc::new(Index::build(&ds.train, 4, 2)), Cascade::default());
        let want = engine.knn_values(window, 3);
        for (got, exp) in rep.neighbors.iter().zip(&want.neighbors) {
            assert_eq!(got.train_idx, exp.train_idx);
            assert_eq!(got.dist.to_bits(), exp.dist.to_bits());
        }
        assert_eq!(rep.stats, want.stats);

        let stats = c.stream_close(skey).unwrap();
        assert_eq!(stats.samples, (t + 10) as u64);
        assert_eq!(stats.windows, 11);
        assert_eq!(c.stream_count(), 0);
        assert!(c.stream_push(skey, &[0.0], None).is_err());

        let snap = c.metrics();
        assert_eq!(snap.streams_opened, 1);
        assert_eq!(snap.streams_closed, 1);
        assert_eq!(snap.stream_samples, (t + 10) as u64);
        assert_eq!(snap.stream_windows, 11);
        // every window folded into the service-wide search counters
        assert_eq!(snap.search_queries, 11);
    }

    #[test]
    fn stream_push_deadline_keeps_prefix_consistent() {
        use crate::data::synthetic;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 5, 8, 2).unwrap();
        let key = c.register_index(Index::build(&ds.train, 4, 2));
        let skey = c.stream_open(key, 1, Cascade::default(), None, None).unwrap();
        // an already-expired budget rejects before ingesting anything
        let err = c
            .stream_push(skey, &ds.test.series[0].values, Some(Deadline::in_ms(0)))
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "got: {err}");
        // the session survives and stays consistent
        let m = c.stream_matches(skey).unwrap();
        assert_eq!(m.stats.samples, 0);
        assert!(m.report.is_none());
        // a bad sample errors but keeps the valid prefix
        let err = c
            .stream_push(skey, &[1.0, 2.0, f64::NAN], None)
            .unwrap_err();
        assert!(err.to_string().contains("finite"), "got: {err}");
        assert_eq!(c.stream_matches(skey).unwrap().stats.samples, 2);
    }

    #[test]
    fn stream_open_sweeps_idle_and_enforces_cap() {
        use crate::data::synthetic;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 7, 6, 2).unwrap();
        let key = c.register_index(Index::build(&ds.train, 4, 2));
        // a zero idle budget expires immediately: the next stream op
        // (here, another open) reclaims it and its key stops resolving
        let dead = c
            .stream_open(key, 1, Cascade::default(), None, Some(0))
            .unwrap();
        assert_eq!(c.stream_count(), 1);
        let live = c.stream_open(key, 1, Cascade::default(), None, None).unwrap();
        assert_eq!(c.stream_count(), 1);
        assert!(c.stream_matches(dead).is_err());
        assert!(c.stream_matches(live).is_ok());
        assert!(c.metrics().streams_evicted >= 1);
        // the cap rejects the 65th live session with a typed config error
        for _ in c.stream_count()..MAX_STREAM_SESSIONS {
            c.stream_open(key, 1, Cascade::default(), None, None).unwrap();
        }
        let err = c
            .stream_open(key, 1, Cascade::default(), None, None)
            .unwrap_err();
        assert!(err.to_string().contains("limit"), "got: {err}");
    }

    #[test]
    fn search_rejects_bad_requests() {
        use crate::data::synthetic;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 3, 8, 2).unwrap();
        let key = c.register_index(Index::build(&ds.train, 2, 1));
        let probe = &ds.test.series[0];
        assert!(c
            .submit_search(IndexKey(99), probe, 1, Cascade::default())
            .is_err());
        let short = TimeSeries::new(0, vec![0.0; 3]);
        assert!(c.submit_search(key, &short, 1, Cascade::default()).is_err());
        assert!(c.submit_search(key, probe, 0, Cascade::default()).is_err());
    }

    #[test]
    fn persistent_register_saves_and_warm_starts() {
        use crate::data::synthetic;
        let store = std::env::temp_dir().join(format!("spdtw_store_{}", std::process::id()));
        std::fs::remove_dir_all(&store).ok();
        let ds = synthetic::generate_scaled("CBF", 5, 8, 4).unwrap();

        let mut cfg = CoordinatorConfig::default();
        cfg.index_store = Some(store.clone());
        {
            let c = Coordinator::start(cfg.clone(), None).unwrap();
            assert_eq!(c.lookup_index_named("cbf"), None);
            let key = c
                .register_index_persistent("cbf", Index::build(&ds.train, 3, 1))
                .unwrap();
            assert_eq!(c.lookup_index_named("cbf"), Some((key, false)));
            assert!(c.register_index_persistent("../evil", Index::build(&ds.train, 3, 1)).is_err());
            assert!(c.register_index_persistent("", Index::build(&ds.train, 3, 1)).is_err());
            assert_eq!(c.metrics().indexes_saved, 1);
            assert!(store.join("cbf.spix").exists());
        }

        // a fresh coordinator warm-starts from the store
        let c2 = Coordinator::start(cfg.clone(), None).unwrap();
        let (key, loaded) = c2.lookup_index_named("cbf").unwrap();
        assert!(loaded, "expected a warm-started entry");
        assert_eq!(c2.metrics().indexes_loaded, 1);
        let out = c2
            .submit_search(key, &ds.test.series[0], 2, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out.neighbors.len(), 2);

        // warm start disabled -> cold registry
        cfg.warm_start = false;
        let c3 = Coordinator::start(cfg, None).unwrap();
        assert_eq!(c3.lookup_index_named("cbf"), None);
        std::fs::remove_dir_all(&store).ok();
    }

    #[test]
    fn batch_search_answers_every_query_like_singles() {
        use crate::data::synthetic;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 7, 12, 6).unwrap();
        let key = c.register_index(Index::build(&ds.train, 3, 2));
        let outs = c
            .submit_batch_search(key, &ds.test.series, 2, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(outs.len(), ds.test.len());
        for (probe, out) in ds.test.series.iter().zip(&outs) {
            let single = c
                .submit_search(key, probe, 2, Cascade::default())
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(out.neighbors.len(), 2);
            for (a, b) in out.neighbors.iter().zip(&single.neighbors) {
                assert_eq!(a.dist.to_bits(), b.dist.to_bits());
                assert_eq!(a.train_idx, b.train_idx);
            }
        }
        c.wait_native_idle();
        let snap = c.metrics();
        assert_eq!(snap.search_batches, 1);
        // batch queries + the per-query cross-checks above
        assert_eq!(snap.completed, 2 * ds.test.len() as u64);
        assert!(snap.peak_concurrent_requests >= 1);
        // rejects: bad key, empty batch, k=0, ragged length
        assert!(c
            .submit_batch_search(IndexKey(99), &ds.test.series, 1, Cascade::default())
            .is_err());
        assert!(c.submit_batch_search(key, &[], 1, Cascade::default()).is_err());
        assert!(c
            .submit_batch_search(key, &ds.test.series, 0, Cascade::default())
            .is_err());
        let short = vec![TimeSeries::new(0, vec![0.0; 3])];
        assert!(c.submit_batch_search(key, &short, 1, Cascade::default()).is_err());
    }

    #[test]
    fn concurrent_batch_searches_from_many_clients() {
        use crate::data::synthetic;
        let c = Arc::new(coord());
        let ds = synthetic::generate_scaled("SyntheticControl", 3, 16, 8).unwrap();
        let key = c.register_index(Index::build(&ds.train, 4, 2));
        let expect = c
            .submit_batch_search(key, &ds.test.series, 1, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                let queries = ds.test.series.clone();
                std::thread::spawn(move || {
                    c.submit_batch_search(key, &queries, 1, Cascade::default())
                        .unwrap()
                        .wait()
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), expect.len());
            for (a, b) in outs.iter().zip(&expect) {
                assert_eq!(
                    a.neighbors[0].dist.to_bits(),
                    b.neighbors[0].dist.to_bits(),
                    "concurrent clients must get bit-identical answers"
                );
                assert_eq!(a.neighbors[0].train_idx, b.neighbors[0].train_idx);
            }
        }
        c.wait_native_idle();
        let snap = c.metrics();
        assert_eq!(snap.search_batches, 5);
        assert_eq!(snap.requests_inflight, 0);
        assert_eq!(snap.completed, 5 * ds.test.len() as u64);
    }

    #[test]
    fn gram_request_matches_direct_computation() {
        use crate::data::synthetic;
        use crate::measures::krdtw::Krdtw;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 9, 6, 2).unwrap();
        let g = c
            .submit_train_gram(Arc::new(Krdtw::new(1.0)), &ds.train)
            .unwrap()
            .wait()
            .unwrap();
        let direct = train_gram(&Krdtw::new(1.0), &ds.train, 2);
        assert_eq!(g.rows, direct.rows);
        assert_eq!(g.visited_cells, direct.visited_cells);
        let ga: Vec<u64> = g.data.iter().map(|v| v.to_bits()).collect();
        let gb: Vec<u64> = direct.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ga, gb);
        c.wait_native_idle();
        assert_eq!(c.metrics().gram_requests, 1);
        assert!(c
            .submit_train_gram(Arc::new(Krdtw::new(1.0)), &LabeledSet::default())
            .is_err());
    }

    #[test]
    fn store_budget_evicts_lru_never_just_written() {
        use crate::data::synthetic;
        let store = std::env::temp_dir().join(format!("spdtw_lru_{}", std::process::id()));
        std::fs::remove_dir_all(&store).ok();
        let ds = synthetic::generate_scaled("CBF", 5, 6, 2).unwrap();
        let idx = || Index::build(&ds.train, 2, 1);

        // budget sized for two of the (identically shaped) index files
        let probe = std::env::temp_dir().join(format!("spdtw_lru_probe_{}.spix", std::process::id()));
        persist::save_index(&idx(), &probe).unwrap();
        let one = std::fs::metadata(&probe).unwrap().len();
        std::fs::remove_file(&probe).ok();

        let mut cfg = CoordinatorConfig::default();
        cfg.index_store = Some(store.clone());
        cfg.index_store_max_bytes = Some(2 * one + one / 2);
        let c = Coordinator::start(cfg.clone(), None).unwrap();

        c.register_index_persistent("a", idx()).unwrap();
        c.register_index_persistent("b", idx()).unwrap();
        assert_eq!(c.metrics().index_evictions, 0);
        assert!(store.join("a.spix").exists() && store.join("b.spix").exists());

        // third index busts the budget: 'a' is the LRU entry
        c.register_index_persistent("cc", idx()).unwrap();
        assert_eq!(c.metrics().index_evictions, 1);
        assert!(!store.join("a.spix").exists(), "LRU file must be evicted");
        assert!(store.join("b.spix").exists() && store.join("cc.spix").exists());
        let m = Manifest::load(&store).unwrap();
        assert!(m.find_index("a").is_none());
        assert!(m.find_index("b").is_some() && m.find_index("cc").is_some());
        // eviction is store-only: 'a' still serves from memory
        assert!(c.lookup_index_named("a").is_some());

        // a named lookup refreshes recency: touching 'b' makes 'cc' the
        // oldest stored entry, so 'cc' goes next instead of 'b'
        c.lookup_index_named("b");
        c.register_index_persistent("d", idx()).unwrap();
        assert_eq!(c.metrics().index_evictions, 2);
        assert!(!store.join("cc.spix").exists());
        assert!(store.join("b.spix").exists() && store.join("d.spix").exists());

        // the index just written survives even a sub-single-file budget
        let mut tiny = cfg;
        tiny.index_store_max_bytes = Some(1);
        let c2 = Coordinator::start(tiny, None).unwrap();
        c2.register_index_persistent("e", idx()).unwrap();
        assert!(store.join("e.spix").exists(), "just-written index must never be evicted");
        std::fs::remove_dir_all(&store).ok();
    }

    #[test]
    fn corrupt_store_file_is_skipped_not_served() {
        use crate::data::synthetic;
        let store = std::env::temp_dir().join(format!("spdtw_store_bad_{}", std::process::id()));
        std::fs::remove_dir_all(&store).ok();
        let ds = synthetic::generate_scaled("CBF", 6, 6, 2).unwrap();
        let mut cfg = CoordinatorConfig::default();
        cfg.index_store = Some(store.clone());
        {
            let c = Coordinator::start(cfg.clone(), None).unwrap();
            c.register_index_persistent("cbf", Index::build(&ds.train, 2, 1))
                .unwrap();
        }
        let path = store.join("cbf.spix");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();

        let c2 = Coordinator::start(cfg, None).unwrap();
        assert_eq!(c2.lookup_index_named("cbf"), None);
        let snap = c2.metrics();
        assert_eq!(snap.indexes_loaded, 0);
        assert_eq!(snap.index_load_failures, 1);
        std::fs::remove_dir_all(&store).ok();
    }

    #[test]
    fn unknown_grid_rejected() {
        let c = coord();
        let x = TimeSeries::new(0, vec![0.0; 4]);
        assert!(c.submit_spdtw(GridKey(42), &x, &x).is_err());
    }

    #[test]
    fn length_mismatch_rejected() {
        let c = coord();
        let key = c.register_grid(LocMatrix::full(4)).unwrap();
        let x = TimeSeries::new(0, vec![0.0; 5]);
        assert!(c.submit_spdtw(key, &x, &x).is_err());
    }

    #[test]
    fn register_measure_and_generic_dist_kernel_submit() {
        use crate::measures::kga::Kga;
        use crate::measures::krdtw::Krdtw;
        let c = coord();
        let x = TimeSeries::new(0, (0..8).map(|i| i as f64).collect());
        let y = TimeSeries::new(0, (0..8).map(|i| (i as f64) * 0.5).collect());

        // spec-submitted distances match direct evaluation bitwise
        let spec_dtw = MeasureSpec::Dtw;
        let got = c.submit_dist_spec(&spec_dtw, &x, &y).unwrap().wait().unwrap();
        let direct = crate::measures::dtw::Dtw.dist(&x, &y);
        assert_eq!(got.value.to_bits(), direct.value.to_bits());
        assert_eq!(got.visited_cells, direct.visited_cells);

        // registered-grid SP-DTW through the generic path equals the
        // dedicated submit_spdtw path
        let key = c.register_grid(LocMatrix::corridor(8, 2)).unwrap();
        let spec_sp = MeasureSpec::SpDtw { grid: GridSpec::Registered { key: key.0 } };
        let a = c.submit_dist_spec(&spec_sp, &x, &y).unwrap().wait().unwrap();
        let b = c.submit_spdtw(key, &x, &y).unwrap().wait().unwrap();
        assert_eq!(a.value.to_bits(), b.value.to_bits());

        // inline corridor grid resolves without any registry entry
        let spec_inline = MeasureSpec::SpDtw { grid: GridSpec::Corridor { t: 8, band: 2 } };
        let i = c.submit_dist_spec(&spec_inline, &x, &y).unwrap().wait().unwrap();
        assert_eq!(i.value.to_bits(), a.value.to_bits());

        // kernels: generic kernel op matches direct log_k; dist on a
        // kernel spec is the normalized distance (0 on self)
        let spec_k = MeasureSpec::Krdtw { nu: 0.5, band_cells: None };
        let kk = c.submit_kernel_spec(&spec_k, &x, &y).unwrap().wait().unwrap();
        let kd = Krdtw::new(0.5).log_kernel(&x.values, &y.values);
        assert_eq!(kk.value.to_bits(), kd.value.to_bits());
        let dd = c.submit_dist_spec(&spec_k, &x, &x).unwrap().wait().unwrap();
        assert!(dd.value.abs() < 1e-9);

        // registered measures answer identically to their specs
        let mkey = c.register_measure(&spec_k).unwrap();
        let via_key = c.submit_kernel_key(mkey, &x, &y).unwrap().wait().unwrap();
        assert_eq!(via_key.value.to_bits(), kk.value.to_bits());
        let gkey = c
            .register_measure(&MeasureSpec::Kga { nu: 0.5, band_cells: Some(3) })
            .unwrap();
        let kga = c.submit_kernel_key(gkey, &x, &y).unwrap().wait().unwrap();
        assert_eq!(
            kga.value.to_bits(),
            Kga::with_band(0.5, 3).log_kernel(&x.values, &y.values).value.to_bits()
        );
        c.wait_native_idle();
        assert_eq!(c.metrics().measures_registered, 2);

        // typed rejections at the boundary, not asserts in the pool
        let short = TimeSeries::new(0, vec![1.0; 3]);
        assert!(c.submit_dist_spec(&spec_sp, &short, &short).is_err()); // grid len
        assert!(c.submit_dist_spec(&spec_k, &x, &short).is_err()); // unequal
        assert!(c.submit_kernel_spec(&MeasureSpec::Dtw, &x, &y).is_err()); // not a kernel
        assert!(c.submit_kernel_key(MeasureKey(99), &x, &y).is_err()); // unknown key
        assert!(c
            .register_measure(&MeasureSpec::SpDtw {
                grid: GridSpec::Registered { key: 404 }
            })
            .is_err());
        assert!(c
            .register_measure(&MeasureSpec::Krdtw { nu: -1.0, band_cells: None })
            .is_err());
        let dkey = c.register_measure(&MeasureSpec::Euclidean).unwrap();
        assert!(c.submit_kernel_key(dkey, &x, &y).is_err()); // dist-only entry
    }

    #[test]
    fn measure_registry_is_bounded() {
        let c = coord();
        for _ in 0..MAX_REGISTERED_MEASURES {
            c.register_measure(&MeasureSpec::Euclidean).unwrap();
        }
        let err = c.register_measure(&MeasureSpec::Euclidean).unwrap_err();
        assert_eq!(err.code(), "bad_request");
        assert!(err.to_string().contains("registry full"));
    }

    #[test]
    fn registered_measures_survive_restart() {
        let store = std::env::temp_dir().join(format!("spdtw_measures_{}", std::process::id()));
        std::fs::remove_dir_all(&store).ok();
        let x = TimeSeries::new(0, (0..8).map(|i| i as f64).collect());
        let y = TimeSeries::new(0, (0..8).map(|i| (i as f64) * 0.25).collect());
        let mut cfg = CoordinatorConfig::default();
        cfg.index_store = Some(store.clone());
        let spec_k = MeasureSpec::Krdtw { nu: 0.5, band_cells: None };
        let spec_sp = MeasureSpec::SpDtw { grid: GridSpec::Corridor { t: 8, band: 2 } };
        let (k1, k2, kreg, expect_kernel, expect_dist);
        {
            let c = Coordinator::start(cfg.clone(), None).unwrap();
            k1 = c.register_measure(&spec_k).unwrap();
            k2 = c.register_measure(&spec_sp).unwrap();
            // a registered-grid reference persists but cannot re-bind
            // (grid registries do not survive a restart)
            let g = c.register_grid(LocMatrix::corridor(8, 1)).unwrap();
            kreg = c
                .register_measure(&MeasureSpec::SpDtw {
                    grid: GridSpec::Registered { key: g.0 },
                })
                .unwrap();
            expect_kernel = c.submit_kernel_key(k1, &x, &y).unwrap().wait().unwrap().value;
            expect_dist = c.submit_dist_key(k2, &x, &y).unwrap().wait().unwrap().value;
        }

        // restart: bindable measures replay at their original keys,
        // answering bit-identically; the grid reference is skipped
        let c2 = Coordinator::start(cfg.clone(), None).unwrap();
        let snap = c2.metrics();
        assert_eq!(snap.measures_loaded, 2);
        assert_eq!(snap.measure_load_failures, 1);
        let got_k = c2.submit_kernel_key(k1, &x, &y).unwrap().wait().unwrap().value;
        let got_d = c2.submit_dist_key(k2, &x, &y).unwrap().wait().unwrap().value;
        assert_eq!(got_k.to_bits(), expect_kernel.to_bits());
        assert_eq!(got_d.to_bits(), expect_dist.to_bits());
        // the unbindable entry's key is dead, not recycled: a fresh
        // registration must get a strictly newer key
        assert!(c2.submit_dist_key(kreg, &x, &y).is_err());
        let k3 = c2.register_measure(&MeasureSpec::Euclidean).unwrap();
        assert!(k3.0 > kreg.0);

        // warm start disabled -> no replay
        cfg.warm_start = false;
        let c3 = Coordinator::start(cfg, None).unwrap();
        assert!(c3.submit_kernel_key(k1, &x, &y).is_err());
        std::fs::remove_dir_all(&store).ok();
    }

    #[test]
    fn sharded_registration_keeps_global_ids() {
        use crate::data::synthetic;
        let c = coord();
        let ds = synthetic::generate_scaled("CBF", 4, 8, 2).unwrap();
        let plain = c.register_index(Index::build(&ds.train, 2, 1));
        let gids = vec![1, 3, 5, 7];
        let sharded = c.register_index_sharded(Index::build(&ds.train, 2, 1), gids.clone());
        assert_eq!(c.index_global_ids(plain).unwrap(), None);
        assert_eq!(
            c.index_global_ids(sharded).unwrap().as_deref(),
            Some(&gids)
        );
        assert!(c.index_global_ids(IndexKey(99)).is_err());
        // sharded slices stay searchable like any registered index
        let out = c
            .submit_search(sharded, &ds.test.series[0], 2, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out.neighbors.len(), 2);
    }

    #[test]
    fn store_lru_recency_survives_restart() {
        use crate::data::synthetic;
        let store = std::env::temp_dir().join(format!("spdtw_lru_restart_{}", std::process::id()));
        std::fs::remove_dir_all(&store).ok();
        let ds = synthetic::generate_scaled("CBF", 5, 6, 2).unwrap();
        let idx = || Index::build(&ds.train, 2, 1);

        let probe = std::env::temp_dir()
            .join(format!("spdtw_lru_restart_probe_{}.spix", std::process::id()));
        persist::save_index(&idx(), &probe).unwrap();
        let one = std::fs::metadata(&probe).unwrap().len();
        std::fs::remove_file(&probe).ok();

        let mut cfg = CoordinatorConfig::default();
        cfg.index_store = Some(store.clone());
        {
            // session 1: register a then b, then touch a — making b the
            // LRU entry, persisted into the manifest
            let c = Coordinator::start(cfg.clone(), None).unwrap();
            c.register_index_persistent("a", idx()).unwrap();
            c.register_index_persistent("b", idx()).unwrap();
            c.lookup_index_named("a").unwrap();
        }

        // session 2 (restart): with the pre-fix manifest-order reset,
        // 'a' would be evicted here; persisted recency must evict 'b'.
        cfg.index_store_max_bytes = Some(2 * one + one / 2);
        let c2 = Coordinator::start(cfg, None).unwrap();
        c2.register_index_persistent("c", idx()).unwrap();
        assert_eq!(c2.metrics().index_evictions, 1);
        assert!(
            store.join("a.spix").exists(),
            "recently-used index evicted: LRU order did not survive the restart"
        );
        assert!(!store.join("b.spix").exists(), "stale index must be the one evicted");
        assert!(store.join("c.spix").exists());
        let m = Manifest::load(&store).unwrap();
        assert!(m.find_index("b").is_none() && m.find_index("a").is_some());
        std::fs::remove_dir_all(&store).ok();
    }

    #[test]
    fn matrix_bulk_api_counts() {
        let c = coord();
        let key = c.register_grid(LocMatrix::full(4)).unwrap();
        let rows = vec![
            TimeSeries::new(0, vec![0.0, 1.0, 2.0, 3.0]),
            TimeSeries::new(0, vec![1.0, 1.0, 1.0, 1.0]),
        ];
        let m = c.spdtw_matrix(key, &rows, &rows).unwrap();
        assert_eq!(m.len(), 4);
        assert!(m[0].abs() < 1e-12 && m[3].abs() < 1e-12); // self distances
        assert!((m[1] - m[2]).abs() < 1e-12); // symmetry
        c.wait_native_idle();
        assert_eq!(c.metrics().completed, 4);
    }
}
