//! Job and result types flowing through the coordinator.

use std::sync::mpsc;

use crate::error::{Error, Result};
use crate::runtime::KernelKind;
use crate::search::{Neighbor, PruneStats};

/// Which execution backend produced a result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// In-process Rust DP (measures::*).
    Native,
    /// AOT XLA executable via PJRT.
    Pjrt,
}

impl Backend {
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Result of one pairwise evaluation.
#[derive(Clone, Copy, Debug)]
pub struct PairResult {
    pub value: f64,
    pub visited_cells: u64,
    pub backend: Backend,
}

/// Completion handle for a submitted job.
pub struct JobTicket {
    pub(crate) rx: mpsc::Receiver<Result<PairResult>>,
}

impl JobTicket {
    /// Block until the result is available.
    pub fn wait(self) -> Result<PairResult> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("job dropped before completion"))?
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<PairResult>> {
        self.rx.try_recv().ok()
    }
}

/// Result of one k-NN search request served by the `search` engine.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// Ascending `(dist, train index)` order, length ≤ k.
    pub neighbors: Vec<Neighbor>,
    /// Cascade counters for this query (also folded into the service
    /// metrics).
    pub stats: PruneStats,
}

/// Completion handle for a submitted search request.
pub struct SearchTicket {
    pub(crate) rx: mpsc::Receiver<Result<SearchOutcome>>,
}

impl SearchTicket {
    /// Block until the k-NN result is available.
    pub fn wait(self) -> Result<SearchOutcome> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("search job dropped before completion"))?
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<SearchOutcome>> {
        self.rx.try_recv().ok()
    }
}

/// Completion handle for a batch k-NN request — the per-request epoch
/// handle of the concurrent-epoch execution path: the whole batch runs
/// as one compute-pool epoch, overlapping with other clients' requests.
pub struct BatchSearchTicket {
    pub(crate) rx: mpsc::Receiver<Result<Vec<SearchOutcome>>>,
}

impl BatchSearchTicket {
    /// Block until every query in the batch has been answered.
    pub fn wait(self) -> Result<Vec<SearchOutcome>> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("batch search dropped before completion"))?
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<Vec<SearchOutcome>>> {
        self.rx.try_recv().ok()
    }
}

/// Completion handle for a Gram-matrix request.
pub struct GramTicket {
    pub(crate) rx: mpsc::Receiver<Result<crate::classify::gram::Gram>>,
}

impl GramTicket {
    /// Block until the Gram matrix is computed.
    pub fn wait(self) -> Result<crate::classify::gram::Gram> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("gram job dropped before completion"))?
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<crate::classify::gram::Gram>> {
        self.rx.try_recv().ok()
    }
}

/// Batching bucket identity: jobs may share a PJRT batch only if they
/// agree on everything the executable closes over.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub kind: KernelKind,
    pub t: usize,
    pub plane_key: u64,
    /// `nu.to_bits()` for K_rdtw buckets, 0 for DTW.
    pub nu_bits: u64,
}

/// A PJRT-routed pairwise job.
pub(crate) struct PjrtJob {
    pub bucket: BucketKey,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    /// Visited-cell accounting carried from the registered grid (nnz).
    pub cells: u64,
    pub resp: mpsc::Sender<Result<PairResult>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_roundtrip() {
        let (tx, rx) = mpsc::channel();
        let t = JobTicket { rx };
        tx.send(Ok(PairResult {
            value: 1.5,
            visited_cells: 10,
            backend: Backend::Native,
        }))
        .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.value, 1.5);
        assert_eq!(r.backend.as_str(), "native");
    }

    #[test]
    fn dropped_sender_is_error() {
        let (tx, rx) = mpsc::channel::<Result<PairResult>>();
        drop(tx);
        assert!(JobTicket { rx }.wait().is_err());
    }

    #[test]
    fn bucket_key_equality() {
        let a = BucketKey { kind: KernelKind::Dtw, t: 60, plane_key: 1, nu_bits: 0 };
        let b = BucketKey { kind: KernelKind::Dtw, t: 60, plane_key: 1, nu_bits: 0 };
        let c = BucketKey { kind: KernelKind::Dtw, t: 60, plane_key: 2, nu_bits: 0 };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
