//! Job and result types flowing through the coordinator.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::runtime::KernelKind;
use crate::search::{Neighbor, PruneStats};

/// A request deadline: an absolute expiry instant plus the original
/// millisecond budget (kept for the typed `deadline_exceeded` error and
/// for recomputing the *remaining* budget when the front forwards the
/// deadline to shard legs).
///
/// Checked at three points along a request's life: before dispatch (the
/// cheap reject), at epoch claim time inside the compute pool (a queued
/// request whose budget drained while waiting never runs), and as the
/// bound on every blocking ticket / shard-link wait.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    /// Absolute expiry.
    pub at: Instant,
    /// The budget the client originally asked for, in milliseconds.
    pub budget_ms: u64,
}

impl Deadline {
    /// A deadline `budget_ms` from now.
    pub fn in_ms(budget_ms: u64) -> Self {
        Deadline {
            at: Instant::now() + Duration::from_millis(budget_ms),
            budget_ms,
        }
    }

    /// Has the budget drained?
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }

    /// Remaining budget (zero once expired — callers can pass this
    /// straight to `recv_timeout` for an immediate poll-style check).
    pub fn remaining(&self) -> Duration {
        self.at.saturating_duration_since(Instant::now())
    }

    /// The typed error for this deadline.
    pub fn error(&self) -> Error {
        Error::deadline_exceeded(self.budget_ms)
    }
}

/// Which execution backend produced a result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// In-process Rust DP (measures::*).
    Native,
    /// AOT XLA executable via PJRT.
    Pjrt,
}

impl Backend {
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Result of one pairwise evaluation.
#[derive(Clone, Copy, Debug)]
pub struct PairResult {
    pub value: f64,
    pub visited_cells: u64,
    pub backend: Backend,
}

/// Completion handle for a submitted job.
pub struct JobTicket {
    pub(crate) rx: mpsc::Receiver<Result<PairResult>>,
}

impl JobTicket {
    /// Block until the result is available.
    pub fn wait(self) -> Result<PairResult> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("job dropped before completion"))?
    }

    /// Like [`JobTicket::wait`], but bounded by an optional deadline:
    /// once the budget drains the wait resolves to the typed
    /// `deadline_exceeded` error instead of blocking on.
    pub fn wait_deadline(self, deadline: Option<Deadline>) -> Result<PairResult> {
        match deadline {
            None => self.wait(),
            Some(d) => match self.rx.recv_timeout(d.remaining()) {
                Ok(r) => r,
                Err(mpsc::RecvTimeoutError::Timeout) => Err(d.error()),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    Err(Error::coordinator("job dropped before completion"))
                }
            },
        }
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<PairResult>> {
        self.rx.try_recv().ok()
    }
}

/// Result of one k-NN search request served by the `search` engine.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// Ascending `(dist, train index)` order, length ≤ k.
    pub neighbors: Vec<Neighbor>,
    /// Cascade counters for this query (also folded into the service
    /// metrics).
    pub stats: PruneStats,
}

/// Completion handle for a submitted search request.
pub struct SearchTicket {
    pub(crate) rx: mpsc::Receiver<Result<SearchOutcome>>,
}

impl SearchTicket {
    /// Block until the k-NN result is available.
    pub fn wait(self) -> Result<SearchOutcome> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("search job dropped before completion"))?
    }

    /// Deadline-bounded wait — see [`JobTicket::wait_deadline`].
    pub fn wait_deadline(self, deadline: Option<Deadline>) -> Result<SearchOutcome> {
        match deadline {
            None => self.wait(),
            Some(d) => match self.rx.recv_timeout(d.remaining()) {
                Ok(r) => r,
                Err(mpsc::RecvTimeoutError::Timeout) => Err(d.error()),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    Err(Error::coordinator("search job dropped before completion"))
                }
            },
        }
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<SearchOutcome>> {
        self.rx.try_recv().ok()
    }
}

/// Completion handle for a batch k-NN request — the per-request epoch
/// handle of the concurrent-epoch execution path: the whole batch runs
/// as one compute-pool epoch, overlapping with other clients' requests.
pub struct BatchSearchTicket {
    pub(crate) rx: mpsc::Receiver<Result<Vec<SearchOutcome>>>,
}

impl BatchSearchTicket {
    /// Block until every query in the batch has been answered.
    pub fn wait(self) -> Result<Vec<SearchOutcome>> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("batch search dropped before completion"))?
    }

    /// Deadline-bounded wait — see [`JobTicket::wait_deadline`].
    pub fn wait_deadline(self, deadline: Option<Deadline>) -> Result<Vec<SearchOutcome>> {
        match deadline {
            None => self.wait(),
            Some(d) => match self.rx.recv_timeout(d.remaining()) {
                Ok(r) => r,
                Err(mpsc::RecvTimeoutError::Timeout) => Err(d.error()),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    Err(Error::coordinator("batch search dropped before completion"))
                }
            },
        }
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<Vec<SearchOutcome>>> {
        self.rx.try_recv().ok()
    }
}

/// Completion handle for a Gram-matrix request.
pub struct GramTicket {
    pub(crate) rx: mpsc::Receiver<Result<crate::classify::gram::Gram>>,
}

impl GramTicket {
    /// Block until the Gram matrix is computed.
    pub fn wait(self) -> Result<crate::classify::gram::Gram> {
        self.rx
            .recv()
            .map_err(|_| Error::coordinator("gram job dropped before completion"))?
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<Result<crate::classify::gram::Gram>> {
        self.rx.try_recv().ok()
    }
}

/// Batching bucket identity: jobs may share a PJRT batch only if they
/// agree on everything the executable closes over.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub kind: KernelKind,
    pub t: usize,
    pub plane_key: u64,
    /// `nu.to_bits()` for K_rdtw buckets, 0 for DTW.
    pub nu_bits: u64,
}

/// A PJRT-routed pairwise job.
pub(crate) struct PjrtJob {
    pub bucket: BucketKey,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    /// Visited-cell accounting carried from the registered grid (nnz).
    pub cells: u64,
    pub resp: mpsc::Sender<Result<PairResult>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_roundtrip() {
        let (tx, rx) = mpsc::channel();
        let t = JobTicket { rx };
        tx.send(Ok(PairResult {
            value: 1.5,
            visited_cells: 10,
            backend: Backend::Native,
        }))
        .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.value, 1.5);
        assert_eq!(r.backend.as_str(), "native");
    }

    #[test]
    fn deadline_bounds_ticket_wait() {
        let (tx, rx) = mpsc::channel::<Result<PairResult>>();
        let err = JobTicket { rx }
            .wait_deadline(Some(Deadline::in_ms(5)))
            .unwrap_err();
        assert_eq!(err.code(), "deadline_exceeded");
        assert!(err.to_string().contains("5 ms"));
        drop(tx);
        assert!(Deadline::in_ms(0).expired());
        assert!(!Deadline::in_ms(60_000).expired());
    }

    #[test]
    fn dropped_sender_is_error() {
        let (tx, rx) = mpsc::channel::<Result<PairResult>>();
        drop(tx);
        assert!(JobTicket { rx }.wait().is_err());
    }

    #[test]
    fn bucket_key_equality() {
        let a = BucketKey { kind: KernelKind::Dtw, t: 60, plane_key: 1, nu_bits: 0 };
        let b = BucketKey { kind: KernelKind::Dtw, t: 60, plane_key: 1, nu_bits: 0 };
        let c = BucketKey { kind: KernelKind::Dtw, t: 60, plane_key: 2, nu_bits: 0 };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
