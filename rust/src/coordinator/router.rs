//! Routing policy: decide per job whether it runs on the native Rust DP
//! or on an AOT PJRT executable.  Pure and unit-testable.
//!
//! Policy (DESIGN.md §7): a job is PJRT-eligible iff the manifest has an
//! artifact for its (kernel, exact T) bucket; otherwise it falls back to
//! native.  `prefer_pjrt = false` keeps everything native (the default
//! for the experiment sweeps, where the native path is faster for the
//! short series of the archive); the serving demo flips it on.

use crate::coordinator::request::Backend;
use crate::runtime::{EngineInfo, KernelKind};

#[derive(Clone, Debug)]
pub struct Router {
    info: Option<EngineInfo>,
    pub prefer_pjrt: bool,
}

impl Router {
    pub fn new(info: Option<EngineInfo>, prefer_pjrt: bool) -> Self {
        Router { info, prefer_pjrt }
    }

    /// Does an artifact bucket exist for (kernel, T)?  Every manifest
    /// entry appears in `EngineInfo::batch_of`, so one lookup covers all
    /// kernel kinds — the lane-batched LB_Keogh/SP-DTW buckets included.
    pub fn has_bucket(&self, kind: KernelKind, t: usize) -> bool {
        self.batch_size(kind, t).is_some()
    }

    /// Batch size of the bucket, if it exists.
    pub fn batch_size(&self, kind: KernelKind, t: usize) -> Option<usize> {
        self.info.as_ref().and_then(|i| i.kernel_batch(kind, t))
    }

    /// Routing decision for a job.
    pub fn route(&self, kind: KernelKind, t: usize) -> Backend {
        if self.prefer_pjrt && self.has_bucket(kind, t) {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info() -> EngineInfo {
        EngineInfo {
            platform: "cpu".into(),
            dtw_lengths: vec![60, 128],
            krdtw_lengths: vec![60],
            batch_of: vec![
                ("dtw".into(), 60, 32),
                ("dtw".into(), 128, 32),
                ("krdtw".into(), 60, 32),
            ],
        }
    }

    #[test]
    fn no_engine_all_native() {
        let r = Router::new(None, true);
        assert_eq!(r.route(KernelKind::Dtw, 60), Backend::Native);
        assert!(!r.has_bucket(KernelKind::Dtw, 60));
    }

    #[test]
    fn prefer_pjrt_routes_matching_lengths() {
        let r = Router::new(Some(info()), true);
        assert_eq!(r.route(KernelKind::Dtw, 60), Backend::Pjrt);
        assert_eq!(r.route(KernelKind::Dtw, 61), Backend::Native); // no bucket
        assert_eq!(r.route(KernelKind::Krdtw, 60), Backend::Pjrt);
        assert_eq!(r.route(KernelKind::Krdtw, 128), Backend::Native);
    }

    #[test]
    fn native_preference_wins() {
        let r = Router::new(Some(info()), false);
        assert_eq!(r.route(KernelKind::Dtw, 60), Backend::Native);
    }

    #[test]
    fn batch_size_lookup() {
        let r = Router::new(Some(info()), true);
        assert_eq!(r.batch_size(KernelKind::Dtw, 60), Some(32));
        assert_eq!(r.batch_size(KernelKind::Dtw, 61), None);
    }

    #[test]
    fn lane_kernels_route_via_batch_of() {
        let mut i = info();
        i.batch_of.push(("lb_keogh".into(), 60, 8));
        i.batch_of.push(("spdtw".into(), 60, 8));
        let r = Router::new(Some(i), true);
        assert_eq!(r.route(KernelKind::LbKeogh, 60), Backend::Pjrt);
        assert_eq!(r.route(KernelKind::Spdtw, 60), Backend::Pjrt);
        assert_eq!(r.route(KernelKind::Spdtw, 61), Backend::Native);
        assert_eq!(r.batch_size(KernelKind::LbKeogh, 60), Some(8));
    }
}
