//! Cheap lower bounds on the DP distances, evaluated against the
//! envelopes cached in [`crate::search::Index`].
//!
//! Admissibility: every alignment path aligns `(0, 0)` and
//! `(T-1, T-1)` and visits every row `i`, pairing `x[i]` only with
//! `y[j]` for `|i - j| ≤ r` (`r` = the index envelope radius, which
//! covers the DP band or the LOC grid's widest off-diagonal).  The
//! squared distance from `x[i]` to the envelope `[l_i, u_i]` of those
//! reachable `y[j]` therefore lower-bounds the cell cost — summing any
//! subset of rows lower-bounds the full path cost (cell weights are
//! ≥ 1; see [`crate::search::Index::lb_valid`]).

/// Squared distance from `x` to the interval `[l, u]` (0 inside).
#[inline(always)]
pub fn env_dist2(x: f64, u: f64, l: f64) -> f64 {
    if x > u {
        (x - u) * (x - u)
    } else if x < l {
        (l - x) * (l - x)
    } else {
        0.0
    }
}

/// O(1) endpoint bound: the first + last terms of LB_Keogh's sum.
///
/// Deliberately the *envelope-clamped* endpoints rather than the classic
/// raw `φ(x_0, y_0) + φ(x_last, y_last)` of Kim et al.: clamping makes
/// `lb_kim ≤ lb_keogh` hold unconditionally (the cascade-monotonicity
/// property), while remaining a true lower bound on the DP distance.
#[inline]
pub fn lb_kim(query: &[f64], upper: &[f64], lower: &[f64]) -> f64 {
    let t = query.len();
    debug_assert!(t > 0 && upper.len() == t && lower.len() == t);
    let head = env_dist2(query[0], upper[0], lower[0]);
    if t == 1 {
        head
    } else {
        head + env_dist2(query[t - 1], upper[t - 1], lower[t - 1])
    }
}

/// Full O(T) LB_Keogh sum of `query` against an envelope.  Identical to
/// [`crate::measures::lb_keogh::lb_keogh`]; re-exported here so the
/// cascade reads as one unit.
#[inline]
pub fn lb_keogh_sum(query: &[f64], upper: &[f64], lower: &[f64]) -> f64 {
    crate::measures::lb_keogh::lb_keogh(query, upper, lower)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::dtw::dtw_banded;
    use crate::measures::lb_keogh::envelope;
    use crate::util::rng::Pcg64;

    #[test]
    fn env_dist2_cases() {
        assert_eq!(env_dist2(3.0, 2.0, 1.0), 1.0); // above
        assert_eq!(env_dist2(0.0, 2.0, 1.0), 1.0); // below
        assert_eq!(env_dist2(1.5, 2.0, 1.0), 0.0); // inside
    }

    #[test]
    fn kim_is_below_keogh_is_below_dtw() {
        let mut rng = Pcg64::new(5);
        for _ in 0..40 {
            let t = 2 + rng.below(30);
            let x: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            for r in [1usize, 4, 9] {
                let (u, l) = envelope(&y, r);
                let kim = lb_kim(&x, &u, &l);
                let keogh = lb_keogh_sum(&x, &u, &l);
                let d = dtw_banded(&x, &y, r).value;
                assert!(kim <= keogh + 1e-12, "kim {kim} > keogh {keogh}");
                assert!(keogh <= d + 1e-9, "keogh {keogh} > dtw {d}");
            }
        }
    }

    #[test]
    fn single_point_series() {
        let (u, l) = envelope(&[2.0], 3);
        assert_eq!(lb_kim(&[5.0], &u, &l), 9.0);
        assert_eq!(lb_keogh_sum(&[5.0], &u, &l), 9.0);
    }
}
