//! Early-abandoning DP kernels: banded DTW and SP-DTW variants that
//! stop as soon as a completed DP row proves the final distance cannot
//! beat an upper bound.
//!
//! Soundness: DP values only accumulate non-negative cell costs, and
//! every admissible alignment path visits every row, so the final
//! distance is ≥ the minimum DP value of any completed row.  Once that
//! row minimum reaches `ub`, the candidate can be abandoned ("Early
//! Abandoned PrunedDTW", Herrmann & Webb 2020 — the lower-bound view of
//! the same cascade the UCR suite popularized).
//!
//! Bit-exactness: both kernels replicate the floating-point operation
//! order of their exhaustive counterparts
//! ([`crate::measures::dtw::dtw_banded`] and
//! [`crate::measures::spdtw::SpDtw::eval`]) — tracking the row minimum
//! adds comparisons, never arithmetic — so a non-abandoned evaluation
//! returns the exact same `f64` the exhaustive kernel would (property:
//! `prop_early_abandon_exact_when_completed`).

use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, BIG};
use crate::sparse::loc::NO_PRED;
use crate::sparse::LocMatrix;

/// Outcome of one early-abandoning evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EaResult {
    /// The exact DP distance, or `None` if the evaluation abandoned
    /// (in which case the true distance is provably ≥ the `ub` given).
    pub value: Option<f64>,
    /// DP cells computed before returning (≤ the exhaustive count).
    pub visited: u64,
}

/// Early-abandoning banded DTW.  `ub = f64::INFINITY` disables
/// abandoning, making this an exact drop-in for
/// [`crate::measures::dtw::dtw_banded`].  Routes through the calling
/// thread's TLS workspace; see [`dtw_banded_ea_into`].
pub fn dtw_banded_ea(x: &[f64], y: &[f64], band: usize, ub: f64) -> EaResult {
    workspace::with_tls(|ws| dtw_banded_ea_into(ws, x, y, band, ub))
}

/// [`dtw_banded_ea`] against caller-provided scratch — the engine's
/// candidate loop reuses one workspace across every DP it runs, so the
/// steady-state search path performs zero allocations per candidate.
pub fn dtw_banded_ea_into(
    ws: &mut DpWorkspace,
    x: &[f64],
    y: &[f64],
    band: usize,
    ub: f64,
) -> EaResult {
    let tx = x.len();
    let ty = y.len();
    assert!(tx > 0 && ty > 0, "empty series");
    let slope = ty as f64 / tx as f64;
    let unbounded = band == usize::MAX || band >= tx.max(ty);
    let (mut prev, mut cur) = ws.rows(ty, BIG);
    let mut visited: u64 = 0;

    for (i, &xi) in x.iter().enumerate() {
        let center = (i as f64 * slope) as usize;
        let (lo, hi) = if unbounded {
            (0, ty - 1)
        } else {
            (center.saturating_sub(band), (center + band).min(ty - 1))
        };
        visited += (hi - lo + 1) as u64;
        let mut row_min = f64::INFINITY;
        if i == 0 {
            let mut acc = 0.0f64;
            for j in lo..=hi {
                acc += phi(xi, y[j]);
                cur[j] = acc;
                if acc < row_min {
                    row_min = acc;
                }
            }
        } else {
            let mut prev_jm1 = if lo > 0 { prev[lo - 1] } else { BIG };
            let mut cur_jm1 = BIG;
            let yrow = &y[lo..=hi];
            let prow = &prev[lo..=hi];
            let crow = &mut cur[lo..=hi];
            for ((&yj, &pj), cj) in yrow.iter().zip(prow).zip(crow.iter_mut()) {
                let mut b = pj;
                if prev_jm1 < b {
                    b = prev_jm1;
                }
                if cur_jm1 < b {
                    b = cur_jm1;
                }
                let v = phi(xi, yj) + b;
                *cj = v;
                cur_jm1 = v;
                prev_jm1 = pj;
                if v < row_min {
                    row_min = v;
                }
            }
        }
        std::mem::swap(&mut prev, &mut cur);
        if !unbounded {
            for c in cur.iter_mut() {
                *c = BIG;
            }
        }
        if ub.is_finite() && row_min >= ub {
            return EaResult {
                value: None,
                visited,
            };
        }
    }
    EaResult {
        value: Some(prev[ty - 1]),
        visited,
    }
}

/// Early-abandoning SP-DTW over a LOC sparse grid: the best-so-far
/// upper bound is threaded through the grid's CSR rows, abandoning as
/// soon as a row's minimum DP value reaches it.  Per-cell arithmetic is
/// identical to [`crate::measures::spdtw::SpDtw::eval`].
///
/// Note on empty rows: a row with no retained cell means no admissible
/// path exists at all; with a finite `ub` the evaluation abandons there
/// (the true distance is `Max_Float` ≥ any finite bound).
pub fn spdtw_ea(loc: &LocMatrix, x: &[f64], y: &[f64], ub: f64) -> EaResult {
    workspace::with_tls(|ws| spdtw_ea_into(ws, loc, x, y, ub))
}

/// [`spdtw_ea`] against caller-provided scratch (the entry-parallel DP
/// array) — zero allocations once warm, bit-identical results.
pub fn spdtw_ea_into(
    ws: &mut DpWorkspace,
    loc: &LocMatrix,
    x: &[f64],
    y: &[f64],
    ub: f64,
) -> EaResult {
    let t = loc.t;
    assert_eq!(x.len(), t, "series length {} != grid size {t}", x.len());
    assert_eq!(y.len(), t, "series length {} != grid size {t}", y.len());
    let n = loc.nnz();
    let d = &mut ws.entries;
    d.clear();
    d.resize(n, BIG);
    let mut visited: u64 = 0;
    for r in 0..t {
        let (rs, re) = (loc.row_ptr[r], loc.row_ptr[r + 1]);
        let mut row_min = f64::INFINITY;
        for k in rs..re {
            let c = loc.cols[k] as usize;
            let local = loc.weights[k] * phi(x[r], y[c]);
            let best = if r == 0 && c == 0 {
                0.0
            } else {
                let p = loc.preds[k];
                let mut b = BIG;
                for &pi in &p {
                    if pi != NO_PRED {
                        let v = d[pi as usize];
                        if v < b {
                            b = v;
                        }
                    }
                }
                b
            };
            let v = local + best;
            d[k] = v;
            if v < row_min {
                row_min = v;
            }
        }
        visited += (re - rs) as u64;
        if ub.is_finite() && row_min >= ub {
            return EaResult {
                value: None,
                visited,
            };
        }
    }
    let corner = loc
        .index_of(t - 1, t - 1)
        .map(|k| d[k])
        .unwrap_or(BIG + BIG);
    EaResult {
        value: Some(corner),
        visited,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::dtw::dtw_banded;
    use crate::measures::spdtw::SpDtw;
    use crate::util::rng::Pcg64;

    fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
        (0..t).map(|_| rng.normal()).collect()
    }

    #[test]
    fn infinite_ub_is_bitwise_exhaustive_dtw() {
        let mut rng = Pcg64::new(11);
        for _ in 0..30 {
            let tx = 2 + rng.below(30);
            let ty = 2 + rng.below(30);
            let x = rand_vec(&mut rng, tx);
            let y = rand_vec(&mut rng, ty);
            for band in [1usize, 4, usize::MAX] {
                let exact = dtw_banded(&x, &y, band);
                let ea = dtw_banded_ea(&x, &y, band, f64::INFINITY);
                assert_eq!(ea.visited, exact.visited_cells);
                assert_eq!(
                    ea.value.unwrap().to_bits(),
                    exact.value.to_bits(),
                    "band={band}"
                );
            }
        }
    }

    #[test]
    fn abandons_are_sound_and_save_cells() {
        let mut rng = Pcg64::new(13);
        let mut abandoned_seen = 0;
        let mut cells_saved = 0u64;
        for _ in 0..40 {
            let t = 8 + rng.below(24);
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            let exact = dtw_banded(&x, &y, usize::MAX);
            for frac in [0.1, 0.5, 0.9, 1.0] {
                let ub = frac * exact.value;
                let ea = dtw_banded_ea(&x, &y, usize::MAX, ub);
                match ea.value {
                    Some(v) => assert_eq!(v.to_bits(), exact.value.to_bits()),
                    None => {
                        abandoned_seen += 1;
                        assert!(exact.value >= ub, "abandoned but true {} < ub {ub}", exact.value);
                        assert!(ea.visited <= exact.visited_cells);
                        cells_saved += exact.visited_cells - ea.visited;
                    }
                }
            }
        }
        assert!(abandoned_seen > 0, "no abandonment ever triggered");
        assert!(cells_saved > 0, "abandoning never saved any cells");
    }

    #[test]
    fn spdtw_ea_matches_eval_and_abandons() {
        let mut rng = Pcg64::new(17);
        for t in [6usize, 15, 28] {
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            for band in [1usize, 3] {
                let loc = LocMatrix::corridor(t, band);
                let sp = SpDtw::new(loc.clone());
                let exact = sp.eval(&x, &y);
                let ea = spdtw_ea(&loc, &x, &y, f64::INFINITY);
                assert_eq!(ea.visited, exact.visited_cells);
                assert_eq!(ea.value.unwrap().to_bits(), exact.value.to_bits());
                let tight = spdtw_ea(&loc, &x, &y, 0.5 * exact.value);
                if let Some(v) = tight.value {
                    assert_eq!(v.to_bits(), exact.value.to_bits());
                } else {
                    assert!(exact.value >= 0.5 * exact.value);
                    assert!(tight.visited <= exact.visited_cells);
                }
            }
        }
    }

    #[test]
    fn zero_ub_abandons_on_first_row() {
        let x = vec![1.0; 16];
        let y = vec![2.0; 16];
        let ea = dtw_banded_ea(&x, &y, usize::MAX, 0.0);
        assert_eq!(ea.value, None);
        assert_eq!(ea.visited, 16); // exactly one row
    }
}
