//! Early-abandoning DP kernels: banded DTW and SP-DTW variants that
//! stop as soon as a completed DP row proves the final distance cannot
//! beat an upper bound.
//!
//! Soundness: DP values only accumulate non-negative cell costs, and
//! every admissible alignment path visits every row, so the final
//! distance is ≥ the minimum DP value of any completed row.  Once that
//! row minimum reaches `ub`, the candidate can be abandoned ("Early
//! Abandoned PrunedDTW", Herrmann & Webb 2020 — the lower-bound view of
//! the same cascade the UCR suite popularized).
//!
//! Bit-exactness: both kernels replicate the floating-point operation
//! order of their exhaustive counterparts
//! ([`crate::measures::dtw::dtw_banded`] and
//! [`crate::measures::spdtw::SpDtw::eval`]) — tracking the row minimum
//! adds comparisons, never arithmetic — so a non-abandoned evaluation
//! returns the exact same `f64` the exhaustive kernel would (property:
//! `prop_early_abandon_exact_when_completed`).  This holds for
//! *degenerate* grids too: unreachable-corner and empty-row grids
//! report the same sentinel-level values as the exhaustive kernel, and
//! abandoning never claims more than it can prove about them — so the
//! k-NN engine's `(dist, train idx)` tie-break stays exact even when
//! candidates tie at a sentinel distance (see [`spdtw_ea`]).

use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, BIG};
use crate::sparse::loc::NO_PRED;
use crate::sparse::LocMatrix;

/// Outcome of one early-abandoning evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EaResult {
    /// The exact DP distance, or `None` if the evaluation abandoned
    /// (in which case the true distance is provably ≥ the `ub` given).
    pub value: Option<f64>,
    /// DP cells computed before returning (≤ the exhaustive count).
    pub visited: u64,
}

/// Early-abandoning banded DTW.  `ub = f64::INFINITY` disables
/// abandoning, making this an exact drop-in for
/// [`crate::measures::dtw::dtw_banded`].  Routes through the calling
/// thread's TLS workspace; see [`dtw_banded_ea_into`].
pub fn dtw_banded_ea(x: &[f64], y: &[f64], band: usize, ub: f64) -> EaResult {
    workspace::with_tls(|ws| dtw_banded_ea_into(ws, x, y, band, ub))
}

/// [`dtw_banded_ea`] against caller-provided scratch — the engine's
/// candidate loop reuses one workspace across every DP it runs, so the
/// steady-state search path performs zero allocations per candidate.
pub fn dtw_banded_ea_into(
    ws: &mut DpWorkspace,
    x: &[f64],
    y: &[f64],
    band: usize,
    ub: f64,
) -> EaResult {
    let tx = x.len();
    let ty = y.len();
    assert!(tx > 0 && ty > 0, "empty series");
    let slope = ty as f64 / tx as f64;
    let unbounded = band == usize::MAX || band >= tx.max(ty);
    let (mut prev, mut cur) = ws.rows(ty, BIG);
    let mut visited: u64 = 0;

    for (i, &xi) in x.iter().enumerate() {
        let center = (i as f64 * slope) as usize;
        let (lo, hi) = if unbounded {
            (0, ty - 1)
        } else {
            (center.saturating_sub(band), (center + band).min(ty - 1))
        };
        visited += (hi - lo + 1) as u64;
        let mut row_min = f64::INFINITY;
        if i == 0 {
            let mut acc = 0.0f64;
            for j in lo..=hi {
                acc += phi(xi, y[j]);
                cur[j] = acc;
                if acc < row_min {
                    row_min = acc;
                }
            }
        } else {
            let mut prev_jm1 = if lo > 0 { prev[lo - 1] } else { BIG };
            let mut cur_jm1 = BIG;
            let yrow = &y[lo..=hi];
            let prow = &prev[lo..=hi];
            let crow = &mut cur[lo..=hi];
            for ((&yj, &pj), cj) in yrow.iter().zip(prow).zip(crow.iter_mut()) {
                let mut b = pj;
                if prev_jm1 < b {
                    b = prev_jm1;
                }
                if cur_jm1 < b {
                    b = cur_jm1;
                }
                let v = phi(xi, yj) + b;
                *cj = v;
                cur_jm1 = v;
                prev_jm1 = pj;
                if v < row_min {
                    row_min = v;
                }
            }
        }
        std::mem::swap(&mut prev, &mut cur);
        if !unbounded {
            for c in cur.iter_mut() {
                *c = BIG;
            }
        }
        if ub.is_finite() && row_min >= ub {
            return EaResult {
                value: None,
                visited,
            };
        }
    }
    EaResult {
        value: Some(prev[ty - 1]),
        visited,
    }
}

/// Early-abandoning SP-DTW over a LOC sparse grid: the best-so-far
/// upper bound is threaded through the grid's CSR rows, abandoning as
/// soon as a row's minimum DP value reaches it.  Per-cell arithmetic is
/// identical to [`crate::measures::spdtw::SpDtw::eval`].
///
/// Exactness extends to degenerate grids: a grid without the
/// bottom-right corner cell reports the same `BIG + BIG` sentinel the
/// exhaustive kernel does (decided up front, no DP needed), and a grid
/// with an empty row only proves the distance is ≥ `BIG` — the corner
/// value is still a *specific* finite number that can tie exactly at a
/// k-NN boundary, so the kernel abandons on an empty row only when
/// `BIG` itself clears `ub` and otherwise completes the DP.  That keeps
/// the engine's `(dist, train idx)` tie-break exact for every grid, not
/// just connected ones.
pub fn spdtw_ea(loc: &LocMatrix, x: &[f64], y: &[f64], ub: f64) -> EaResult {
    workspace::with_tls(|ws| spdtw_ea_into(ws, loc, x, y, ub))
}

/// [`spdtw_ea`] against caller-provided scratch (the entry-parallel DP
/// array) — zero allocations once warm, bit-identical results.
pub fn spdtw_ea_into(
    ws: &mut DpWorkspace,
    loc: &LocMatrix,
    x: &[f64],
    y: &[f64],
    ub: f64,
) -> EaResult {
    let t = loc.t;
    assert_eq!(x.len(), t, "series length {} != grid size {t}", x.len());
    assert_eq!(y.len(), t, "series length {} != grid size {t}", y.len());
    // A grid without the bottom-right corner cell always reports the
    // constant sentinel, regardless of anything the DP computes — so the
    // exact answer (which can tie against other sentinel candidates) is
    // known up front, and returning it directly is both faster and
    // tie-break exact.  `visited` is 0: no DP cell was computed.
    let Some(corner_k) = loc.index_of(t - 1, t - 1) else {
        return EaResult {
            value: Some(BIG + BIG),
            visited: 0,
        };
    };
    let n = loc.nnz();
    let d = &mut ws.entries;
    d.clear();
    d.resize(n, BIG);
    let mut visited: u64 = 0;
    for r in 0..t {
        let (rs, re) = (loc.row_ptr[r], loc.row_ptr[r + 1]);
        let mut row_min = f64::INFINITY;
        for k in rs..re {
            let c = loc.cols[k] as usize;
            let local = loc.weights[k] * phi(x[r], y[c]);
            let best = if r == 0 && c == 0 {
                0.0
            } else {
                let p = loc.preds[k];
                let mut b = BIG;
                for &pi in &p {
                    if pi != NO_PRED {
                        let v = d[pi as usize];
                        if v < b {
                            b = v;
                        }
                    }
                }
                b
            };
            let v = local + best;
            d[k] = v;
            if v < row_min {
                row_min = v;
            }
        }
        visited += (re - rs) as u64;
        // Every admissible path visits every row, so the final distance
        // is ≥ this row's minimum.  An empty row proves disconnection —
        // every later DP value (corner included) is ≥ BIG — but the
        // corner value is still a specific finite number that can tie
        // exactly at the k-th boundary, so the *proven* bound there is
        // BIG, not infinity: abandoning on a looser claim would drop a
        // tie-winning candidate (`(dist, train idx)` order).
        let proven = if re == rs { BIG } else { row_min };
        if ub.is_finite() && proven >= ub {
            return EaResult {
                value: None,
                visited,
            };
        }
    }
    EaResult {
        value: Some(d[corner_k]),
        visited,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::measures::dtw::dtw_banded;
    use crate::measures::spdtw::SpDtw;
    use crate::util::rng::Pcg64;

    fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
        (0..t).map(|_| rng.normal()).collect()
    }

    #[test]
    fn infinite_ub_is_bitwise_exhaustive_dtw() {
        let mut rng = Pcg64::new(11);
        for _ in 0..30 {
            let tx = 2 + rng.below(30);
            let ty = 2 + rng.below(30);
            let x = rand_vec(&mut rng, tx);
            let y = rand_vec(&mut rng, ty);
            for band in [1usize, 4, usize::MAX] {
                let exact = dtw_banded(&x, &y, band);
                let ea = dtw_banded_ea(&x, &y, band, f64::INFINITY);
                assert_eq!(ea.visited, exact.visited_cells);
                assert_eq!(
                    ea.value.unwrap().to_bits(),
                    exact.value.to_bits(),
                    "band={band}"
                );
            }
        }
    }

    #[test]
    fn abandons_are_sound_and_save_cells() {
        let mut rng = Pcg64::new(13);
        let mut abandoned_seen = 0;
        let mut cells_saved = 0u64;
        for _ in 0..40 {
            let t = 8 + rng.below(24);
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            let exact = dtw_banded(&x, &y, usize::MAX);
            for frac in [0.1, 0.5, 0.9, 1.0] {
                let ub = frac * exact.value;
                let ea = dtw_banded_ea(&x, &y, usize::MAX, ub);
                match ea.value {
                    Some(v) => assert_eq!(v.to_bits(), exact.value.to_bits()),
                    None => {
                        abandoned_seen += 1;
                        assert!(exact.value >= ub, "abandoned but true {} < ub {ub}", exact.value);
                        assert!(ea.visited <= exact.visited_cells);
                        cells_saved += exact.visited_cells - ea.visited;
                    }
                }
            }
        }
        assert!(abandoned_seen > 0, "no abandonment ever triggered");
        assert!(cells_saved > 0, "abandoning never saved any cells");
    }

    #[test]
    fn spdtw_ea_matches_eval_and_abandons() {
        let mut rng = Pcg64::new(17);
        for t in [6usize, 15, 28] {
            let x = rand_vec(&mut rng, t);
            let y = rand_vec(&mut rng, t);
            for band in [1usize, 3] {
                let loc = LocMatrix::corridor(t, band);
                let sp = SpDtw::new(loc.clone());
                let exact = sp.eval(&x, &y);
                let ea = spdtw_ea(&loc, &x, &y, f64::INFINITY);
                assert_eq!(ea.visited, exact.visited_cells);
                assert_eq!(ea.value.unwrap().to_bits(), exact.value.to_bits());
                let tight = spdtw_ea(&loc, &x, &y, 0.5 * exact.value);
                if let Some(v) = tight.value {
                    assert_eq!(v.to_bits(), exact.value.to_bits());
                } else {
                    assert!(exact.value >= 0.5 * exact.value);
                    assert!(tight.visited <= exact.visited_cells);
                }
            }
        }
    }

    #[test]
    fn zero_ub_abandons_on_first_row() {
        let x = vec![1.0; 16];
        let y = vec![2.0; 16];
        let ea = dtw_banded_ea(&x, &y, usize::MAX, 0.0);
        assert_eq!(ea.value, None);
        assert_eq!(ea.visited, 16); // exactly one row
    }

    #[test]
    fn cornerless_grid_returns_exact_sentinel_without_dp() {
        use crate::measures::BIG;
        use crate::util::mathx::next_up_f64;
        // no (t-1, t-1) cell: the exhaustive kernel reports BIG + BIG
        let loc = LocMatrix::from_triples(4, vec![(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)]);
        let x = vec![0.5; 4];
        let y = vec![-0.5; 4];
        let exact = SpDtw::new(loc.clone()).eval(&x, &y);
        assert_eq!(exact.value.to_bits(), (BIG + BIG).to_bits());
        for ub in [f64::INFINITY, 1.0, BIG, next_up_f64(BIG + BIG)] {
            let ea = spdtw_ea(&loc, &x, &y, ub);
            // the sentinel is a *value*, never an abandon: a candidate
            // tying at BIG + BIG must survive to the tie-break
            assert_eq!(ea.value.map(f64::to_bits), Some(exact.value.to_bits()), "ub={ub}");
            assert_eq!(ea.visited, 0);
        }
    }

    #[test]
    fn empty_row_tie_at_kth_boundary_completes_exactly() {
        use crate::measures::BIG;
        use crate::util::mathx::next_up_f64;
        // row 2 empty, corner present: disconnected, but the corner DP
        // value is a specific finite number (local(3,3) + BIG)
        let loc = LocMatrix::from_triples(
            4,
            vec![(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0), (3, 3, 1.0)],
        );
        let x = vec![0.0, 0.0, 0.0, 1.0];
        let y = vec![0.0, 0.0, 0.0, 3.0];
        let exact = SpDtw::new(loc.clone()).eval(&x, &y);
        assert!(exact.value >= BIG, "grid must be disconnected");

        // ub just above the true value (the `(dist, idx)` tie-winner
        // threshold): the kernel must COMPLETE and return the exact
        // value — the pre-fix empty-row abandon dropped it here.
        let tie = spdtw_ea(&loc, &x, &y, next_up_f64(exact.value));
        assert_eq!(tie.value.map(f64::to_bits), Some(exact.value.to_bits()));
        assert_eq!(tie.visited, exact.visited_cells);

        // a real (sub-BIG) bound still abandons at the empty row
        let ea = spdtw_ea(&loc, &x, &y, 10.0);
        assert_eq!(ea.value, None);
        assert!(ea.visited < exact.visited_cells);
    }
}
