//! Versioned on-disk persistence for [`Index`] — the warm-start path
//! that lets a serving restart skip the envelope/z-normalization build.
//!
//! # File format (`.spix`)
//!
//! Everything is **little-endian**.  A file is a fixed 24-byte header
//! followed by a checksummed payload:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"SPIX"
//! 4       4     format version, u32 (currently 1)
//! 8       8     payload length in bytes, u64
//! 16      8     FNV-1a 64 checksum of the payload bytes, u64
//! 24      ...   payload
//! ```
//!
//! Payload layout (version 1):
//!
//! ```text
//! flags      u32   bit 0 = znormalized, bit 1 = lb_valid, bit 2 = has grid
//! t          u64   series length
//! radius     u64   envelope radius
//! band       u64   DP band (u64::MAX = unbounded)
//! n          u64   number of train series
//! nnz        u64   grid entry count (0 when bit 2 is clear)
//! labels     n × u64
//! series     n × t × f64 (IEEE-754 bit patterns, exactly as built)
//! envelopes  n × (t × f64 upper, then t × f64 lower)
//! grid       nnz × (row u32, col u32, weight f64)   — only when bit 2 set
//! ```
//!
//! # Integrity
//!
//! A loader must never turn a bad file into a wrong search answer, so
//! [`load_index`] rejects, with a clean [`Error::Data`]:
//!
//! * wrong magic or unsupported version (stale format),
//! * a payload length that disagrees with the file size (truncation
//!   or trailing garbage),
//! * a checksum mismatch (bit rot, partial writes),
//! * unknown flag bits (a newer writer's file),
//! * structurally valid payloads that violate the [`Index`] invariants:
//!   radius/band inconsistency, grid entries out of range, an `lb_valid`
//!   flag the grid weights do not support, or stored envelopes that do
//!   not actually bound their series.
//!
//! Saves go through a temp file + atomic rename, so a crashed writer
//! leaves either the old file or none — never a torn one.

use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::search::Index;
use crate::sparse::LocMatrix;

/// File magic: identifies a serialized search index.
pub const MAGIC: [u8; 4] = *b"SPIX";
/// Current format version; bump on any layout change.
pub const VERSION: u32 = 1;
/// Fixed header size (magic + version + payload length + checksum).
pub const HEADER_LEN: usize = 24;

const FLAG_ZNORM: u32 = 1 << 0;
const FLAG_LB_VALID: u32 = 1 << 1;
const FLAG_HAS_GRID: u32 = 1 << 2;
const KNOWN_FLAGS: u32 = FLAG_ZNORM | FLAG_LB_VALID | FLAG_HAS_GRID;

/// FNV-1a-64 offset basis (the hash of the empty input).
pub const FNV1A64_INIT: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit hash — the payload checksum (dependency-free, good
/// dispersion for the "did this file get corrupted" question).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_extend(FNV1A64_INIT, bytes)
}

/// Streaming FNV-1a-64: fold `bytes` into a running hash (seed with
/// [`FNV1A64_INIT`]).  Used by [`crate::search::Index::content_hash`]
/// to hash multi-buffer payloads without assembling them.
pub fn fnv1a64_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Header + dimension summary of an index file (the `inspect` view).
#[derive(Clone, Debug)]
pub struct IndexFileInfo {
    pub version: u32,
    pub file_bytes: usize,
    pub checksum_ok: bool,
    pub t: usize,
    pub n: usize,
    pub radius: usize,
    /// DP band (`usize::MAX` = unbounded / grid-driven).
    pub band: usize,
    pub znormalized: bool,
    pub lb_valid: bool,
    /// Grid entry count, when an SP-DTW grid is attached.
    pub grid_nnz: Option<usize>,
}

/// Serialize `index` into the `.spix` byte format.
pub fn to_bytes(index: &Index) -> Vec<u8> {
    let n = index.len();
    let t = index.t;
    let nnz = index.loc.as_ref().map(|l| l.nnz()).unwrap_or(0);
    let mut payload = Vec::with_capacity(44 + n * 8 + n * t * 24 + nnz * 16);

    let mut flags = 0u32;
    if index.znormalized {
        flags |= FLAG_ZNORM;
    }
    if index.lb_valid {
        flags |= FLAG_LB_VALID;
    }
    if index.loc.is_some() {
        flags |= FLAG_HAS_GRID;
    }
    payload.extend_from_slice(&flags.to_le_bytes());
    for dim in [t as u64, index.radius as u64, index.band as u64, n as u64, nnz as u64] {
        payload.extend_from_slice(&dim.to_le_bytes());
    }
    for &label in &index.labels {
        payload.extend_from_slice(&(label as u64).to_le_bytes());
    }
    for s in &index.series {
        for &v in s {
            payload.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    for (u, l) in &index.envs {
        for &v in u {
            payload.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        for &v in l {
            payload.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    if let Some(loc) = &index.loc {
        for (r, c, w, _) in loc.iter_cells() {
            payload.extend_from_slice(&(r as u32).to_le_bytes());
            payload.extend_from_slice(&(c as u32).to_le_bytes());
            payload.extend_from_slice(&w.to_bits().to_le_bytes());
        }
    }

    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Deserialize an [`Index`] from `.spix` bytes, rejecting anything
/// corrupt, truncated or inconsistent (see the module docs).
pub fn from_bytes(bytes: &[u8]) -> Result<Index> {
    let payload = checked_payload(bytes)?;
    let mut r = Reader { b: payload, i: 0 };

    let flags = r.u32()?;
    if flags & !KNOWN_FLAGS != 0 {
        return Err(Error::data(format!(
            "index file has unknown flag bits {:#x} (written by a newer version?)",
            flags & !KNOWN_FLAGS
        )));
    }
    let t = r.dim("t")?;
    let radius = r.dim("radius")?;
    let band = r.dim("band")?;
    let n = r.dim("n")?;
    let nnz = r.dim("nnz")?;
    let has_grid = flags & FLAG_HAS_GRID != 0;

    if t == 0 || n == 0 {
        return Err(Error::data("index file holds an empty index"));
    }
    if radius >= t {
        return Err(Error::data(format!(
            "index file radius {radius} out of range for T={t}"
        )));
    }
    if !has_grid && nnz > 0 {
        return Err(Error::data("index file grid flag disagrees with entry count"));
    }

    // The payload is fixed-size given the dims: anything else is a
    // truncated or padded file that slipped past the outer length check.
    let expected = 44usize
        .checked_add(n.checked_mul(8).ok_or_else(oversize)?)
        .and_then(|v| v.checked_add(n.checked_mul(t)?.checked_mul(24)?))
        .and_then(|v| v.checked_add(nnz.checked_mul(16)?))
        .ok_or_else(oversize)?;
    if payload.len() != expected {
        return Err(Error::data(format!(
            "index file payload is {} bytes but dims require {expected}",
            payload.len()
        )));
    }

    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        labels.push(r.dim("label")?);
    }
    let mut series = Vec::with_capacity(n);
    for _ in 0..n {
        series.push(r.f64s(t)?);
    }
    let mut envs = Vec::with_capacity(n);
    for _ in 0..n {
        let u = r.f64s(t)?;
        let l = r.f64s(t)?;
        envs.push((u, l));
    }
    let loc = if has_grid {
        let mut triples = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let row = r.u32()? as usize;
            let col = r.u32()? as usize;
            let w = f64::from_bits(r.u64()?);
            triples.push((row, col, w));
        }
        Some(Arc::new(LocMatrix::try_from_triples(t, triples)?))
    } else {
        None
    };
    debug_assert_eq!(r.i, payload.len());

    // ---- semantic invariants: a structurally valid file must still
    // describe an index that searches correctly --------------------------
    let lb_valid = flags & FLAG_LB_VALID != 0;
    match &loc {
        Some(grid) => {
            if band != usize::MAX {
                return Err(Error::data("grid index must store an unbounded band"));
            }
            if radius < grid.max_band_offset() {
                return Err(Error::data(format!(
                    "index file radius {radius} narrower than grid reach {} — \
                     envelope bounds would be inadmissible",
                    grid.max_band_offset()
                )));
            }
            if lb_valid && grid.min_weight() < 1.0 - 1e-12 {
                return Err(Error::data(
                    "index file claims admissible lower bounds but grid has sub-unit weights",
                ));
            }
        }
        None => {
            if band.min(t - 1) != radius {
                return Err(Error::data(format!(
                    "index file radius {radius} inconsistent with band {band} (T={t})"
                )));
            }
        }
    }
    for (i, ((u, l), s)) in envs.iter().zip(&series).enumerate() {
        for j in 0..t {
            if !(l[j] <= s[j] && s[j] <= u[j]) {
                return Err(Error::data(format!(
                    "index file envelope of series {i} does not bound it at position {j}"
                )));
            }
        }
    }

    Ok(Index {
        t,
        radius,
        band,
        series,
        labels,
        envs,
        loc,
        lb_valid,
        znormalized: flags & FLAG_ZNORM != 0,
    })
}

/// Save `index` to `path` (atomically: temp file + rename).  The
/// conventional extension is `.spix`.
pub fn save_index(index: &Index, path: &Path) -> Result<()> {
    let bytes = to_bytes(index);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = path.with_extension("spix.tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        Error::Io(e)
    })
}

/// Load an [`Index`] previously written by [`save_index`].
pub fn load_index(path: &Path) -> Result<Index> {
    let bytes = read_spix_bytes(path).map_err(|e| prefix_path(path, e))?;
    from_bytes(&bytes).map_err(|e| prefix_path(path, e))
}

/// Header/dimension summary of an index file without materializing the
/// series (still hashes the payload to report checksum validity).
pub fn inspect(path: &Path) -> Result<IndexFileInfo> {
    let bytes = read_spix_bytes(path).map_err(|e| prefix_path(path, e))?;
    let payload = checked_payload_relaxed(&bytes)?;
    let mut r = Reader { b: payload.0, i: 0 };
    let flags = r.u32()?;
    let t = r.dim("t")?;
    let radius = r.dim("radius")?;
    let band = r.dim("band")?;
    let n = r.dim("n")?;
    let nnz = r.dim("nnz")?;
    Ok(IndexFileInfo {
        version: VERSION,
        file_bytes: bytes.len(),
        checksum_ok: payload.1,
        t,
        n,
        radius,
        band,
        znormalized: flags & FLAG_ZNORM != 0,
        lb_valid: flags & FLAG_LB_VALID != 0,
        grid_nnz: if flags & FLAG_HAS_GRID != 0 { Some(nnz) } else { None },
    })
}

fn oversize() -> Error {
    Error::data("index file dimensions overflow")
}

fn prefix_path(path: &Path, e: Error) -> Error {
    Error::data(format!("{}: {e}", path.display()))
}

/// Sequential `.spix` read with ONE pre-sized allocation: the fixed
/// header is read first, validated (magic, version), and its payload
/// length — cross-checked against the file's metadata size — sizes a
/// single `Vec` the rest of the file is `read_exact` into.  Unlike a
/// bare `std::fs::read`, a corrupt length field (or a file that shrank
/// or grew behind the header) is rejected *before* any payload-sized
/// allocation, and the one-shot sequential read keeps the page-cache
/// access pattern mmap-friendly for multi-hundred-MB shard stores.
fn read_spix_bytes(path: &Path) -> Result<Vec<u8>> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)
        .map_err(|e| Error::data(format!("cannot read index file: {e}")))?;
    let mut header = [0u8; HEADER_LEN];
    f.read_exact(&mut header).map_err(|_| {
        Error::data(format!(
            "index file truncated: header needs {HEADER_LEN} bytes"
        ))
    })?;
    if header[0..4] != MAGIC {
        return Err(Error::data("not a spdtw index file (bad magic)"));
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(Error::data(format!(
            "unsupported index file version {version} (this build reads {VERSION})"
        )));
    }
    let payload_len = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let on_disk = f.metadata()?.len().saturating_sub(HEADER_LEN as u64);
    if payload_len != on_disk {
        return Err(Error::data(format!(
            "index file truncated or padded: \
             header says {payload_len} payload bytes, file has {on_disk}"
        )));
    }
    let payload_len = usize::try_from(payload_len).map_err(|_| oversize())?;
    let total = HEADER_LEN.checked_add(payload_len).ok_or_else(oversize)?;
    let mut bytes = vec![0u8; total];
    bytes[..HEADER_LEN].copy_from_slice(&header);
    f.read_exact(&mut bytes[HEADER_LEN..])
        .map_err(|_| Error::data("index file shrank while reading (concurrent writer?)"))?;
    Ok(bytes)
}

/// Validate header + checksum, returning the payload slice.
fn checked_payload(bytes: &[u8]) -> Result<&[u8]> {
    let (payload, checksum_ok) = checked_payload_relaxed(bytes)?;
    if !checksum_ok {
        return Err(Error::data("index file checksum mismatch (corrupt file)"));
    }
    Ok(payload)
}

/// Like [`checked_payload`] but reports checksum validity instead of
/// failing on it (the `inspect` path).
fn checked_payload_relaxed(bytes: &[u8]) -> Result<(&[u8], bool)> {
    if bytes.len() < HEADER_LEN {
        return Err(Error::data(format!(
            "index file truncated: {} bytes, header needs {HEADER_LEN}",
            bytes.len()
        )));
    }
    if bytes[0..4] != MAGIC {
        return Err(Error::data("not a spdtw index file (bad magic)"));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(Error::data(format!(
            "unsupported index file version {version} (this build reads {VERSION})"
        )));
    }
    let payload_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let actual = (bytes.len() - HEADER_LEN) as u64;
    if payload_len != actual {
        return Err(Error::data(format!(
            "index file truncated or padded: \
             header says {payload_len} payload bytes, file has {actual}"
        )));
    }
    let checksum = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    Ok((payload, fnv1a64(payload) == checksum))
}

/// Bounds-checked little-endian cursor over the payload.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(len)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| Error::data("index file payload ends mid-field"))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A u64 that must fit in usize on this platform.
    fn dim(&mut self, what: &str) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| Error::data(format!("index file {what} {v} exceeds platform usize")))
    }

    fn f64s(&mut self, count: usize) -> Result<Vec<f64>> {
        let raw = self.take(count * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::data::synthetic;

    fn sample_index() -> Index {
        let ds = synthetic::generate_scaled("CBF", 11, 8, 2).unwrap();
        Index::build(&ds.train, 4, 2)
    }

    fn assert_same(a: &Index, b: &Index) {
        assert_eq!(a.t, b.t);
        assert_eq!(a.radius, b.radius);
        assert_eq!(a.band, b.band);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.lb_valid, b.lb_valid);
        assert_eq!(a.znormalized, b.znormalized);
        for (x, y) in a.series.iter().zip(&b.series) {
            for (p, q) in x.iter().zip(y) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
        for ((ua, la), (ub, lb)) in a.envs.iter().zip(&b.envs) {
            for (p, q) in ua.iter().zip(ub).chain(la.iter().zip(lb)) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
        match (&a.loc, &b.loc) {
            (None, None) => {}
            (Some(x), Some(y)) => assert_eq!(x.as_ref(), y.as_ref()),
            _ => panic!("grid presence differs"),
        }
    }

    #[test]
    fn roundtrip_banded_bitexact() {
        let idx = sample_index();
        let back = from_bytes(&to_bytes(&idx)).unwrap();
        assert_same(&idx, &back);
    }

    #[test]
    fn roundtrip_spdtw_and_znorm_variants() {
        let ds = synthetic::generate_scaled("Gun-Point", 3, 6, 2).unwrap();
        let loc = std::sync::Arc::new(LocMatrix::corridor(ds.series_len(), 3));
        let sp = Index::build_spdtw(&ds.train, loc, 1);
        assert_same(&sp, &from_bytes(&to_bytes(&sp)).unwrap());

        let zn = Index::build_znormalized(&ds.train, 2, 1);
        let back = from_bytes(&to_bytes(&zn)).unwrap();
        assert!(back.znormalized);
        assert_same(&zn, &back);
    }

    #[test]
    fn rejects_bad_magic_version_and_truncation() {
        let idx = sample_index();
        let good = to_bytes(&idx);

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad).unwrap_err().to_string().contains("magic"));

        let mut bumped = good.clone();
        bumped[4] = 2;
        let err = from_bytes(&bumped).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        for cut in [0, 3, HEADER_LEN - 1, HEADER_LEN + 10, good.len() - 1] {
            assert!(from_bytes(&good[..cut]).is_err(), "cut at {cut} accepted");
        }
        let mut padded = good.clone();
        padded.push(0);
        assert!(from_bytes(&padded).is_err());
    }

    #[test]
    fn rejects_flipped_payload_byte() {
        let idx = sample_index();
        let good = to_bytes(&idx);
        for probe in [HEADER_LEN, HEADER_LEN + 45, good.len() - 1] {
            let mut bad = good.clone();
            bad[probe] ^= 0x40;
            let err = from_bytes(&bad).unwrap_err().to_string();
            assert!(err.contains("checksum"), "byte {probe}: {err}");
        }
    }

    #[test]
    fn rejects_unknown_flags_and_empty_index() {
        let idx = sample_index();
        let mut payload = to_bytes(&idx)[HEADER_LEN..].to_vec();
        payload[0] |= 0x80; // unknown flag bit
        let bad = reseal(&payload);
        assert!(from_bytes(&bad).unwrap_err().to_string().contains("flag"));

        let mut empty = to_bytes(&idx)[HEADER_LEN..].to_vec();
        empty[4..12].copy_from_slice(&0u64.to_le_bytes()); // t = 0
        assert!(from_bytes(&reseal(&empty)).is_err());
    }

    #[test]
    fn rejects_inconsistent_radius() {
        // valid checksum, structurally sound, but radius lies about the
        // band: the loader must refuse rather than mis-search.
        let idx = sample_index();
        let mut payload = to_bytes(&idx)[HEADER_LEN..].to_vec();
        let wrong = (idx.radius as u64 + 1).to_le_bytes();
        payload[12..20].copy_from_slice(&wrong);
        let err = from_bytes(&reseal(&payload)).unwrap_err().to_string();
        assert!(err.contains("radius"), "{err}");
    }

    #[test]
    fn save_load_inspect_roundtrip() {
        let dir = std::env::temp_dir().join(format!("spdtw_persist_{}", std::process::id()));
        let path = dir.join("a.spix");
        let idx = sample_index();
        save_index(&idx, &path).unwrap();
        let back = load_index(&path).unwrap();
        assert_same(&idx, &back);

        let info = inspect(&path).unwrap();
        assert_eq!(info.version, VERSION);
        assert!(info.checksum_ok);
        assert_eq!(info.t, idx.t);
        assert_eq!(info.n, idx.len());
        assert_eq!(info.grid_nnz, None);

        // corrupt on disk -> load fails cleanly, inspect flags it
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 1;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_index(&path).is_err());
        assert!(!inspect(&path).unwrap().checksum_ok);

        assert!(load_index(&dir.join("missing.spix")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loader_rejects_lying_length_before_allocating() {
        // a header whose length field promises petabytes over a tiny
        // file must fail the metadata cross-check, never allocate
        let dir = std::env::temp_dir().join(format!("spdtw_persist_liar_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("liar.spix");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("truncated or padded"), "{err}");
        assert!(inspect(&path).is_err());

        // header-only truncation reads cleanly up to the header, then
        // fails the same check (0 promised vs whatever is on disk)
        std::fs::write(&path, &to_bytes(&sample_index())[..HEADER_LEN - 4]).unwrap();
        assert!(load_index(&path).unwrap_err().to_string().contains("truncated"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn search_results_identical_after_reload() {
        use crate::search::{Cascade, SearchEngine};
        let ds = synthetic::generate_scaled("SyntheticControl", 7, 12, 6).unwrap();
        let idx = Index::build(&ds.train, 6, 2);
        let back = from_bytes(&to_bytes(&idx)).unwrap();
        let a = SearchEngine::new(std::sync::Arc::new(idx), Cascade::default());
        let b = SearchEngine::new(std::sync::Arc::new(back), Cascade::default());
        for probe in &ds.test.series {
            let ra = a.knn(probe, 3);
            let rb = b.knn(probe, 3);
            assert_eq!(ra.neighbors.len(), rb.neighbors.len());
            for (x, y) in ra.neighbors.iter().zip(&rb.neighbors) {
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
                assert_eq!(x.train_idx, y.train_idx);
            }
        }
    }

    #[test]
    fn single_series_index_roundtrips() {
        let train = from_pairs(vec![(3, vec![1.0, -2.0, f64::MIN_POSITIVE, 0.0])]);
        let idx = Index::build(&train, usize::MAX, 1);
        assert_same(&idx, &from_bytes(&to_bytes(&idx)).unwrap());
    }

    /// Re-wrap a doctored payload with a fresh (valid) header+checksum.
    fn reseal(payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
        out.extend_from_slice(payload);
        out
    }
}
